"""Paper Fig. 8(a): runtime vs nearest-neighbor accuracy per method, on the
20News-like sparse text corpus.

Emits one CSV row per method: name, us_per_query, derived (precision@1/4/16
plus the speedup over the WMD reference). Expected qualitative reproduction:
ACT-k ~= WMD accuracy at orders-of-magnitude lower cost; RWMD fastest but
least accurate of the relaxations; BoW/WCD cheap and weaker for larger l.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (build_index, emit, precision_all,
                               text_corpus, timeit)
from repro.core.wmd import wmd_search


def run(n_wmd_queries: int = 12) -> None:
    corpus, labels = text_corpus()
    lj = jnp.asarray(labels)
    q_ids, q_w = corpus.ids[0], corpus.w[0]

    methods = [
        ("bow", dict(method="bow")),
        ("wcd", dict(method="wcd")),
        ("rwmd", dict(method="act", iters=0)),
        ("omr", dict(method="omr")),
        ("act-1", dict(method="act", iters=1)),
        ("act-3", dict(method="act", iters=3)),
        ("act-7", dict(method="act", iters=7)),
    ]
    # per-query scoring time, served through the unified index
    per_q = {}
    for name, kw in methods:
        index = build_index(corpus, **kw)
        per_q[name] = timeit(lambda ix=index: ix.scores(q_ids, q_w))

    # WMD (exact EMD + RWMD pruning) reference on a query subset
    t0 = time.perf_counter()
    hits = {1: [], 4: [], 16: []}
    for qi in range(n_wmd_queries):
        for top_l in hits:
            _, idx = wmd_search(corpus, qi, top_l)
            hits[top_l].append(np.mean(labels[idx] == labels[qi]))
    wmd_us = (time.perf_counter() - t0) * 1e6 / (n_wmd_queries * 3)
    wmd_prec = {k: float(np.mean(v)) for k, v in hits.items()}
    emit("fig8.wmd", wmd_us,
         "prec@1=%.3f prec@4=%.3f prec@16=%.3f speedup=1x"
         % (wmd_prec[1], wmd_prec[4], wmd_prec[16]))

    for name, kw in methods:
        precs = {L: precision_all(corpus, labels, top_l=L, **kw)
                 for L in (1, 4, 16)}
        emit(f"fig8.{name}", per_q[name],
             "prec@1=%.3f prec@4=%.3f prec@16=%.3f speedup=%.0fx"
             % (precs[1], precs[4], precs[16], wmd_us / per_q[name]))


if __name__ == "__main__":
    run()
