"""Batched multi-query throughput: queries/sec of the batched engine
(Phase 1 amortized across the query batch, query-blocked Phase 2) vs the
``engine="scan"`` ``lax.map`` fallback, at nq in {1, 8, 64}.

Timing is PAIRED: scan and batched runs interleave rep by rep and the
speedup is the median of per-rep ratios, so machine-load drift cancels
instead of polluting one side. Emits CSV rows like every other benchmark
AND writes ``BENCH_batch.json`` (repo root, override with
BENCH_BATCH_JSON) so the queries/sec trajectory is tracked across PRs.
``BENCH_SMOKE=1`` shrinks every dimension to CI smoke sizes.

On CPU the headline case is rwmd (LC-RWMD, the paper's zero-Phase-2-round
serving fast path): its batched engine replaces per-query ranked top-1
selection with one masked min and streams blocked gathers, a >= 2x
queries/sec win at nq=64. act/omr amortize the same way but stay
gather/pour-bound on CPU; on TPU the stacked Phase-1 matmul and the
query-batched kernel grids are where the batch axis pays off hardest.
"""
from __future__ import annotations

import json
import os

import jax

import jax.numpy as jnp

from benchmarks.common import device_kind, emit, paired, text_corpus, timeit
from repro.api import EmdIndex, EngineConfig
from repro.core import retrieval
from repro.core.precision import resolve

#: (method, iters) cases: the fast relaxation, the overlap fix, the
#: tight bound.
CASES = (("rwmd", 0), ("omr", 0), ("act", 3))

#: The mixed-precision frontier: every policy is swept for recall drift
#: against the f32 ranking, handoff bytes, and throughput.
PRECISION_POLICIES = ("f32", "bf16", "bf16_agg")

#: (method, iters) cases for the distributed-step smoke entry (the
#: method-generic mesh pipeline; single-host mesh here, so this tracks
#: step-latency drift rather than scaling).
DIST_CASES = (("rwmd", 0), ("act", 3))


def _sizes(smoke: bool) -> dict:
    if smoke:
        return dict(n_docs=48, n_classes=4, vocab=192, m=16, doc_len=24,
                    hmax=16, nqs=(1, 4), reps=3)
    return dict(n_docs=512, n_classes=8, vocab=512, m=16, doc_len=20,
                hmax=16, nqs=(1, 8, 64), reps=11)


def _precision_sweep(report: dict, corpus, nq: int, reps: int,
                     top_l: int) -> None:
    """The precision-vs-recall frontier: the batched ACT engine under
    each precision policy, recording recall@top_l against the float32
    ranking (delta 0 for f32 by construction), the Phase-1 handoff bytes
    per (query, vocab-row) pair implied by the policy's storage dtype —
    the Z/W ladders hold ``2 * iters + 1`` entries per pair — and the
    measured queries/sec. ``analysis.bench_check`` requires all three
    policies present, the bf16 bytes exactly halved, and the bf16 recall
    delta within the acceptance band."""
    iters = 3
    q_ids, q_w = corpus.ids[:nq], corpus.w[:nq]
    entries = []
    ref_scores = None
    for policy in PRECISION_POLICIES:
        ix = EmdIndex.build(corpus, EngineConfig(
            method="act", iters=iters, top_l=top_l, precision=policy))
        scores = ix.scores(q_ids, q_w)
        if ref_scores is None:                       # f32 runs first
            ref_scores = scores
        _, ref_idx = jax.lax.top_k(-ref_scores, top_l)
        _, idx = jax.lax.top_k(-scores, top_l)
        recall = retrieval.topl_overlap(idx, ref_idx)
        maxerr = float(jnp.abs(scores.astype(jnp.float32)
                               - ref_scores).max())
        us = timeit(lambda: ix.scores(q_ids, q_w), n_iter=reps)
        qps = nq / (us / 1e6)
        storage = jnp.dtype(resolve(policy).storage)
        emit(f"bench_batch.precision.{policy}", us,
             f"recall@{top_l}={recall:.4f} qps={qps:.1f}")
        entries.append(dict(
            policy=policy, storage_dtype=storage.name,
            recall_at_l_vs_f32=round(recall, 4),
            recall_delta_vs_f32=round(1.0 - recall, 4),
            handoff_bytes_per_row=storage.itemsize * (2 * iters + 1),
            max_abs_err_vs_f32=maxerr,
            us_per_call=round(us, 1), queries_per_sec=round(qps, 1)))
    report["precision_sweep"] = dict(method="act", iters=iters, nq=nq,
                                     top_l=top_l, entries=entries)


def run() -> None:
    smoke = os.environ.get("BENCH_SMOKE", "0") not in ("0", "")
    sz = _sizes(smoke)
    nqs, reps = sz.pop("nqs"), sz.pop("reps")
    corpus, _ = text_corpus(**sz, seed=11)
    # Tile policy: with BENCH_TUNE_CACHE set the indexes apply that
    # TuneCache's winners ("cached" never times, so runs stay
    # deterministic); without it the dataclass-default tiles are used.
    tune_cache = os.environ.get("BENCH_TUNE_CACHE") or None
    autotune = "cached" if tune_cache else "off"
    report = {"bench": "bench_batch", "smoke": smoke,
              "sizes": dict(sz, nqs=list(nqs)),
              "backend": jax.default_backend(),
              "device_kind": device_kind(),
              "autotune": {"mode": autotune, "tune_cache": tune_cache,
                           "tuned_blocks": {}},
              "entries": [], "speedup_batched_over_scan": {}}

    for method, iters in CASES:
        for nq in nqs:
            q_ids, q_w = corpus.ids[:nq], corpus.w[:nq]
            scan = EmdIndex.build(corpus, EngineConfig(
                method=method, iters=iters, batch_engine="scan"))
            batched = EmdIndex.build(corpus, EngineConfig(
                method=method, iters=iters, batch_engine="batched"))
            us_s, us_b, speedup = paired(
                lambda: scan.scores(q_ids, q_w),
                lambda: batched.scores(q_ids, q_w), reps)
            for engine, us in (("scan", us_s), ("batched", us_b)):
                qps = nq / (us / 1e6)
                emit(f"bench_batch.{method}.nq{nq}.{engine}", us,
                     f"qps={qps:.1f}")
                report["entries"].append(dict(
                    method=method, iters=iters, nq=nq, engine=engine,
                    us_per_call=round(us, 1),
                    queries_per_sec=round(qps, 1)))
            emit(f"bench_batch.{method}.nq{nq}.speedup", 0.0,
                 f"batched/scan={speedup:.2f}x")
            report["speedup_batched_over_scan"][f"{method}.nq{nq}"] = round(
                speedup, 2)

    # Distributed-step smoke: the same batched pipeline traced through the
    # mesh-sharded step (EmdIndex builds a single-device mesh when none is
    # passed). Guards the serving path the host-mesh CI job parity-tests.
    nq_d = max(nqs)
    q_ids, q_w = corpus.ids[:nq_d], corpus.w[:nq_d]
    report["distributed_step"] = {}
    for method, iters in DIST_CASES:
        dist = EmdIndex.build(corpus, EngineConfig(
            method=method, iters=iters, backend="distributed",
            pad_multiple=64, autotune=autotune, tune_cache=tune_cache))
        report["autotune"]["tuned_blocks"].update(dist.tuned_blocks)
        us = timeit(lambda: dist.scores(q_ids, q_w), n_iter=reps)
        qps = nq_d / (us / 1e6)
        emit(f"bench_batch.{method}.nq{nq_d}.distributed", us,
             f"qps={qps:.1f}")
        report["entries"].append(dict(
            method=method, iters=iters, nq=nq_d, engine="distributed",
            us_per_call=round(us, 1), queries_per_sec=round(qps, 1)))
        report["distributed_step"][f"{method}.nq{nq_d}"] = round(qps, 1)

    _precision_sweep(report, corpus, max(nqs), reps,
                     top_l=4 if smoke else 16)

    path = os.environ.get("BENCH_BATCH_JSON", "BENCH_batch.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")


if __name__ == "__main__":
    run()
