"""Shared benchmark harness: timing, CSV emission, dataset cache."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import EmdIndex, EngineConfig
from repro.data.synth import make_image_like, make_text_like


def timeit(fn, *args, n_warmup: int = 1, n_iter: int = 3) -> float:
    """Median wall time in microseconds (after jit warmup)."""
    for _ in range(n_warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(n_iter):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def paired(fn_a, fn_b, reps: int):
    """Interleaved timing: per-rep (a_us, b_us) pairs after joint warmup.
    Returns (median_a_us, median_b_us, median of per-rep a/b ratios).

    The interleaving cancels slow drift (thermal, background load) that
    would bias two back-to-back timing loops — the single home of the
    comparison harness: the bench entry points and the tile autotuner's
    config tournaments (``repro.kernels.autotune``) all time through
    here."""
    jax.block_until_ready(fn_a())
    jax.block_until_ready(fn_b())
    ta, tb, ratios = [], [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        a = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        b = (time.perf_counter() - t0) * 1e6
        ta.append(a)
        tb.append(b)
        ratios.append(a / b)
    return (float(np.median(ta)), float(np.median(tb)),
            float(np.median(ratios)))


def device_kind() -> str:
    """Hardware kind of device 0 (e.g. "cpu", "TPU v4") — recorded into
    the BENCH JSONs next to ``jax.default_backend()``."""
    return str(jax.devices()[0].device_kind)


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


@functools.lru_cache(maxsize=None)
def text_corpus(n_docs=512, n_classes=8, vocab=2048, m=64, doc_len=80,
                hmax=64, seed=11):
    c, labels = make_text_like(n_docs=n_docs, n_classes=n_classes,
                               vocab=vocab, m=m, doc_len=doc_len, hmax=hmax,
                               seed=seed)
    return c, np.asarray(labels)


@functools.lru_cache(maxsize=None)
def image_corpus(n_images=192, n_classes=6, side=12, background=False,
                 seed=5):
    c, labels = make_image_like(n_images=n_images, n_classes=n_classes,
                                side=side, include_background=background,
                                seed=seed)
    return c, np.asarray(labels)


def build_index(corpus, method: str, iters: int = 1,
                backend: str = "reference") -> EmdIndex:
    """One EmdIndex per (method, iters, backend) — every benchmark entry
    point scores through the unified serving API."""
    return EmdIndex.build(corpus, EngineConfig(method=method, iters=iters,
                                               backend=backend))


def precision_all(corpus, labels, method: str, top_l: int,
                  iters: int = 1) -> float:
    return build_index(corpus, method, iters).precision_at_l(
        jnp.asarray(labels), top_l)
