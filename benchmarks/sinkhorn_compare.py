"""Paper Fig. 8(b): ACT vs Sinkhorn on image histograms — accuracy AND
runtime (the paper reports 4 orders of magnitude speedup at equal-or-better
precision; on CPU the gap is smaller but the shape of the result is the
same: ACT-1 matches/bests Sinkhorn precision at a fraction of the cost)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (build_index, emit, image_corpus,
                               precision_all, timeit)
from repro.core import sinkhorn
from repro.core.geometry import pairwise_dist


def run(n_queries: int = 24, top_l: int = 8) -> None:
    corpus, labels = image_corpus(n_images=96, background=False)
    n = corpus.n

    # Sinkhorn: dense histograms over the pixel grid, lambda=20 (paper's)
    v = corpus.v
    dense = np.zeros((n, v), np.float32)
    ids, w = np.asarray(corpus.ids), np.asarray(corpus.w)
    for u in range(n):
        dense[u, ids[u]] += w[u]
    dense = jnp.asarray(dense)
    C = pairwise_dist(corpus.coords, corpus.coords)

    @jax.jit
    def sink_scores(q):
        return jax.vmap(
            lambda p: sinkhorn.sinkhorn_cost(p, q, C, lam=20.0, n_iters=50)
        )(dense)

    t_sink = timeit(lambda: sink_scores(dense[0]))
    hits = []
    for qi in range(n_queries):
        s = np.array(sink_scores(dense[qi]))
        s[qi] = np.inf
        idx = np.argsort(s)[:top_l]
        hits.append(np.mean(labels[idx] == labels[qi]))
    emit("fig8b.sinkhorn", t_sink,
         f"prec@{top_l}={float(np.mean(hits)):.4f} lam=20")

    index = build_index(corpus, "act", iters=1)
    t_act = timeit(lambda: index.scores(corpus.ids[0], corpus.w[0]))
    p_act = precision_all(corpus, labels, method="act", top_l=top_l, iters=1)
    emit("fig8b.act-1", t_act,
         f"prec@{top_l}={p_act:.4f} speedup={t_sink / t_act:.0f}x")


if __name__ == "__main__":
    run()
