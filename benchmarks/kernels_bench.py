"""Kernel-level microbenchmarks: Pallas (interpret on CPU) vs pure-jnp
reference, the HBM-traffic model that motivates the fusion (DESIGN.md
section 2: one pass over X instead of k), and a tile-size sweep.

The sweep does NOT hand-roll tile shapes: it enumerates exactly the
configs ``repro.kernels.autotune.admissible_configs`` admits — the same
``analysis/vmem.check_launch`` filter the autotuner times through — so
every timed point is a launch that fits the 16 MiB VMEM budget and the
bench can never report a number for a config that would OOM a core.
``BENCH_SMOKE=1`` caps the number of configs timed per family (the cap
is emitted, never silent).
"""
from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels import autotune, ref

#: (family, launch dims) swept — one per kernel family, at sizes small
#: enough that CPU interpret mode can time the whole admissible set.
SWEEPS = (
    ("dist_topk", dict(nq=2, v=256, h=32, m=16, k=4)),
    ("act_phase2", dict(nq=2, n=256, h=32, iters=3)),
    ("cand_pour", dict(nq=2, b=32, h=32, v=256, k=4, iters=3,
                       mode="pour")),
    ("cand_dist", dict(nq=2, b=32, h=32, v=256, qh=32, mode="ict")),
)


def _sweep() -> None:
    smoke = os.environ.get("BENCH_SMOKE", "0") not in ("0", "")
    cap = 4 if smoke else None
    for family, dims in SWEEPS:
        cfgs = autotune.admissible_configs(family, dims)
        dtag = ",".join(f"{k}={v}" for k, v in sorted(dims.items()))
        emit(f"kernels.sweep.{family}.admissible", float(len(cfgs)),
             f"dims[{dtag}] configs admitted by vmem.check_launch")
        # Smoke cap samples evenly across the admissible list so both
        # tiny and large tiles stay covered, not just the slow small ones.
        timed = (cfgs if cap is None
                 else cfgs[::max(1, len(cfgs) // cap)][:cap])
        if len(timed) < len(cfgs):
            emit(f"kernels.sweep.{family}.capped", float(len(timed)),
                 f"timing {len(timed)}/{len(cfgs)} admissible "
                 "(BENCH_SMOKE=1)")
        make_run = autotune._runner(family, dims)
        best_cfg, best_us = None, float("inf")
        for cfg in timed:
            us = timeit(make_run(cfg))
            ctag = ",".join(f"{k}={v}" for k, v in sorted(cfg.items()))
            emit(f"kernels.sweep.{family}[{ctag}]", us, f"dims[{dtag}]")
            if us < best_us:
                best_cfg, best_us = cfg, us
        ctag = ",".join(f"{k}={v}" for k, v in sorted(best_cfg.items()))
        emit(f"kernels.sweep.{family}.best", best_us, ctag)


def run() -> None:
    rng = np.random.default_rng(0)
    v, h, m, k = 2048, 256, 64, 8
    coords = jnp.asarray(rng.normal(size=(v, m)), jnp.float32)
    qc = jnp.asarray(rng.normal(size=(h, m)), jnp.float32)
    qmask = jnp.ones((h,), jnp.float32)
    t_ref = timeit(lambda: ref.dist_topk_ref(coords, qc, qmask, k))
    emit("kernels.dist_topk_ref_jnp", t_ref,
         f"v={v} h={h} m={m} k={k} materializes D: {v*h*4/1e6:.1f}MB")
    emit("kernels.dist_topk_out_bytes", float(v * k * 8),
         f"fused output {v*k*8/1e6:.2f}MB = {h/(2*k):.0f}x smaller than D")

    n, hmax, it = 4096, 128, 7
    x = jnp.asarray(rng.uniform(size=(n, hmax)), jnp.float32)
    zg = jnp.asarray(np.sort(rng.uniform(size=(n, hmax, it + 1)), -1),
                     jnp.float32)
    wg = jnp.asarray(rng.uniform(size=(n, hmax, it)), jnp.float32)
    t2 = timeit(lambda: ref.act_phase2_ref(x, zg, wg))
    emit("kernels.act_phase2_ref_jnp", t2,
         f"n={n} hmax={hmax} iters={it}")
    paper_traffic = it * (2 * x.nbytes + zg.nbytes // (it + 1) + wg.nbytes // it)
    fused_traffic = x.nbytes + zg.nbytes + wg.nbytes
    emit("kernels.act_phase2_traffic_model", float(fused_traffic),
         f"paper k-pass bytes={paper_traffic} fused bytes={fused_traffic} "
         f"cut={paper_traffic/fused_traffic:.2f}x")

    _sweep()


if __name__ == "__main__":
    run()
