"""Kernel-level microbenchmarks: Pallas (interpret on CPU) vs pure-jnp
reference, plus the HBM-traffic model that motivates the fusion
(DESIGN.md section 2: one pass over X instead of k)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels import ops, ref


def run() -> None:
    rng = np.random.default_rng(0)
    v, h, m, k = 2048, 256, 64, 8
    coords = jnp.asarray(rng.normal(size=(v, m)), jnp.float32)
    qc = jnp.asarray(rng.normal(size=(h, m)), jnp.float32)
    qmask = jnp.ones((h,), jnp.float32)
    t_ref = timeit(lambda: ref.dist_topk_ref(coords, qc, qmask, k))
    emit("kernels.dist_topk_ref_jnp", t_ref,
         f"v={v} h={h} m={m} k={k} materializes D: {v*h*4/1e6:.1f}MB")
    emit("kernels.dist_topk_out_bytes", float(v * k * 8),
         f"fused output {v*k*8/1e6:.2f}MB = {h/(2*k):.0f}x smaller than D")

    n, hmax, it = 4096, 128, 7
    x = jnp.asarray(rng.uniform(size=(n, hmax)), jnp.float32)
    zg = jnp.asarray(np.sort(rng.uniform(size=(n, hmax, it + 1)), -1),
                     jnp.float32)
    wg = jnp.asarray(rng.uniform(size=(n, hmax, it)), jnp.float32)
    t2 = timeit(lambda: ref.act_phase2_ref(x, zg, wg))
    emit("kernels.act_phase2_ref_jnp", t2,
         f"n={n} hmax={hmax} iters={it}")
    paper_traffic = it * (2 * x.nbytes + zg.nbytes // (it + 1) + wg.nbytes // it)
    fused_traffic = x.nbytes + zg.nbytes + wg.nbytes
    emit("kernels.act_phase2_traffic_model", float(fused_traffic),
         f"paper k-pass bytes={paper_traffic} fused bytes={fused_traffic} "
         f"cut={paper_traffic/fused_traffic:.2f}x")


if __name__ == "__main__":
    run()
