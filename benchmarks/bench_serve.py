"""Online serving runtime under offered load and injected faults.

Drives ``repro.serving.EmdServer`` — the micro-batching queue plus the
degradation ladder — with seeded open-loop traffic (exponential
inter-arrivals) at several offered loads and records, per load level:

* request latency p50 / p99 (ms, enqueue -> resolved future),
* the served-tier mix (how often the ladder degraded, and to what),
* micro-batch shape stats (launches, flushes, bucket histogram), and
* sheds (requests fast-failed after the whole ladder was exhausted).

A final CHAOS entry replays deterministic traffic under a seeded
:class:`~repro.serving.ChaosSchedule` (the same schedules the chaos test
suite proves correct: every request completes, degraded tiers labeled,
zero wrong results) and asserts the served-tier mix reproduces exactly
under the fixed seed — run twice, compared byte for byte.

Results append to the CSV stream and land in ``BENCH_serve.json`` (repo
root, override with BENCH_SERVE_JSON). ``BENCH_SMOKE=1`` shrinks corpus,
load levels, and request counts to CI smoke sizes.
"""
from __future__ import annotations

import asyncio
import json
import os

import jax
import numpy as np

from benchmarks.common import emit, text_corpus
from repro.api import EmdIndex, EngineConfig
from repro.serving import (ChaosInjector, ChaosSchedule, EmdServer,
                           ServerOverloaded, ServingPolicy)

#: Offered load levels in requests/sec (open loop: arrivals don't wait
#: for completions, so overload shows up as queueing + degradation).
LOADS = (50.0, 200.0, 800.0)
LOADS_SMOKE = (50.0, 400.0)

CHAOS_SEED = 17
CHAOS_P_FAIL = 0.25


def _sizes(smoke: bool) -> dict:
    if smoke:
        return dict(n_docs=64, n_classes=4, vocab=192, m=16, doc_len=24,
                    hmax=16, top_l=4, n_req=32, iters=2)
    return dict(n_docs=512, n_classes=8, vocab=512, m=16, doc_len=20,
                hmax=16, top_l=8, n_req=192, iters=3)


def _policy() -> ServingPolicy:
    return ServingPolicy(ladder=("primary", "fast", "wcd"), max_batch=16,
                         flush_ms=2.0, deadline_ms=500.0, max_retries=1,
                         backoff_ms=0.5)


async def _drive_open_loop(server: EmdServer, corpus, n_req: int,
                           qps: float, seed: int):
    """Seeded open-loop arrivals; returns (results, sheds)."""
    rng = np.random.default_rng(seed)
    at = np.cumsum(rng.exponential(1.0 / qps, n_req))
    results, sheds = [], 0

    async def one(k: int, t: float):
        nonlocal sheds
        await asyncio.sleep(t)
        try:
            results.append(await server.search(corpus.ids[k % corpus.n],
                                               corpus.w[k % corpus.n]))
        except ServerOverloaded:
            sheds += 1

    await asyncio.gather(*[one(k, float(at[k])) for k in range(n_req)])
    return results, sheds


def _mix(results) -> dict[str, int]:
    mix: dict[str, int] = {}
    for r in results:
        mix[r.tier] = mix.get(r.tier, 0) + 1
    return dict(sorted(mix.items()))


def _load_entry(index, corpus, qps: float, n_req: int) -> dict:
    async def go():
        async with EmdServer(index, _policy()) as server:
            # Warm every primary (tier, bucket) jit shape out of the
            # measurement: a burst per power-of-two bucket.
            b = 1
            while b <= server.policy.max_batch:
                await asyncio.gather(*[
                    server.search(corpus.ids[k % corpus.n],
                                  corpus.w[k % corpus.n])
                    for k in range(b)])
                b <<= 1
            server.stats = type(server.stats)()     # measured run only
            results, sheds = await _drive_open_loop(
                server, corpus, n_req, qps, seed=int(qps))
            return results, sheds, server.stats
    results, sheds, stats = asyncio.run(go())
    lat = np.asarray([r.latency_ms for r in results])
    p50 = float(np.percentile(lat, 50)) if lat.size else float("nan")
    p99 = float(np.percentile(lat, 99)) if lat.size else float("nan")
    degraded = sum(1 for r in results if r.degraded)
    entry = dict(
        offered_qps=qps, n_requests=n_req,
        completed=len(results) + sheds, served=len(results), shed=sheds,
        p50_ms=round(p50, 3), p99_ms=round(p99, 3),
        tier_mix=_mix(results), degraded=degraded,
        launches=stats.launches, flushes=stats.flushes,
        bucket_launches={str(k): v for k, v in
                         sorted(stats.bucket_launches.items())})
    emit(f"bench_serve.load{int(qps)}", p50 * 1e3,
         f"p99_ms={p99:.1f} served={len(results)} shed={sheds} "
         f"degraded={degraded} launches={stats.launches}")
    return entry


def _chaos_run(index, corpus, n_req: int) -> dict:
    """Sequential deterministic traffic under a seeded fault schedule;
    launch order is then a pure function of the schedule, so the tier
    sequence must reproduce exactly."""
    schedule = ChaosSchedule.from_seed(CHAOS_SEED, horizon=8 * n_req,
                                       p_fail=CHAOS_P_FAIL)

    def once():
        chaos = ChaosInjector(schedule)

        async def go():
            async with EmdServer(index, _policy(),
                                 launch_hook=chaos) as server:
                tiers, sheds, lat = [], 0, []
                for k in range(n_req):
                    try:
                        r = await server.search(
                            corpus.ids[k % corpus.n],
                            corpus.w[k % corpus.n])
                        tiers.append(r.tier)
                        lat.append(r.latency_ms)
                    except ServerOverloaded:
                        sheds += 1
                        tiers.append("SHED")
                return tiers, sheds, lat, server.stats
        return asyncio.run(go()) + (chaos,)

    tiers_a, sheds_a, lat, stats, chaos = once()
    tiers_b, sheds_b, *_ = once()
    mix = {t: tiers_a.count(t) for t in sorted(set(tiers_a))}
    completed = len(tiers_a)            # served or fast-failed, no hangs
    entry = dict(
        seed=CHAOS_SEED, p_fail=CHAOS_P_FAIL, n_requests=n_req,
        completed=completed, shed=sheds_a,
        tier_mix=mix, launch_failures=stats.launch_failures,
        injected_faults=sum(1 for e in chaos.log if e[2] == "fail"),
        p50_ms=round(float(np.percentile(lat, 50)), 3) if lat else None,
        deterministic=bool(tiers_a == tiers_b and sheds_a == sheds_b))
    emit("bench_serve.chaos", entry["p50_ms"] * 1e3 if lat else 0.0,
         f"completed={completed}/{n_req} shed={sheds_a} "
         f"failures={stats.launch_failures} "
         f"deterministic={entry['deterministic']}")
    return entry


def run() -> None:
    smoke = os.environ.get("BENCH_SMOKE", "0") not in ("0", "")
    sz = _sizes(smoke)
    n_req, top_l, iters = sz.pop("n_req"), sz.pop("top_l"), sz.pop("iters")
    corpus, _ = text_corpus(**sz, seed=11)
    index = EmdIndex.build(corpus, EngineConfig(method="act", iters=iters,
                                                top_l=top_l))
    report = {"bench": "bench_serve", "smoke": smoke,
              "sizes": dict(sz, n_req=n_req, top_l=top_l, iters=iters),
              "backend": jax.default_backend(),
              "ladder": list(_policy().ladder), "entries": []}
    for qps in (LOADS_SMOKE if smoke else LOADS):
        report["entries"].append(_load_entry(index, corpus, qps, n_req))
    report["chaos"] = _chaos_run(index, corpus, n_req)

    path = os.environ.get("BENCH_SERVE_JSON", "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")


if __name__ == "__main__":
    run()
