"""Paper Table 6: dense histograms (background included) break RWMD
(precision ~ chance) while OMR/ACT stay near the sparse-case accuracy —
the paper's central robustness claim."""
from __future__ import annotations

from benchmarks.common import (build_index, emit, image_corpus,
                               precision_all, timeit)


def run() -> None:
    corpus, labels = image_corpus(background=True)
    n_classes = int(labels.max()) + 1
    index = build_index(corpus, "omr")
    t = timeit(lambda: index.scores(corpus.ids[0], corpus.w[0]))
    rows = [("bow", dict(method="bow")),
            ("rwmd", dict(method="act", iters=0)),
            ("omr", dict(method="omr")),
            ("act-7", dict(method="act", iters=7)),
            ("act-15", dict(method="act", iters=15))]
    for name, kw in rows:
        precs = {L: precision_all(corpus, labels, top_l=L, **kw)
                 for L in (1, 16, 64)}
        emit(f"table6.{name}", t,
             "prec@1=%.4f prec@16=%.4f prec@64=%.4f chance=%.3f"
             % (precs[1], precs[16], precs[64], 1.0 / n_classes))


if __name__ == "__main__":
    run()
