"""Paper Table 5: precision@top-l on sparse image histograms (no
background): BoW vs RWMD vs ACT-1/3/7. Expected: all high; ACT >= BoW for
larger l; ACT-k improves monotonically with k."""
from __future__ import annotations

from benchmarks.common import (build_index, emit, image_corpus,
                               precision_all, timeit)


def run() -> None:
    corpus, labels = image_corpus(background=False)
    index = build_index(corpus, "act", iters=1)
    t = timeit(lambda: index.scores(corpus.ids[0], corpus.w[0]))
    for name, kw in [("bow", dict(method="bow")),
                     ("rwmd", dict(method="act", iters=0)),
                     ("act-1", dict(method="act", iters=1)),
                     ("act-3", dict(method="act", iters=3)),
                     ("act-7", dict(method="act", iters=7))]:
        precs = {L: precision_all(corpus, labels, top_l=L, **kw)
                 for L in (1, 16, 64)}
        emit(f"table5.{name}", t,
             "prec@1=%.4f prec@16=%.4f prec@64=%.4f"
             % (precs[1], precs[16], precs[64]))


if __name__ == "__main__":
    run()
