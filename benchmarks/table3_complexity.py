"""Paper Tables 2/3: empirical complexity scaling of LC-ACT.

The claim: time is LINEAR in each of n (database size), h (histogram
size), k (iterations) and v (vocabulary), i.e. O(vhm + nhk). We time
lc_act_scores while doubling one parameter at a time and report the
scaling exponent log2(t(2x)/t(x)) — should be ~<=1 (sublinear exponents
appear when the doubled term is not dominant)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_index, emit, timeit
from repro.data.synth import make_text_like


def _time_for(n_docs=256, vocab=1024, m=32, hmax=32, iters=3, seed=0):
    c, _ = make_text_like(n_docs=n_docs, vocab=vocab, m=m,
                          doc_len=2 * hmax, hmax=hmax, seed=seed)
    index = build_index(c, "act", iters=iters)
    return timeit(lambda: index.scores(c.ids[0], c.w[0]))


def run() -> None:
    base = dict(n_docs=256, vocab=1024, m=32, hmax=32, iters=3)
    t0 = _time_for(**base)
    emit("table3.base", t0, f"params={base}")
    for key, hi in [("n_docs", 512), ("vocab", 2048), ("hmax", 64),
                    ("iters", 6)]:
        kw = dict(base)
        kw[key] = hi
        t1 = _time_for(**kw)
        exponent = np.log2(max(t1, 1e-9) / max(t0, 1e-9))
        emit(f"table3.double_{key}", t1,
             f"scaling_exponent={exponent:.2f} (linear==1.0, quadratic==2.0)")


if __name__ == "__main__":
    run()
