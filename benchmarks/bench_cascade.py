"""Cascaded prune-and-rescore throughput and recall vs full-corpus ACT.

The acceptance workload of the cascade subsystem: the ``wcd -> rwmd ->
act`` ladder at rescore budgets {1%, 5%, 20%} of n against full-corpus
LC-ACT scoring of the same query batch — each budget measured on the
reference engines AND on the ``use_kernels`` path (``backend="pallas"``:
fused candidate kernels for the pruned stages + rescorer). For each
entry it reports

* recall@l of the cascade's top-l vs the full ACT top-l,
* end-to-end queries/sec (PAIRED interleaved timing vs full scoring, as
  in ``bench_batch``), and
* the rows-scored ladder — the cascade's pruned stages together read
  strictly fewer candidate rows than the n the full scorer reads.

NOTE on the kernel entries off-TPU: without a TPU the kernels run in
interpret mode, where the in-kernel one-hot gather is emulated as dense
matmuls on the CPU — their queries/sec is a conformance smoke number,
not a perf claim (the MXU gather win is a TPU measurement; see ROADMAP).

Results append to the CSV stream and land in ``BENCH_cascade.json``
(repo root, override with BENCH_CASCADE_JSON) with a distributed-step
entry (the mesh cascade step with its shard-blocked top-budget, on a
single-device mesh here) carrying the same recall + queries/sec fields.
``BENCH_SMOKE=1`` shrinks everything to CI smoke sizes.

The CORPUS-SIZE SWEEP (``sweep`` in the report) is the candidate-source
subsystem's acceptance axis: at each n in {4k, 64k, 1M} (smoke: {256,
512}) a clustered corpus is searched through ``EmdIndex`` with the
full-scan cascade (the reference ranking AND the qps bar) and with each
sublinear source (``centroid_lsh``, ``cluster_tree``), recording
recall@l vs the full-scan top-l, queries/sec, index build seconds, and
probed rows per query. The full-scan stage 1 reads all n rows, so its
qps falls linearly with n; the sourced entries read only their probed
rows, which is what must show as flat latency and a widening speedup at
1M (recall@16 >= 0.9 is the acceptance bar; ``analysis/bench_check``
enforces both).
"""
from __future__ import annotations

import json
import os
import time

import jax

from benchmarks.common import device_kind, emit, paired, text_corpus, timeit
from repro import cascade
from repro.api import EmdIndex, EngineConfig
from repro.candidates import CentroidLSHSpec, ClusterTreeSpec
from repro.cascade import CascadeSpec, CascadeStage
from repro.data.synth import make_clustered_text

#: Rescore budgets as fractions of n (the acceptance grid).
BUDGETS = (0.01, 0.05, 0.20)

#: The mixed-precision frontier, swept through the cascade at the 5%
#: budget (see ``bench_batch.PRECISION_POLICIES`` for the batched-engine
#: sweep of the same policies).
PRECISION_POLICIES = ("f32", "bf16", "bf16_agg")

#: ACT Phase-2 rounds of both the full-corpus baseline and the rescorer.
ACT_ITERS = 3


def _spec(pct: float) -> CascadeSpec:
    """The acceptance cascade at rescore budget ``pct``: wcd prefetch
    keeping 8x the final budget (capped at the full corpus), rwmd prune
    to ``pct``, ACT rescore. The 8x headroom is what the centroid
    heuristic needs to hold >= 0.95 of the true ACT neighbors (rwmd is a
    near-perfect ACT proxy at these budgets; wcd is the lossy stage)."""
    return CascadeSpec(stages=(CascadeStage("wcd", min(8 * pct, 1.0)),
                               CascadeStage("rwmd", pct)),
                       rescorer="act", rescorer_iters=ACT_ITERS)


def _sizes(smoke: bool) -> dict:
    if smoke:
        return dict(n_docs=64, n_classes=4, vocab=192, m=16, doc_len=24,
                    hmax=16, nq=8, top_l=4, reps=3)
    return dict(n_docs=1024, n_classes=8, vocab=512, m=16, doc_len=20,
                hmax=16, nq=64, top_l=16, reps=7)


def _sweep_plan(smoke: bool) -> list[dict]:
    """Per-n sweep rungs: the full-scan reference ladder (absolute
    budgets so the scan cost is the only thing growing with n) and the
    two sublinear sources sized to the corpus. Every source sets
    ``refine`` to the reference's wcd scan budget: the probed rows are
    re-ranked by exact centroid distance so the downstream rwmd stage
    sees the same wcd-prefix geometry the reference cascade does —
    without it, probed rows outside that prefix crowd true neighbors
    out of the prune budget (~0.10 recall lost at 64k). Probe counts
    target ~12% of buckets (the measured recall@16 >= 0.9 operating
    point); caps carry ~2x headroom over mean occupancy so overflow
    drops stay in the low percent."""
    if smoke:
        return [
            dict(n=256, scan=64, prune=32,
                 lsh=CentroidLSHSpec(n_buckets=16, probes=6, bucket_cap=32,
                                     refine=64),
                 tree=ClusterTreeSpec(branching=4, depth=2, beam=4,
                                      probes=3, leaf_cap=32, refine=64)),
            dict(n=512, scan=128, prune=32,
                 lsh=CentroidLSHSpec(n_buckets=16, probes=6, bucket_cap=64,
                                     refine=128),
                 tree=ClusterTreeSpec(branching=4, depth=2, beam=4,
                                      probes=3, leaf_cap=64, refine=128)),
        ]
    return [
        dict(n=4096, scan=512, prune=128,
             lsh=CentroidLSHSpec(n_buckets=64, probes=8, bucket_cap=128,
                                 refine=512),
             tree=ClusterTreeSpec(branching=8, depth=2, beam=8, probes=6,
                                  leaf_cap=128, refine=512)),
        dict(n=65536, scan=2048, prune=256,
             lsh=CentroidLSHSpec(n_buckets=256, probes=32, bucket_cap=512,
                                 refine=2048),
             tree=ClusterTreeSpec(branching=16, depth=2, beam=16,
                                  probes=16, leaf_cap=512, refine=2048)),
        dict(n=1_000_000, scan=4096, prune=256,
             lsh=CentroidLSHSpec(n_buckets=1024, probes=128,
                                 bucket_cap=2048, refine=4096),
             tree=ClusterTreeSpec(branching=16, depth=2, beam=16,
                                  probes=16, leaf_cap=8192, refine=4096)),
    ]


def _sweep(report: dict, smoke: bool, top_l: int) -> None:
    """The corpus-size sweep: full-scan reference vs each sublinear
    source at every n, through ``EmdIndex.search``."""
    nq = 8 if smoke else 16
    report["sweep"] = []
    for rung in _sweep_plan(smoke):
        n = rung["n"]
        reps = 2 if (smoke or n >= 1_000_000) else 3
        # min_len=20: WCD prefetch (and therefore centroid bucketing)
        # needs documents long enough for centroids to carry topic
        # signal — at zipf-minimum lengths of 4 the wcd rank of true
        # neighbors degrades ~10x and no probe budget recovers it.
        corpus, _ = make_clustered_text(
            n, n_topics=8 if smoke else 64,
            vocab=256 if smoke else 2048, m=16, hmax=32, min_len=20,
            seed=17)
        q_ids, q_w = corpus.ids[:nq], corpus.w[:nq]
        full_spec = CascadeSpec(
            stages=(CascadeStage("wcd", rung["scan"]),
                    CascadeStage("rwmd", rung["prune"])),
            rescorer="act", rescorer_iters=ACT_ITERS)
        entries = []
        t0 = time.perf_counter()
        ref = EmdIndex.build(corpus, EngineConfig(
            method="act", iters=ACT_ITERS, top_l=top_l,
            cascade=full_spec))
        build_ref = time.perf_counter() - t0
        _, ref_idx = ref.search(q_ids, q_w)
        us_ref = timeit(lambda: ref.search(q_ids, q_w), n_iter=reps)
        qps_ref = nq / (us_ref / 1e6)
        emit(f"bench_cascade.sweep.n{n}.full_scan", us_ref,
             f"qps={qps_ref:.1f}")
        entries.append(dict(
            source="full_scan", spec=full_spec.describe(),
            admissible=full_spec.admissible, recall_at_l=1.0,
            top_l=top_l, queries_per_sec=round(qps_ref, 2),
            probed_rows_per_query=n,
            build_seconds=round(build_ref, 2)))
        for key in ("lsh", "tree"):
            src_spec = rung[key]
            spec = CascadeSpec(
                stages=(CascadeStage("rwmd", rung["prune"]),),
                rescorer="act", rescorer_iters=ACT_ITERS,
                source=src_spec)
            t0 = time.perf_counter()
            ix = EmdIndex.build(corpus, EngineConfig(
                method="act", iters=ACT_ITERS, top_l=top_l,
                cascade=spec))
            build_s = time.perf_counter() - t0
            _, idx = ix.search(q_ids, q_w)
            recall = cascade.topk_recall(idx, ref_idx)
            us = timeit(lambda: ix.search(q_ids, q_w), n_iter=reps)
            qps = nq / (us / 1e6)
            emit(f"bench_cascade.sweep.n{n}.{src_spec.kind}", us,
                 f"recall@{top_l}={recall:.3f} qps={qps:.1f} "
                 f"full_qps={qps_ref:.1f}")
            probed = src_spec.probes * ix.source.rows.shape[1]
            entries.append(dict(
                source=src_spec.kind, spec=spec.describe(),
                admissible=spec.admissible,
                recall_at_l=round(recall, 4), top_l=top_l,
                queries_per_sec=round(qps, 2),
                probed_rows_per_query=probed,
                emitted_rows_per_query=ix.source.width,
                dropped_rows=int(ix.source.dropped_rows),
                build_seconds=round(build_s, 2),
                speedup_over_full_scan=round(qps / qps_ref, 2)))
        report["sweep"].append(dict(n=n, nq=nq, entries=entries))


def _precision_sweep(report: dict, corpus, q_ids, q_w, nq: int,
                     top_l: int, reps: int) -> None:
    """The cascade's precision-vs-recall frontier: the acceptance
    cascade at the 5% budget under each precision policy — recall@top_l
    of the policy's retrieved set against the f32 cascade's (delta 0 for
    f32), per-(query, vocab-row) handoff bytes from the storage dtype,
    and measured queries/sec. The reduced policies ride the SAME pruned
    stages and rescorer; only the handoff/table dtypes move."""
    import jax.numpy as jnp

    from repro.core.precision import resolve

    pct = 0.05
    entries = []
    ref_idx = None
    for policy in PRECISION_POLICIES:
        casc = EmdIndex.build(corpus, EngineConfig(
            method="act", iters=ACT_ITERS, top_l=top_l, cascade=_spec(pct),
            precision=policy))
        _, idx = casc.search(q_ids, q_w)
        if ref_idx is None:                          # f32 runs first
            ref_idx = idx
        recall = cascade.topk_recall(idx, ref_idx)
        us = timeit(lambda: casc.search(q_ids, q_w), n_iter=reps)
        qps = nq / (us / 1e6)
        storage = jnp.dtype(resolve(policy).storage)
        emit(f"bench_cascade.precision.{policy}", us,
             f"recall@{top_l}={recall:.4f} qps={qps:.1f}")
        entries.append(dict(
            policy=policy, storage_dtype=storage.name, budget_pct=pct,
            recall_at_l_vs_f32=round(recall, 4),
            recall_delta_vs_f32=round(1.0 - recall, 4),
            handoff_bytes_per_row=storage.itemsize * (2 * ACT_ITERS + 1),
            us_per_call=round(us, 1), queries_per_sec=round(qps, 1)))
    report["precision_sweep"] = dict(budget_pct=pct, nq=nq, top_l=top_l,
                                     entries=entries)


def run() -> None:
    smoke = os.environ.get("BENCH_SMOKE", "0") not in ("0", "")
    sz = _sizes(smoke)
    nq, top_l, reps = sz.pop("nq"), sz.pop("top_l"), sz.pop("reps")
    corpus, _ = text_corpus(**sz, seed=11)
    q_ids, q_w = corpus.ids[:nq], corpus.w[:nq]
    n = corpus.n
    # Tile policy, as in bench_batch: BENCH_TUNE_CACHE applies a
    # TuneCache's winners deterministically; unset keeps the defaults.
    tune_cache = os.environ.get("BENCH_TUNE_CACHE") or None
    autotune = "cached" if tune_cache else "off"
    report = {"bench": "bench_cascade", "smoke": smoke,
              "sizes": dict(sz, nq=nq, top_l=top_l),
              "backend": jax.default_backend(),
              "device_kind": device_kind(),
              "autotune": {"mode": autotune, "tune_cache": tune_cache,
                           "tuned_blocks": {}},
              "full_rows_per_query": n, "entries": []}

    full = EmdIndex.build(corpus, EngineConfig(method="act",
                                               iters=ACT_ITERS,
                                               top_l=top_l))
    _, full_idx = full.search(q_ids, q_w)

    for pct in BUDGETS:
        spec = _spec(pct)
        for use_kernels in (False, True):
            backend = "pallas" if use_kernels else "reference"
            casc = EmdIndex.build(corpus, EngineConfig(
                method="act", iters=ACT_ITERS, top_l=top_l, cascade=spec,
                backend=backend, autotune=autotune, tune_cache=tune_cache))
            if use_kernels:
                report["autotune"]["tuned_blocks"].update(casc.tuned_blocks)
            _, idx = casc.search(q_ids, q_w)
            recall = cascade.topk_recall(idx, full_idx)
            us_full, us_casc, speedup = paired(
                lambda: full.search(q_ids, q_w),
                lambda: casc.search(q_ids, q_w), reps)
            rows = cascade.stage_rows(spec, n, top_l)
            cand_rows = sum(v for k, v in rows.items()
                            if not k.startswith("stage1"))
            qps_casc = nq / (us_casc / 1e6)
            qps_full = nq / (us_full / 1e6)
            tag = ".kernels" if use_kernels else ""
            emit(f"bench_cascade.act.b{int(100 * pct)}pct{tag}", us_casc,
                 f"recall@{top_l}={recall:.3f} qps={qps_casc:.1f} "
                 f"full_qps={qps_full:.1f} speedup={speedup:.2f}x")
            report["entries"].append(dict(
                budget_pct=pct, spec=spec.describe(),
                admissible=spec.admissible, use_kernels=use_kernels,
                recall_at_l=round(recall, 4), top_l=top_l,
                queries_per_sec=round(qps_casc, 1),
                full_queries_per_sec=round(qps_full, 1),
                speedup_over_full=round(speedup, 2),
                rows_scored=rows, candidate_rows_per_query=cand_rows,
                scores_fewer_candidate_rows=bool(cand_rows < n)))

    # Distributed cascade step (single-device mesh: step-latency drift +
    # recall through the shard-blocked top-budget path the host-mesh CI
    # job parity-tests).
    pct = 0.05
    dist = EmdIndex.build(corpus, EngineConfig(
        method="act", iters=ACT_ITERS, top_l=top_l, cascade=_spec(pct),
        backend="distributed", pad_multiple=64))
    _, idx_d = dist.search(q_ids, q_w)
    recall_d = cascade.topk_recall(idx_d, full_idx)
    us = timeit(lambda: dist.search(q_ids, q_w), n_iter=reps)
    qps_d = nq / (us / 1e6)
    emit(f"bench_cascade.act.b{int(100 * pct)}pct.distributed", us,
         f"recall@{top_l}={recall_d:.3f} qps={qps_d:.1f}")
    report["distributed_step"] = dict(
        budget_pct=pct, spec=_spec(pct).describe(),
        recall_at_l=round(recall_d, 4), top_l=top_l,
        queries_per_sec=round(qps_d, 1))

    _precision_sweep(report, corpus, q_ids, q_w, nq, top_l, reps)
    _sweep(report, smoke, top_l)

    path = os.environ.get("BENCH_CASCADE_JSON", "BENCH_cascade.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")


if __name__ == "__main__":
    run()
