"""Cascaded prune-and-rescore throughput and recall vs full-corpus ACT.

The acceptance workload of the cascade subsystem: the ``wcd -> rwmd ->
act`` ladder at rescore budgets {1%, 5%, 20%} of n against full-corpus
LC-ACT scoring of the same query batch — each budget measured on the
reference engines AND on the ``use_kernels`` path (``backend="pallas"``:
fused candidate kernels for the pruned stages + rescorer). For each
entry it reports

* recall@l of the cascade's top-l vs the full ACT top-l,
* end-to-end queries/sec (PAIRED interleaved timing vs full scoring, as
  in ``bench_batch``), and
* the rows-scored ladder — the cascade's pruned stages together read
  strictly fewer candidate rows than the n the full scorer reads.

NOTE on the kernel entries off-TPU: without a TPU the kernels run in
interpret mode, where the in-kernel one-hot gather is emulated as dense
matmuls on the CPU — their queries/sec is a conformance smoke number,
not a perf claim (the MXU gather win is a TPU measurement; see ROADMAP).

Results append to the CSV stream and land in ``BENCH_cascade.json``
(repo root, override with BENCH_CASCADE_JSON) with a distributed-step
entry (the mesh cascade step with its shard-blocked top-budget, on a
single-device mesh here) carrying the same recall + queries/sec fields.
``BENCH_SMOKE=1`` shrinks everything to CI smoke sizes.
"""
from __future__ import annotations

import json
import os

import jax

from benchmarks.common import device_kind, emit, paired, text_corpus, timeit
from repro import cascade
from repro.api import EmdIndex, EngineConfig
from repro.cascade import CascadeSpec, CascadeStage

#: Rescore budgets as fractions of n (the acceptance grid).
BUDGETS = (0.01, 0.05, 0.20)

#: ACT Phase-2 rounds of both the full-corpus baseline and the rescorer.
ACT_ITERS = 3


def _spec(pct: float) -> CascadeSpec:
    """The acceptance cascade at rescore budget ``pct``: wcd prefetch
    keeping 8x the final budget (capped at the full corpus), rwmd prune
    to ``pct``, ACT rescore. The 8x headroom is what the centroid
    heuristic needs to hold >= 0.95 of the true ACT neighbors (rwmd is a
    near-perfect ACT proxy at these budgets; wcd is the lossy stage)."""
    return CascadeSpec(stages=(CascadeStage("wcd", min(8 * pct, 1.0)),
                               CascadeStage("rwmd", pct)),
                       rescorer="act", rescorer_iters=ACT_ITERS)


def _sizes(smoke: bool) -> dict:
    if smoke:
        return dict(n_docs=64, n_classes=4, vocab=192, m=16, doc_len=24,
                    hmax=16, nq=8, top_l=4, reps=3)
    return dict(n_docs=1024, n_classes=8, vocab=512, m=16, doc_len=20,
                hmax=16, nq=64, top_l=16, reps=7)


def run() -> None:
    smoke = os.environ.get("BENCH_SMOKE", "0") not in ("0", "")
    sz = _sizes(smoke)
    nq, top_l, reps = sz.pop("nq"), sz.pop("top_l"), sz.pop("reps")
    corpus, _ = text_corpus(**sz, seed=11)
    q_ids, q_w = corpus.ids[:nq], corpus.w[:nq]
    n = corpus.n
    # Tile policy, as in bench_batch: BENCH_TUNE_CACHE applies a
    # TuneCache's winners deterministically; unset keeps the defaults.
    tune_cache = os.environ.get("BENCH_TUNE_CACHE") or None
    autotune = "cached" if tune_cache else "off"
    report = {"bench": "bench_cascade", "smoke": smoke,
              "sizes": dict(sz, nq=nq, top_l=top_l),
              "backend": jax.default_backend(),
              "device_kind": device_kind(),
              "autotune": {"mode": autotune, "tune_cache": tune_cache,
                           "tuned_blocks": {}},
              "full_rows_per_query": n, "entries": []}

    full = EmdIndex.build(corpus, EngineConfig(method="act",
                                               iters=ACT_ITERS,
                                               top_l=top_l))
    _, full_idx = full.search(q_ids, q_w)

    for pct in BUDGETS:
        spec = _spec(pct)
        for use_kernels in (False, True):
            backend = "pallas" if use_kernels else "reference"
            casc = EmdIndex.build(corpus, EngineConfig(
                method="act", iters=ACT_ITERS, top_l=top_l, cascade=spec,
                backend=backend, autotune=autotune, tune_cache=tune_cache))
            if use_kernels:
                report["autotune"]["tuned_blocks"].update(casc.tuned_blocks)
            _, idx = casc.search(q_ids, q_w)
            recall = cascade.topk_recall(idx, full_idx)
            us_full, us_casc, speedup = paired(
                lambda: full.search(q_ids, q_w),
                lambda: casc.search(q_ids, q_w), reps)
            rows = cascade.stage_rows(spec, n, top_l)
            cand_rows = sum(v for k, v in rows.items()
                            if not k.startswith("stage1"))
            qps_casc = nq / (us_casc / 1e6)
            qps_full = nq / (us_full / 1e6)
            tag = ".kernels" if use_kernels else ""
            emit(f"bench_cascade.act.b{int(100 * pct)}pct{tag}", us_casc,
                 f"recall@{top_l}={recall:.3f} qps={qps_casc:.1f} "
                 f"full_qps={qps_full:.1f} speedup={speedup:.2f}x")
            report["entries"].append(dict(
                budget_pct=pct, spec=spec.describe(),
                admissible=spec.admissible, use_kernels=use_kernels,
                recall_at_l=round(recall, 4), top_l=top_l,
                queries_per_sec=round(qps_casc, 1),
                full_queries_per_sec=round(qps_full, 1),
                speedup_over_full=round(speedup, 2),
                rows_scored=rows, candidate_rows_per_query=cand_rows,
                scores_fewer_candidate_rows=bool(cand_rows < n)))

    # Distributed cascade step (single-device mesh: step-latency drift +
    # recall through the shard-blocked top-budget path the host-mesh CI
    # job parity-tests).
    pct = 0.05
    dist = EmdIndex.build(corpus, EngineConfig(
        method="act", iters=ACT_ITERS, top_l=top_l, cascade=_spec(pct),
        backend="distributed", pad_multiple=64))
    _, idx_d = dist.search(q_ids, q_w)
    recall_d = cascade.topk_recall(idx_d, full_idx)
    us = timeit(lambda: dist.search(q_ids, q_w), n_iter=reps)
    qps_d = nq / (us / 1e6)
    emit(f"bench_cascade.act.b{int(100 * pct)}pct.distributed", us,
         f"recall@{top_l}={recall_d:.3f} qps={qps_d:.1f}")
    report["distributed_step"] = dict(
        budget_pct=pct, spec=_spec(pct).describe(),
        recall_at_l=round(recall_d, 4), top_l=top_l,
        queries_per_sec=round(qps_d, 1))

    path = os.environ.get("BENCH_CASCADE_JSON", "BENCH_cascade.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")


if __name__ == "__main__":
    run()
