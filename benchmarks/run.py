"""Benchmark driver — one module per paper table/figure.

  fig8_tradeoff      Fig. 8(a)  runtime vs accuracy, text (incl. WMD ref)
  sinkhorn_compare   Fig. 8(b)  ACT vs Sinkhorn, images
  table5_mnist       Table 5    sparse image precision@top-l
  table6_dense       Table 6    dense histograms (RWMD collapse)
  table3_complexity  Tables 2/3 empirical linear-scaling check
  kernels_bench      DESIGN 2   kernel traffic/fusion model
  bench_batch        serving    batched vs scanned queries/sec (+ JSON)
  bench_cascade      serving    cascaded prune-and-rescore recall/qps (+ JSON)
  bench_serve        serving    online runtime latency/tier mix vs load (+ JSON)

Each prints ``name,us_per_call,derived`` CSV rows. All retrieval-bench
entry points score through the unified ``repro.api.EmdIndex`` serving API
(``benchmarks.common.build_index``); only kernel microbenches go below it.
Run: PYTHONPATH=src python -m benchmarks.run [--only fig8]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark module names")
    args = ap.parse_args()

    from benchmarks import (bench_batch, bench_cascade, bench_serve,
                            fig8_tradeoff, kernels_bench, sinkhorn_compare,
                            table3_complexity, table5_mnist, table6_dense)
    mods = [table6_dense, table5_mnist, fig8_tradeoff, sinkhorn_compare,
            table3_complexity, kernels_bench, bench_batch, bench_cascade,
            bench_serve]
    print("name,us_per_call,derived")
    failures = 0
    for mod in mods:
        name = mod.__name__.split(".")[-1]
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod.run()
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
