"""Checkpoint store: pytree -> per-leaf .npy shards + JSON manifest.

Design goals (DESIGN.md section 5):
  * restart-safety — the manifest is written LAST and atomically
    (tmp + rename), so a crash mid-save never leaves a "latest" pointer at
    a torn checkpoint;
  * integrity — SHA256 per leaf, verified on restore;
  * elasticity — restore() takes target shardings, so the same checkpoint
    restores onto a different mesh (runtime/elastic.py).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any

import jax
import numpy as np

MANIFEST = "manifest.json"


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                        for e in path)
        out.append((name, leaf))
    return out


def _fname(name: str) -> str:
    return name.replace("/", "__") + ".npy"


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import jax.numpy as jnp
        return np.dtype(getattr(jnp, name))


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None) -> str:
    """Write checkpoint ``step`` under ckpt_dir/step_<n>/; returns the path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves = _leaf_paths(tree)
    manifest: dict[str, Any] = {"step": step, "leaves": {},
                                "extra": extra or {}}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        path = os.path.join(tmp, _fname(name))
        # Store raw bytes: np.save can't round-trip extension dtypes (bf16).
        np.save(path, np.ascontiguousarray(arr).view(np.uint8)
                if arr.ndim else arr.reshape(1).view(np.uint8))
        with open(path, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["leaves"][name] = {
            "file": _fname(name), "shape": list(arr.shape),
            "dtype": str(arr.dtype), "sha256": digest,
        }
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic publish
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d, MANIFEST))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any = None, verify: bool = True) -> Any:
    """Restore into the structure of ``like``. ``shardings``: optional
    matching tree of NamedShardings — THE elastic-rescale hook: pass the new
    mesh's shardings and each leaf lands resharded."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, MANIFEST)) as f:
        manifest = json.load(f)
    names = dict(_leaf_paths(like))
    shard_map_ = dict(_leaf_paths(shardings)) if shardings is not None else {}
    out = {}
    for name in names:
        meta = manifest["leaves"][name]
        path = os.path.join(d, meta["file"])
        if verify:
            with open(path, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            if digest != meta["sha256"]:
                raise IOError(f"checkpoint corruption in {name}: "
                              f"{digest} != {meta['sha256']}")
        raw = np.load(path)
        dtype = _np_dtype(meta["dtype"])
        arr = raw.view(dtype).reshape(meta["shape"])
        if name in shard_map_:
            out[name] = jax.device_put(arr, shard_map_[name])
        else:
            out[name] = jax.numpy.asarray(arr)
    # Rebuild the tree in ``like``'s structure.
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, _ in flat:
        name = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                        for e in path)
        leaves.append(out[name])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_extra(ckpt_dir: str, step: int) -> dict:
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, MANIFEST)) as f:
        return json.load(f)["extra"]
