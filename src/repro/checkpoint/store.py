"""Checkpoint store: pytree -> per-leaf .npy shards + JSON manifest.

Design goals (DESIGN.md section 5):
  * restart-safety — the manifest is written LAST and atomically
    (tmp + rename), so a crash mid-save never leaves a "latest" pointer at
    a torn checkpoint; ``latest_step``/``steps`` additionally re-verify
    that a step directory is *complete* (manifest present, parseable, and
    every leaf file it names on disk), so even a torn directory produced
    by a non-atomic filesystem or a crashed copy is skipped, never served;
  * integrity — SHA256 per leaf, verified on restore; any mismatch (or a
    missing/unreadable file) surfaces as the typed :class:`CheckpointCorrupt`
    so callers can fall back to an older snapshot instead of crashing on a
    bare assertion;
  * elasticity — restore() takes target shardings, so the same checkpoint
    restores onto a different mesh (runtime/elastic.py).
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import os
import shutil
from typing import Any

import jax
import numpy as np

MANIFEST = "manifest.json"


class CheckpointCorrupt(IOError):
    """A checkpoint failed integrity verification: SHA-256 mismatch,
    missing/unreadable leaf file, or missing/partial manifest. Typed so
    recovery paths (``serving/lifecycle.restore_latest``) can skip the
    bad snapshot and fall back to an older one."""


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                        for e in path)
        out.append((name, leaf))
    return out


def _fname(name: str) -> str:
    return name.replace("/", "__") + ".npy"


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import jax.numpy as jnp
        return np.dtype(getattr(jnp, name))


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None) -> str:
    """Write checkpoint ``step`` under ckpt_dir/step_<n>/; returns the path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves = _leaf_paths(tree)
    manifest: dict[str, Any] = {"step": step, "leaves": {},
                                "extra": extra or {}}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        path = os.path.join(tmp, _fname(name))
        # Store raw bytes: np.save can't round-trip extension dtypes (bf16).
        np.save(path, np.ascontiguousarray(arr).view(np.uint8)
                if arr.ndim else arr.reshape(1).view(np.uint8))
        with open(path, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["leaves"][name] = {
            "file": _fname(name), "shape": list(arr.shape),
            "dtype": str(arr.dtype), "sha256": digest,
        }
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic publish
    return final


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def load_manifest(ckpt_dir: str, step: int) -> dict:
    """The parsed manifest of checkpoint ``step``.

    Raises :class:`CheckpointCorrupt` when the manifest is missing or
    partial (a crash mid-save on a filesystem without atomic rename, or a
    truncated copy) — the checkpoint must be treated as torn.
    """
    path = os.path.join(_step_dir(ckpt_dir, step), MANIFEST)
    try:
        with open(path) as f:
            manifest = json.load(f)
    except FileNotFoundError as e:
        raise CheckpointCorrupt(
            f"checkpoint step {step}: manifest missing ({path})") from e
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointCorrupt(
            f"checkpoint step {step}: manifest partial/unparseable "
            f"({path}: {e})") from e
    if not isinstance(manifest, dict) or "leaves" not in manifest:
        raise CheckpointCorrupt(
            f"checkpoint step {step}: manifest has no leaf table ({path})")
    return manifest


def _complete(ckpt_dir: str, step: int) -> bool:
    """True when the step directory holds a parseable manifest AND every
    leaf file the manifest names. Cheap (stat-only — no hashing): the
    completeness gate for ``steps``/``latest_step``; full integrity is
    verified at restore time."""
    try:
        manifest = load_manifest(ckpt_dir, step)
    except CheckpointCorrupt:
        return False
    d = _step_dir(ckpt_dir, step)
    return all(os.path.exists(os.path.join(d, meta["file"]))
               for meta in manifest["leaves"].values())


def steps(ckpt_dir: str) -> list[int]:
    """All COMPLETE checkpoint steps under ``ckpt_dir``, ascending.

    Skips ``.tmp`` staging directories and torn checkpoints (directory
    present but manifest missing/partial, or leaf files absent) — a crash
    at any point mid-save can never surface here.
    """
    if not os.path.isdir(ckpt_dir):
        return []
    found = []
    for d in os.listdir(ckpt_dir):
        if not d.startswith("step_") or d.endswith(".tmp"):
            continue
        with contextlib.suppress(ValueError):
            found.append(int(d.split("_")[1]))
    return sorted(s for s in found if _complete(ckpt_dir, s))


def latest_step(ckpt_dir: str) -> int | None:
    """Newest complete checkpoint step, or None. Provably skips torn
    checkpoints — delegates to :func:`steps`' completeness gate."""
    all_steps = steps(ckpt_dir)
    return all_steps[-1] if all_steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any = None, verify: bool = True) -> Any:
    """Restore into the structure of ``like``. ``shardings``: optional
    matching tree of NamedShardings — THE elastic-rescale hook: pass the new
    mesh's shardings and each leaf lands resharded.

    Integrity failures (SHA-256 mismatch, missing leaf file or manifest)
    raise :class:`CheckpointCorrupt`.
    """
    d = _step_dir(ckpt_dir, step)
    manifest = load_manifest(ckpt_dir, step)
    names = dict(_leaf_paths(like))
    shard_map_ = dict(_leaf_paths(shardings)) if shardings is not None else {}
    out = {}
    for name in names:
        try:
            meta = manifest["leaves"][name]
        except KeyError as e:
            raise CheckpointCorrupt(
                f"checkpoint corruption in {name}: leaf missing from "
                f"manifest at step {step}") from e
        path = os.path.join(d, meta["file"])
        try:
            if verify:
                with open(path, "rb") as f:
                    digest = hashlib.sha256(f.read()).hexdigest()
            raw = np.load(path)
        except (OSError, ValueError) as e:
            # ValueError: np.load on a corrupted/truncated .npy header.
            raise CheckpointCorrupt(
                f"checkpoint corruption in {name}: leaf file unreadable "
                f"({path}: {e})") from e
        if verify and digest != meta["sha256"]:
            raise CheckpointCorrupt(
                f"checkpoint corruption in {name}: "
                f"{digest} != {meta['sha256']}")
        dtype = _np_dtype(meta["dtype"])
        want = getattr(names[name], "dtype", None)
        if want is not None and np.dtype(want) != dtype:
            # A precision-policy index must come back in its stored
            # dtypes — reinterpreting (or casting) here would silently
            # change what the caller serves. Typed so recovery paths
            # treat it like any other snapshot/target disagreement.
            raise CheckpointCorrupt(
                f"checkpoint dtype mismatch in {name}: stored {dtype} "
                f"but restore target expects {np.dtype(want)}; rebuild "
                "the target with the snapshot's dtypes (no silent cast)")
        arr = raw.view(dtype).reshape(meta["shape"])
        if name in shard_map_:
            out[name] = jax.device_put(arr, shard_map_[name])
        else:
            out[name] = jax.numpy.asarray(arr)
    # Rebuild the tree in ``like``'s structure.
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, _ in flat:
        name = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                        for e in path)
        leaves.append(out[name])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_extra(ckpt_dir: str, step: int) -> dict:
    return load_manifest(ckpt_dir, step).get("extra", {})
