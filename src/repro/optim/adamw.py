"""AdamW + warmup-cosine schedule in pure JAX (no optax on this box).

Optimizer state shards exactly like the parameters (same PartitionSpecs),
i.e. ZeRO-style: each device holds only its parameter shard's moments.
``opt_state_dtype`` comes from the model config — bf16 moments for the
341B/141B archs so one pod's HBM holds params+state (DESIGN.md section 4).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    """Linear warmup then cosine decay to min_lr."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params: Params, dtype: str = "float32") -> dict:
    dt = jnp.dtype(dtype)
    zeros = lambda p: jax.tree.map(lambda a: jnp.zeros(a.shape, dt), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Params, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def update(grads: Params, state: dict, params: Params,
           cfg: AdamWConfig) -> tuple[Params, dict, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(step, cfg)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m2.astype(m.dtype), v2.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
