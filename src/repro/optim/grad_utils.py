"""Distributed-optimization utilities: microbatch gradient accumulation and
int8 stochastic-rounding gradient compression (for the cross-pod reduce).
"""
from __future__ import annotations

from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp


def accumulate_grads(loss_fn: Callable, params: Any, batch: Any,
                     n_micro: int) -> tuple[jax.Array, Any]:
    """Gradient accumulation: split the global batch into ``n_micro``
    microbatches along dim 0 and scan, accumulating fp32 grads.

    Keeps activation memory at 1/n_micro of the monolithic step — the knob
    that makes nemotron-4-340b's train_4k cell fit one pod (EXPERIMENTS.md).
    """
    if n_micro <= 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return loss, grads

    micro = jax.tree.map(
        lambda a: a.reshape(n_micro, a.shape[0] // n_micro, *a.shape[1:]),
        batch)

    def body(carry, mb):
        loss_sum, gacc = carry
        loss, grads = jax.value_and_grad(loss_fn)(params, mb)
        gacc = jax.tree.map(lambda acc, g: acc + g.astype(acc.dtype),
                            gacc, grads)
        return (loss_sum + loss, gacc), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, gsum), _ = jax.lax.scan(body, (jnp.float32(0.0), g0), micro)
    inv = 1.0 / n_micro
    return loss_sum * inv, jax.tree.map(lambda g: g * inv, gsum)


# ----------------------------------------------------------------------------
# int8 stochastic-rounding compression (cross-pod gradient reduce)
# ----------------------------------------------------------------------------

def compress_int8(x: jax.Array, key: jax.Array, scale: jax.Array | None = None):
    """x -> (int8 payload, fp32 per-tensor scale). Stochastic rounding keeps
    the quantizer unbiased so accumulated compressed reduces don't drift.
    ``scale`` may be supplied (e.g. a pmax-shared scale for reductions)."""
    xf = x.astype(jnp.float32)
    if scale is None:
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    scaled = xf / scale
    low = jnp.floor(scaled)
    p_up = scaled - low
    rnd = jax.random.uniform(key, x.shape)
    q = low + (rnd < p_up).astype(jnp.float32)
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def decompress_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum_tree(grads: Any, key: jax.Array, axis_name: str) -> Any:
    """Compress -> psum -> decompress over ``axis_name`` (use inside
    shard_map over the 'pod' axis): 4x cross-pod gradient traffic cut at
    <1e-2 relative error (tests/test_optim.py)."""
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = []
    for leaf, k in zip(leaves, keys, strict=True):
        # Share ONE scale across the axis first (scalar pmax — cheap), so the
        # int8 payloads are additive under psum.
        local_max = jnp.maximum(jnp.max(jnp.abs(leaf.astype(jnp.float32))),
                                1e-12)
        scale = jax.lax.pmax(local_max, axis_name) / 127.0
        q, _ = compress_int8(leaf, k, scale=scale)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        out.append((total.astype(jnp.float32) * scale).astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)
