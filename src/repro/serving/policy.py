"""Serving policy: deadlines, retries, and the graceful-degradation ladder.

The bound hierarchy the repo validates statically (RWMD <= OMR <= ACT <=
ICT <= EMD, ``cascade/spec.py``) is what makes degradation *honest*: every
rung of the ladder is a real retrieval configuration with a known quality
relationship to the primary tier, so under overload or partial failure the
server steps DOWN the ladder and labels the response with the tier it
actually served (plus that tier's recall expectation) instead of timing
out or silently serving garbage. Load-shedding (fast-fail with
:class:`ServerOverloaded`) is the final rung.

A ladder rung is one of:

* ``"primary"`` — the index's own configured search (its cascade if the
  ``EngineConfig`` carries one, else full-corpus scoring with its method);
* a cascade preset name (``repro.cascade.CASCADES``) or an explicit
  ``CascadeSpec`` — served through the prune-and-rescore ladder;
* a method name (``repro.core.retrieval.METHODS``) — a full-corpus scan
  with that (cheap) measure, e.g. the ``"wcd"`` centroid-only rung.

The whole ladder is validated against the index configuration BEFORE the
server takes traffic (:func:`validate_ladder`): unknown rungs, cascade
specs whose budgets cannot resolve on the corpus, host-side rescorers on
the distributed backend, and symmetric-scoring conflicts all fail at
construction, never at the moment a fallback is needed.
"""
from __future__ import annotations

import dataclasses

from repro.cascade.spec import CASCADES, CascadeSpec, resolve_spec
from repro.core.retrieval import METHODS


class ServerOverloaded(RuntimeError):
    """The final rung: every tier of the ladder failed (or was shed);
    the request fast-fails instead of hanging past its deadline."""


#: Documented recall expectation (vs the primary tier's own top-l) that a
#: degraded response carries. Admissible cascade presets guarantee exact
#: top-l whenever budgets cover the true neighbors' stage ranks => 1.0;
#: ``fast`` is non-admissible and its number is the measured floor from
#: ``benchmarks/bench_cascade.py`` (>= 0.95 recall@16 at its budgets on
#: the text-like workload). Method rungs have no cascade guarantee at all
#: — ``None`` means "measured only", and ``benchmarks/bench_serve.py``
#: reports the served-tier mix so the quality cost of degradation is
#: always visible.
TIER_RECALL: dict[str, float | None] = {
    "primary": 1.0,
    "exact": 1.0,
    "tight": 1.0,
    "chain": 1.0,
    "fast": 0.95,
}


@dataclasses.dataclass(frozen=True)
class ServingTier:
    """One resolved rung: either a cascade (``cascade`` set) or a plain
    full-corpus method scan (``method`` set) — exactly one of the two,
    except the primary rung, which may be a plain-method primary with
    neither when the index has no cascade configured."""
    name: str
    cascade: CascadeSpec | None = None
    method: str | None = None
    expected_recall: float | None = None

    def __post_init__(self) -> None:
        if self.cascade is not None and self.method is not None:
            raise ValueError(f"tier {self.name!r} sets both cascade and "
                             "method")


def resolve_tier(rung: str | CascadeSpec | ServingTier) -> ServingTier:
    """Rung -> :class:`ServingTier`. Strings resolve against the cascade
    presets first, then the method registry; ``"primary"`` is returned as
    a sentinel tier for the server to bind to the index config."""
    if isinstance(rung, ServingTier):
        return rung
    if isinstance(rung, CascadeSpec):
        return ServingTier(name=rung.describe(), cascade=rung,
                           expected_recall=1.0 if rung.admissible else None)
    if rung == "primary":
        return ServingTier(name="primary", expected_recall=1.0)
    if rung in CASCADES:
        return ServingTier(name=rung, cascade=CASCADES[rung],
                           expected_recall=TIER_RECALL.get(rung))
    if rung in METHODS:
        return ServingTier(name=rung, method=rung,
                           expected_recall=TIER_RECALL.get(rung))
    raise ValueError(
        f"unknown ladder rung {rung!r}: not 'primary', a cascade preset "
        f"({sorted(CASCADES)}), or a method ({sorted(METHODS)})")


@dataclasses.dataclass(frozen=True)
class ServingPolicy:
    """Frozen per-server policy knobs.

    ladder:      degradation rungs, best quality first (see module doc).
                 The first rung is what healthy traffic is served with.
    flush_ms:    deadline trigger of the micro-batch queue — a batch is
                 launched when the OLDEST queued request has waited this
                 long, even if the batch is not full.
    max_batch:   size trigger — a batch launches immediately at this many
                 queued requests. Also the top padding bucket.
    deadline_ms: default per-request deadline; on flush, a request whose
                 remaining budget no longer fits the current tier's
                 latency estimate pulls the whole batch down-ladder
                 (deadline pressure — the batch shares one launch).
    max_retries: device-launch retries (with backoff) per tier before the
                 batch steps down to the next rung.
    backoff_ms:  base of the exponential retry backoff
                 (``backoff_ms * 2**attempt``). Tests set 0.
    headroom:    safety factor on the latency estimate: a tier is
                 considered to fit when ``est * headroom <= remaining``.
    """
    ladder: tuple[str | CascadeSpec | ServingTier, ...] = (
        "primary", "fast", "wcd")
    flush_ms: float = 2.0
    max_batch: int = 32
    deadline_ms: float = 200.0
    max_retries: int = 2
    backoff_ms: float = 1.0
    headroom: float = 1.5

    def __post_init__(self) -> None:
        if not self.ladder:
            raise ValueError("the degradation ladder needs >= 1 rung")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0, "
                             f"got {self.max_retries}")
        if min(self.flush_ms, self.deadline_ms, self.backoff_ms) < 0:
            raise ValueError("flush_ms/deadline_ms/backoff_ms must be >= 0")
        if self.headroom <= 0:
            raise ValueError(f"headroom must be > 0, got {self.headroom}")

    def resolved_ladder(self) -> tuple[ServingTier, ...]:
        return tuple(resolve_tier(r) for r in self.ladder)


def validate_ladder(policy: ServingPolicy, config, n: int,
                    top_l: int) -> tuple[ServingTier, ...]:
    """Resolve and validate every rung of ``policy.ladder`` against an
    index built with ``config`` over ``n`` corpus rows; returns the
    resolved tiers. Raises ``ValueError`` on the first rung that could
    not actually serve — the whole ladder must be servable up front.
    """
    tiers = policy.resolved_ladder()
    names = [t.name for t in tiers]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate ladder rungs: {names}")
    for tier in tiers:
        try:
            _check_tier(tier, config, n, top_l)
        except ValueError as e:
            raise ValueError(
                f"ladder rung {tier.name!r} cannot serve this index: "
                f"{e}") from e
    return tiers


def _check_tier(tier: ServingTier, config, n: int, top_l: int) -> None:
    if tier.cascade is not None:
        if config.symmetric:
            raise ValueError("cascade rungs score directionally but the "
                             "index is configured symmetric=True")
        spec = resolve_spec(tier.cascade)
        spec.check_servable(
            n, top_l, require_jittable=config.backend == "distributed")
    elif tier.method is not None:
        # Method rungs serve the DIRECTIONAL score regardless of the
        # index's symmetric flag (wcd/bow have no reverse direction);
        # that quality change is exactly what the tier label reports.
        if tier.method not in METHODS:
            raise ValueError(f"unknown method {tier.method!r}")
    elif tier.name == "primary":
        if top_l > n:
            raise ValueError(f"top_l={top_l} exceeds corpus size {n}")
    else:
        raise ValueError("tier resolves to neither a cascade nor a method")
