"""``EmdServer``: the async online runtime over a prebuilt ``EmdIndex``.

The batched engines amortize Phase 1 across a query batch, but a live
service receives queries one at a time from concurrent callers — this
module FORMS the batches. Three cooperating pieces:

* **Micro-batching queue** — concurrent ``await server.search(...)``
  calls coalesce into one padded device launch, flushed when the batch
  fills (``policy.max_batch``) OR the oldest request has waited
  ``policy.flush_ms`` (deadline trigger). The query count pads up to the
  next power-of-two bucket so the jit cache sees a small, fixed set of
  shapes and stays warm.
* **Policy layer** — per-request deadlines, bounded retry-with-backoff
  around every device launch, and graceful degradation: on repeated
  launch failure or deadline pressure the batch steps down the
  ``ServingPolicy`` ladder of cascade presets / cheap methods; the
  response carries the tier actually served and its recall expectation.
  Load shedding (``ServerOverloaded``) is the final rung — a fast fail,
  never a silent timeout.
* **Generational index lifecycle** — the corpus and the per-tier built
  indexes live in an immutable ``_Generation``; ``append``/``delete``
  build a new generation and atomically swap the reference, so in-flight
  batches finish on the snapshot they started on (Phase-1 tables are
  row-independent, so a row-block mutation is an array concat, not new
  math). Snapshot/restore and crash recovery live in
  ``serving/lifecycle.py``; deterministic fault injection for tests and
  benchmarks in ``serving/chaos.py``.

Launches run synchronously on the event loop: one host drives one
device/mesh, so overlapping device launches would only contend — while a
launch runs, new arrivals queue up, which is precisely what the
micro-batcher wants.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.api.config import EngineConfig
from repro.api.index import EmdIndex
from repro.core.lc import Corpus
from repro.serving.policy import (ServerOverloaded, ServingPolicy,
                                  ServingTier, validate_ladder)


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """One served request. ``indices`` are EXTERNAL doc ids (stable under
    append/delete), ``tier``/``expected_recall`` label the quality level
    actually served (``degraded`` = below the ladder's first rung), and
    ``generation`` names the corpus snapshot that answered."""
    scores: np.ndarray
    indices: np.ndarray
    tier: str
    expected_recall: float | None
    degraded: bool
    generation: int
    retries: int
    latency_ms: float


@dataclasses.dataclass
class ServerStats:
    """Mutable counters exposed for tests/benchmarks (not thread-safe —
    the server is single-loop by design)."""
    requests: int = 0
    launches: int = 0
    launch_failures: int = 0
    flushes: int = 0
    shed: int = 0
    tier_served: dict = dataclasses.field(default_factory=dict)
    bucket_launches: dict = dataclasses.field(default_factory=dict)
    tier_latency_ms: dict = dataclasses.field(default_factory=dict)

    def count_tier(self, name: str, k: int) -> None:
        self.tier_served[name] = self.tier_served.get(name, 0) + k

    def ewma(self, name: str, ms: float, alpha: float = 0.3) -> None:
        prev = self.tier_latency_ms.get(name)
        self.tier_latency_ms[name] = ms if prev is None else \
            (1 - alpha) * prev + alpha * ms


@dataclasses.dataclass
class _Request:
    q_ids: np.ndarray
    q_w: np.ndarray
    future: asyncio.Future
    t_enqueue: float
    deadline_s: float


@dataclasses.dataclass(frozen=True)
class _BuiltTier:
    tier: ServingTier
    index: EmdIndex
    rank: int                       # position in the ladder (0 = primary)


@dataclasses.dataclass(frozen=True)
class _Generation:
    """Immutable corpus snapshot + the per-tier indexes built over it.
    In-flight batches hold a reference; mutations swap the server's
    pointer to a freshly built generation."""
    gen: int
    corpus: Corpus
    doc_ids: np.ndarray             # (n,) int64 external ids, row-aligned
    tiers: tuple[_BuiltTier, ...]


def _tier_config(config: EngineConfig, tier: ServingTier) -> EngineConfig:
    """The EngineConfig a non-primary rung's index is built with: same
    backend/batch knobs, the rung's cascade or method swapped in."""
    if tier.cascade is not None:
        return dataclasses.replace(config, cascade=tier.cascade,
                                   symmetric=False)
    # Method rung: directional full-corpus scan with the cheap measure.
    return dataclasses.replace(config, method=tier.method, cascade=None,
                               symmetric=False, iters=0)


def _build_generation(gen: int, corpus: Corpus, doc_ids: np.ndarray,
                      config: EngineConfig, tiers: tuple[ServingTier, ...],
                      mesh, reuse_primary: EmdIndex | None) -> _Generation:
    built = []
    for rank, tier in enumerate(tiers):
        if tier.name == "primary":
            index = reuse_primary if reuse_primary is not None else \
                EmdIndex.build(corpus, config, mesh=mesh)
        else:
            index = EmdIndex.build(corpus, _tier_config(config, tier),
                                   mesh=mesh)
        built.append(_BuiltTier(tier=tier, index=index, rank=rank))
    return _Generation(gen=gen, corpus=corpus,
                       doc_ids=np.asarray(doc_ids, np.int64),
                       tiers=tuple(built))


class EmdServer:
    """Async serving runtime over a prebuilt :class:`EmdIndex`.

        index = EmdIndex.build(corpus, EngineConfig(method="act", iters=3))
        server = EmdServer(index, ServingPolicy(max_batch=16, flush_ms=2))
        async with server:
            res = await server.search(q_ids, q_w)     # one (h,) query
        res.scores, res.indices, res.tier, res.generation

    ``launch_hook`` wraps every device-launch attempt (called as
    ``hook(launch_fn, tier, Q_ids, Q_w)``) — the chaos-injection seam.
    """

    def __init__(self, index: EmdIndex, policy: ServingPolicy | None = None,
                 *, launch_hook=None, doc_ids=None, generation: int = 0,
                 next_doc_id: int | None = None,
                 time_fn=time.monotonic) -> None:
        self.policy = policy if policy is not None else ServingPolicy()
        self.config = index.config
        self.stats = ServerStats()
        self._mesh = index.mesh
        self._hook = launch_hook
        self._clock = time_fn
        n = index.corpus.n
        tiers = validate_ladder(self.policy, self.config, n,
                                self.config.top_l)
        if doc_ids is None:
            doc_ids = np.arange(n, dtype=np.int64)
        doc_ids = np.asarray(doc_ids, np.int64)
        if doc_ids.shape != (n,):
            raise ValueError(f"doc_ids shape {doc_ids.shape} != ({n},)")
        self._next_doc_id = int(next_doc_id) if next_doc_id is not None \
            else (int(doc_ids.max()) + 1 if n else 0)
        self._gen = _build_generation(generation, index.corpus, doc_ids,
                                      self.config, tiers, self._mesh,
                                      reuse_primary=index)
        self._pending: list[_Request] = []
        self._arrival = asyncio.Event()
        self._running = False
        self._flusher: asyncio.Task | None = None
        # (tier, bucket) shapes launched at least once: the FIRST launch
        # of a shape jit-compiles, so its wall time is excluded from the
        # tier latency estimate — otherwise one cold start would read as
        # deadline pressure and spuriously degrade the next batches.
        self._warm: set[tuple[str, int]] = set()

    # ------------------------------------------------------------ lifecycle
    @property
    def generation(self) -> int:
        return self._gen.gen

    @property
    def corpus(self) -> Corpus:
        return self._gen.corpus

    @property
    def doc_ids(self) -> np.ndarray:
        return self._gen.doc_ids

    @property
    def tiers(self) -> tuple[ServingTier, ...]:
        return tuple(b.tier for b in self._gen.tiers)

    async def start(self) -> None:
        if self._running:
            return
        self._running = True
        self._flusher = asyncio.get_running_loop().create_task(
            self._flush_loop())

    async def stop(self) -> None:
        """Drain the queue (every queued request is served or shed), then
        stop the flusher."""
        if not self._running:
            return
        self._running = False
        self._arrival.set()
        if self._flusher is not None:
            await self._flusher
            self._flusher = None

    async def __aenter__(self) -> "EmdServer":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -------------------------------------------------------------- serving
    async def search(self, q_ids, q_w, *,
                     deadline_ms: float | None = None) -> ServeResult:
        """Serve one ``(h,)`` query; coalesced with concurrent callers
        into a micro-batched device launch. Raises
        :class:`ServerOverloaded` when every ladder rung failed (load
        shedding) and ``RuntimeError`` if the server is not started."""
        if not self._running:
            raise RuntimeError("EmdServer is not running; use "
                               "'async with server:' or await start()")
        q_ids = np.asarray(q_ids)
        q_w = np.asarray(q_w)
        if q_ids.ndim != 1 or q_ids.shape != q_w.shape:
            raise ValueError(
                f"EmdServer.search takes one (h,) query per call, got ids "
                f"{q_ids.shape} / w {q_w.shape} (batching is the queue's "
                "job)")
        deadline = (self.policy.deadline_ms if deadline_ms is None
                    else deadline_ms) / 1e3
        req = _Request(q_ids=q_ids, q_w=q_w,
                       future=asyncio.get_running_loop().create_future(),
                       t_enqueue=self._clock(), deadline_s=deadline)
        self.stats.requests += 1
        self._pending.append(req)
        self._arrival.set()
        return await req.future

    async def _flush_loop(self) -> None:
        flush_s = self.policy.flush_ms / 1e3
        while True:
            if not self._pending:
                if not self._running:
                    return
                self._arrival.clear()
                if self._pending:        # arrival raced the clear
                    continue
                await self._arrival.wait()
                continue
            # Fill-or-deadline: wait for more arrivals until the batch is
            # full or the oldest request has waited flush_ms.
            while (self._running
                   and len(self._pending) < self.policy.max_batch):
                remaining = flush_s - (self._clock()
                                       - self._pending[0].t_enqueue)
                if remaining <= 0:
                    break
                self._arrival.clear()
                try:
                    await asyncio.wait_for(self._arrival.wait(), remaining)
                except (asyncio.TimeoutError, TimeoutError):
                    break
            batch = self._pending[:self.policy.max_batch]
            del self._pending[:len(batch)]
            await self._serve_batch(batch)

    def _bucket(self, nq: int) -> int:
        """Next power-of-two >= nq, capped at max_batch — the padded
        query count of the launch, so the jit cache sees O(log max_batch)
        distinct shapes."""
        b = 1
        while b < nq:
            b <<= 1
        return min(b, self.policy.max_batch)

    def _start_rank(self, gen: _Generation, batch: list[_Request]) -> int:
        """Deadline pressure: the rung the batch starts at — the first
        tier whose latency estimate (when known) fits the TIGHTEST
        remaining deadline in the batch with headroom. The batch shares
        one launch, so the most-pressured request decides."""
        now = self._clock()
        tightest = min(r.deadline_s - (now - r.t_enqueue) for r in batch)
        for built in gen.tiers:
            est = self.stats.tier_latency_ms.get(built.tier.name)
            if est is None or est / 1e3 * self.policy.headroom <= tightest:
                return built.rank
        return len(gen.tiers) - 1

    def _raw_launch(self, built: _BuiltTier, Q_ids, Q_w):
        scores, idx = built.index.search(jnp.asarray(Q_ids),
                                         jnp.asarray(Q_w))
        return np.asarray(scores), np.asarray(idx)

    async def _serve_batch(self, batch: list[_Request]) -> None:
        gen = self._gen                      # snapshot: mutations swap it
        self.stats.flushes += 1
        nq = len(batch)
        bucket = self._bucket(nq)
        hmax = gen.corpus.hmax
        Q_ids = np.zeros((bucket, hmax), np.int32)
        Q_w = np.zeros((bucket, hmax), np.float32)
        for i, r in enumerate(batch):
            h = min(r.q_ids.shape[0], hmax)
            Q_ids[i, :h] = r.q_ids[:h]
            Q_w[i, :h] = r.q_w[:h]
        self.stats.bucket_launches[bucket] = \
            self.stats.bucket_launches.get(bucket, 0) + 1

        start = self._start_rank(gen, batch)
        retries = 0
        for built in gen.tiers[start:]:
            # The hook contract sees the ServingTier (its name labels the
            # rung); the built index rides along in the closure.
            def launch(tier, q_ids, q_w, _built=built):
                return self._raw_launch(_built, q_ids, q_w)

            for attempt in range(self.policy.max_retries + 1):
                try:
                    t0 = time.perf_counter()
                    self.stats.launches += 1
                    if self._hook is not None:
                        scores, idx = self._hook(launch, built.tier,
                                                 Q_ids, Q_w)
                    else:
                        scores, idx = self._raw_launch(built, Q_ids, Q_w)
                    dt_ms = (time.perf_counter() - t0) * 1e3
                except Exception:
                    self.stats.launch_failures += 1
                    retries += 1
                    if attempt < self.policy.max_retries:
                        await asyncio.sleep(
                            self.policy.backoff_ms * 2 ** attempt / 1e3)
                    continue
                if (built.tier.name, bucket) in self._warm:
                    self.stats.ewma(built.tier.name, dt_ms)
                else:
                    self._warm.add((built.tier.name, bucket))
                self.stats.count_tier(built.tier.name, nq)
                self._resolve(batch, gen, built, scores, idx,
                              retries=retries)
                return
        # Ladder exhausted: shed (fast-fail, the final rung).
        self.stats.shed += nq
        for r in batch:
            if not r.future.done():
                r.future.set_exception(ServerOverloaded(
                    f"all {len(gen.tiers[start:])} ladder rung(s) failed "
                    f"after {retries} launch failure(s)"))

    def _resolve(self, batch, gen: _Generation, built: _BuiltTier,
                 scores: np.ndarray, idx: np.ndarray, *,
                 retries: int) -> None:
        now = self._clock()
        ext = gen.doc_ids[idx]               # internal row -> external id
        for i, r in enumerate(batch):
            if r.future.done():              # e.g. caller cancelled
                continue
            r.future.set_result(ServeResult(
                scores=scores[i], indices=ext[i],
                tier=built.tier.name,
                expected_recall=built.tier.expected_recall,
                degraded=built.rank > 0,
                generation=gen.gen, retries=retries,
                latency_ms=(now - r.t_enqueue) * 1e3))

    # ------------------------------------------------- corpus mutation
    def append(self, ids, w) -> np.ndarray:
        """Append document rows (``(k, hmax)`` ids/weights) as a new
        generation; returns the external doc ids assigned. In-flight
        batches finish on the previous snapshot; the next flush serves
        the new one."""
        gen = self._gen
        ids = np.asarray(ids, np.int32)
        w = np.asarray(w, np.float32)
        if ids.ndim != 2 or ids.shape != w.shape \
                or ids.shape[1] != gen.corpus.hmax:
            raise ValueError(
                f"append takes (k, hmax={gen.corpus.hmax}) rows, got ids "
                f"{ids.shape} / w {w.shape}")
        if ids.size and int(ids.max()) >= gen.corpus.v:
            raise ValueError("append row ids exceed the vocabulary "
                             f"({int(ids.max())} >= {gen.corpus.v})")
        k = ids.shape[0]
        new_ids = np.arange(self._next_doc_id, self._next_doc_id + k,
                            dtype=np.int64)
        self._next_doc_id += k
        corpus = Corpus(
            ids=jnp.concatenate([jnp.asarray(gen.corpus.ids),
                                 jnp.asarray(ids)]),
            w=jnp.concatenate([jnp.asarray(gen.corpus.w), jnp.asarray(w)]),
            coords=gen.corpus.coords)
        self._swap(corpus, np.concatenate([gen.doc_ids, new_ids]))
        return new_ids

    def delete(self, doc_ids) -> int:
        """Delete documents by EXTERNAL id (row-block removal — Phase-1
        tables are row-independent); returns rows removed. Surviving
        documents keep their external ids. Unknown ids are an error: a
        delete that silently no-ops would hide a lost mutation."""
        gen = self._gen
        drop = np.asarray(doc_ids, np.int64).ravel()
        missing = np.setdiff1d(drop, gen.doc_ids)
        if missing.size:
            raise KeyError(f"unknown doc ids: {missing.tolist()}")
        keep = ~np.isin(gen.doc_ids, drop)
        if int(keep.sum()) < self.config.top_l:
            raise ValueError(
                f"delete would leave {int(keep.sum())} rows < "
                f"top_l={self.config.top_l}")
        corpus = Corpus(ids=jnp.asarray(np.asarray(gen.corpus.ids)[keep]),
                        w=jnp.asarray(np.asarray(gen.corpus.w)[keep]),
                        coords=gen.corpus.coords)
        self._swap(corpus, gen.doc_ids[keep])
        return int((~keep).sum())

    def reshard(self, new_mesh) -> None:
        """Recovery on mesh change (distributed backend): rebuild every
        tier's jitted step and table placement on the surviving mesh as a
        new generation — in-flight batches finish on the old mesh's
        snapshot. Single-host backends ignore the mesh."""
        self._mesh = new_mesh
        self._swap(self._gen.corpus, self._gen.doc_ids)

    def _swap(self, corpus: Corpus, doc_ids: np.ndarray) -> None:
        gen = self._gen
        tiers = tuple(b.tier for b in gen.tiers)
        self._gen = _build_generation(gen.gen + 1, corpus, doc_ids,
                                      self.config, tiers, self._mesh,
                                      reuse_primary=None)
