"""Fault-tolerant online serving runtime over :class:`~repro.api.EmdIndex`.

From batch library to live service: ``EmdServer`` forms device batches
out of concurrent single-query callers (micro-batching queue), survives
launch failures and deadline pressure by honestly degrading down a
validated ladder of cascade presets (``ServingPolicy``), and keeps the
index crash-safe through generational snapshot/restore
(``serving.lifecycle``) with deterministic chaos injection for tests and
benchmarks (``serving.chaos``).

    from repro.serving import EmdServer, ServingPolicy
    server = EmdServer(index, ServingPolicy(ladder=("primary", "fast",
                                                    "wcd")))
    async with server:
        res = await server.search(q_ids, q_w)
    print(res.tier, res.expected_recall, res.indices)
"""
from repro.serving.chaos import (ChaosInjector, ChaosSchedule,
                                 FaultInjected, corrupt_checkpoint)
from repro.serving.lifecycle import (RestoredSnapshot, restore_latest,
                                     restore_server, restore_snapshot,
                                     snapshot)
from repro.serving.policy import (TIER_RECALL, ServerOverloaded,
                                  ServingPolicy, ServingTier, resolve_tier,
                                  validate_ladder)
from repro.serving.server import EmdServer, ServeResult, ServerStats

__all__ = [
    "TIER_RECALL", "ChaosInjector", "ChaosSchedule", "EmdServer",
    "FaultInjected", "RestoredSnapshot", "ServeResult", "ServerOverloaded",
    "ServerStats", "ServingPolicy", "ServingTier", "corrupt_checkpoint",
    "resolve_tier", "restore_latest", "restore_server", "restore_snapshot",
    "snapshot", "validate_ladder",
]
