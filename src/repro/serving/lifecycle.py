"""Crash-safe index lifecycle: snapshot / restore / recover.

A serving snapshot is one checkpoint step written through
``checkpoint/store``'s atomic manifest protocol (tmp + rename, SHA-256
per leaf), keyed by the server's GENERATION counter, holding:

* the Phase-1 tables — the padded dense-bucket corpus (``ids``, ``w``,
  ``coords``); nothing else is needed to rebuild every engine, because
  all per-tier state (jitted steps, shardings) is derived at build time;
* the corpus manifest — the external ``doc_ids`` row map and the next
  id to assign, so append/delete history survives a restart;
* the frozen ``EngineConfig`` (cascade spec included), JSON-encoded in
  the checkpoint's ``extra`` block.

``restore_server`` rebuilds a serving runtime from the newest snapshot
that passes integrity verification — a corrupt or torn newest snapshot
(``store.CheckpointCorrupt``) falls back to the previous generation
instead of refusing to serve. Passing ``mesh=`` restores onto a
DIFFERENT device mesh (recovery after losing part of the machine): the
tables are stored unsharded, so a mesh change is a pure rebuild, the
same property ``runtime/elastic.py`` gives training checkpoints.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.api.config import EngineConfig
from repro.api.index import EmdIndex
from repro.candidates import SOURCES, SourceSpec
from repro.cascade.spec import CascadeSpec, CascadeStage
from repro.checkpoint import store
from repro.checkpoint.store import CheckpointCorrupt
from repro.core.lc import Corpus
from repro.serving.policy import ServingPolicy
from repro.serving.server import EmdServer

#: Leaf names of a serving snapshot (the ``like`` tree for store.restore
#: is reconstructed from the manifest, so restore needs no prior shapes).
SNAPSHOT_LEAVES = ("ids", "w", "coords", "doc_ids")


# ------------------------------------------------------------- config codec
def config_to_dict(config: EngineConfig) -> dict:
    """JSON-encodable dict round-tripping through
    :func:`config_from_dict` (CascadeSpec encoded structurally; preset
    names stay strings)."""
    d = {f.name: getattr(config, f.name)
         for f in dataclasses.fields(config)}
    c = d["cascade"]
    if isinstance(c, CascadeSpec):
        source = None
        if isinstance(c.source, SourceSpec):
            source = dict(kind=c.source.kind,
                          **dataclasses.asdict(c.source))
        d["cascade"] = {
            "stages": [{"method": s.method, "budget": s.budget,
                        "iters": s.iters} for s in c.stages],
            "rescorer": c.rescorer,
            "rescorer_iters": c.rescorer_iters,
            "source": source,
        }
    return d


def config_from_dict(d: dict) -> EngineConfig:
    d = dict(d)
    c = d.get("cascade")
    if isinstance(c, dict):
        source = c.get("source")
        if isinstance(source, dict):
            source = dict(source)
            source = SOURCES[source.pop("kind")](**source)
        d["cascade"] = CascadeSpec(
            stages=tuple(CascadeStage(**s) for s in c["stages"]),
            rescorer=c["rescorer"],
            rescorer_iters=c["rescorer_iters"],
            source=source)
    return EngineConfig(**d)


# ---------------------------------------------------------------- snapshot
def snapshot(server: EmdServer, ckpt_dir: str) -> str:
    """Write the server's CURRENT generation as checkpoint step
    ``generation`` under ``ckpt_dir``; returns the snapshot path.
    Atomic: a crash mid-save leaves the previous snapshot live."""
    gen = server._gen
    tree = {"ids": gen.corpus.ids, "w": gen.corpus.w,
            "coords": gen.corpus.coords, "doc_ids": gen.doc_ids}
    # The primary tier's built candidate-source state checkpoints too:
    # restore then skips the host-side index fit (and byte-identical
    # state survives even a seed-behavior change across versions).
    source_leaves = 0
    primary = next((t.index for t in gen.tiers
                    if t.tier.name == "primary"), None)
    if primary is not None and primary.source is not None:
        import jax
        leaves = jax.tree_util.tree_leaves(primary.source)
        for i, leaf in enumerate(leaves):
            tree[f"source/{i}"] = np.asarray(leaf)
        source_leaves = len(leaves)
    extra = {
        "kind": "emd-serving-snapshot",
        "generation": gen.gen,
        "next_doc_id": server._next_doc_id,
        "config": config_to_dict(server.config),
        "corpus_manifest": {"n": gen.corpus.n, "hmax": gen.corpus.hmax,
                            "v": gen.corpus.v, "m": gen.corpus.m},
        "source_leaves": source_leaves,
    }
    return store.save(ckpt_dir, gen.gen, tree, extra=extra)


@dataclasses.dataclass(frozen=True)
class RestoredSnapshot:
    """One verified snapshot, ready to build a server from."""
    corpus: Corpus
    doc_ids: np.ndarray
    config: EngineConfig
    generation: int
    next_doc_id: int
    #: The built candidate-source (stage-1 index) checkpointed with the
    #: primary tier, ``None`` for unsourced configs — feed it to
    #: ``EmdIndex.build(source=...)`` so restore skips the host-side fit.
    source: Any = None


def _like_from_manifest(manifest: dict) -> dict[str, Any]:
    like = {}
    n_src = int(manifest.get("extra", {}).get("source_leaves", 0))
    names = SNAPSHOT_LEAVES + tuple(f"source/{i}" for i in range(n_src))
    for name in names:
        try:
            meta = manifest["leaves"][name]
        except KeyError as e:
            raise CheckpointCorrupt(
                f"serving snapshot missing leaf {name!r}") from e
        # store._np_dtype, not np.dtype: extension dtypes ("bfloat16")
        # raise TypeError under plain np.dtype, and a bf16-policy
        # snapshot must restore in its stored dtypes.
        like[name] = np.zeros(tuple(meta["shape"]),
                              dtype=store._np_dtype(meta["dtype"]))
    return like


def restore_snapshot(ckpt_dir: str,
                     generation: int | None = None) -> RestoredSnapshot:
    """Load + verify snapshot ``generation`` (default: newest complete).
    Raises :class:`~repro.checkpoint.store.CheckpointCorrupt` on torn or
    corrupt data — see :func:`restore_latest` for the falling-back
    variant."""
    if generation is None:
        generation = store.latest_step(ckpt_dir)
        if generation is None:
            raise FileNotFoundError(
                f"no complete serving snapshot under {ckpt_dir}")
    manifest = store.load_manifest(ckpt_dir, generation)
    extra = manifest.get("extra", {})
    if extra.get("kind") != "emd-serving-snapshot":
        raise CheckpointCorrupt(
            f"step {generation} under {ckpt_dir} is not a serving "
            f"snapshot (kind={extra.get('kind')!r})")
    tree = store.restore(ckpt_dir, generation,
                         _like_from_manifest(manifest))
    config = config_from_dict(extra["config"])
    source = None
    n_src = int(extra.get("source_leaves", 0))
    if n_src:
        src_spec = config.source_spec
        if src_spec is None:
            raise CheckpointCorrupt(
                f"step {generation} carries {n_src} candidate-source "
                "leaves but its config declares no source")
        source = src_spec.wrap(tuple(tree[f"source/{i}"]
                                     for i in range(n_src)))
    return RestoredSnapshot(
        corpus=Corpus(ids=tree["ids"], w=tree["w"], coords=tree["coords"]),
        doc_ids=np.asarray(tree["doc_ids"], np.int64),
        config=config,
        generation=generation,
        next_doc_id=int(extra["next_doc_id"]),
        source=source)


def restore_latest(ckpt_dir: str) -> RestoredSnapshot:
    """Newest snapshot that passes FULL integrity verification, walking
    backwards over generations past any corrupt/torn ones (the
    kill-and-restore path: a crash mid-save, or chaos-injected
    corruption, costs at most the mutations since the previous
    snapshot)."""
    failures = []
    for generation in reversed(store.steps(ckpt_dir)):
        try:
            return restore_snapshot(ckpt_dir, generation)
        except CheckpointCorrupt as e:
            failures.append(f"gen {generation}: {e}")
    raise CheckpointCorrupt(
        f"no intact serving snapshot under {ckpt_dir}"
        + (": " + "; ".join(failures) if failures else ""))


def restore_server(ckpt_dir: str, policy: ServingPolicy | None = None, *,
                   generation: int | None = None, mesh=None,
                   launch_hook=None) -> EmdServer:
    """Snapshot -> running-ready :class:`EmdServer` (caller still
    ``await start()``s it). ``generation=None`` takes the newest INTACT
    snapshot (corrupt ones skipped); ``mesh`` rebuilds the distributed
    backend's steps on a different mesh (recovery on mesh change)."""
    snap = (restore_latest(ckpt_dir) if generation is None
            else restore_snapshot(ckpt_dir, generation))
    index = EmdIndex.build(snap.corpus, snap.config, mesh=mesh,
                           source=snap.source)
    return EmdServer(index, policy, launch_hook=launch_hook,
                     doc_ids=snap.doc_ids, generation=snap.generation,
                     next_doc_id=snap.next_doc_id)
