"""Deterministic chaos injection for the serving runtime.

One seeded :class:`ChaosSchedule` describes every fault up front — which
device-launch attempts raise, which are slowed by an injected straggler
delay, and which checkpoint leaves get corrupted — so a chaos run is a
pure function of (schedule, traffic): tests assert exact tier sequences
and bit-identical results, and re-running the same schedule reproduces
the same served-tier mix (the acceptance criterion's "all deterministic
under fixed seeds").

:class:`ChaosInjector` is the live half: it plugs into
``EmdServer(launch_hook=...)`` and counts every launch ATTEMPT (retries
included), raising :class:`FaultInjected` or sleeping per the schedule.
``corrupt_checkpoint`` flips bytes in a saved snapshot's leaf files so
restore-path tests exercise the typed ``CheckpointCorrupt`` fallback.

Used by ``tests/test_serving.py`` and ``benchmarks/bench_serve.py`` — the
same schedules, so the benchmark's chaos entry measures exactly what the
tests prove correct.
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np


class FaultInjected(RuntimeError):
    """The injected launch failure (stands in for a device launch error /
    lost node; the server's retry + degradation path treats it like any
    other launch exception)."""


@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    """Faults keyed by global launch-attempt index (0-based, counted
    across ALL tiers and retries in arrival order).

    fail_launches:  attempt indices that raise :class:`FaultInjected`.
    delay_launches: attempt index -> injected latency in seconds (a
                    straggler: the launch succeeds but slowly, which
                    feeds the server's tier-latency estimate and can
                    trigger deadline-pressure degradation).
    corrupt_leaves: leaf names to corrupt in ``corrupt_checkpoint``.
    seed:           the generating seed (bookkeeping only).
    """
    fail_launches: frozenset[int] = frozenset()
    delay_launches: tuple[tuple[int, float], ...] = ()
    corrupt_leaves: tuple[str, ...] = ()
    seed: int | None = None

    @classmethod
    def from_seed(cls, seed: int, horizon: int, p_fail: float = 0.1,
                  p_delay: float = 0.0,
                  delay_s: float = 0.05) -> "ChaosSchedule":
        """Bernoulli fail/delay draws per attempt over ``horizon``
        attempts — same seed, same schedule, byte for byte."""
        rng = np.random.default_rng(seed)
        draws = rng.random((horizon, 2))
        fails = frozenset(int(i) for i in np.nonzero(
            draws[:, 0] < p_fail)[0])
        delays = tuple((int(i), delay_s) for i in np.nonzero(
            (draws[:, 1] < p_delay))[0] if int(i) not in fails)
        return cls(fail_launches=fails, delay_launches=delays, seed=seed)


class ChaosInjector:
    """Launch hook executing a :class:`ChaosSchedule`.

    Contract (``EmdServer`` launch_hook): called as
    ``hook(launch_fn, tier, q_ids, q_w)`` for every attempt; must either
    return ``launch_fn(tier, q_ids, q_w)`` or raise. Keeps a log of
    (attempt index, tier name, outcome) for assertions.
    """

    def __init__(self, schedule: ChaosSchedule,
                 sleep_fn=time.sleep) -> None:
        self.schedule = schedule
        self.attempts = 0
        self.log: list[tuple[int, str, str]] = []
        self._delays = dict(schedule.delay_launches)
        self._sleep = sleep_fn

    def __call__(self, launch_fn, tier, q_ids, q_w):
        i = self.attempts
        self.attempts += 1
        if i in self.schedule.fail_launches:
            self.log.append((i, tier.name, "fail"))
            raise FaultInjected(f"injected launch failure #{i} "
                                f"(tier {tier.name})")
        if i in self._delays:
            self.log.append((i, tier.name, "delay"))
            self._sleep(self._delays[i])
        else:
            self.log.append((i, tier.name, "ok"))
        return launch_fn(tier, q_ids, q_w)


def corrupt_checkpoint(ckpt_path: str, leaves: tuple[str, ...] = (),
                       seed: int = 0) -> list[str]:
    """Flip one byte in each named leaf file of a saved checkpoint
    directory (every ``.npy`` when ``leaves`` is empty); returns the
    files touched. The manifest is left intact — exactly the corruption
    SHA-256 verification exists to catch (``store.CheckpointCorrupt``).
    """
    rng = np.random.default_rng(seed)
    names = leaves or tuple(sorted(
        f for f in os.listdir(ckpt_path) if f.endswith(".npy")))
    touched = []
    for name in names:
        fname = name if name.endswith(".npy") else name + ".npy"
        path = os.path.join(ckpt_path, fname)
        with open(path, "r+b") as f:
            data = bytearray(f.read())
            pos = int(rng.integers(0, len(data)))
            data[pos] ^= 0xFF
            f.seek(0)
            f.write(data)
        touched.append(path)
    return touched
