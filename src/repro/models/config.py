"""Model/architecture configuration schema and the assigned input shapes.

One ``ModelConfig`` per assigned architecture lives in ``repro/configs/``.
The config is the single source of truth for model construction
(``models/model.py``), sharding rules (``sharding/rules.py``), input specs
(``launch/dryrun.py``) and smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int          # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int             # dense MLP hidden (or per-expert hidden for MoE)
    vocab: int
    head_dim: int = 0     # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # Pack each expert's FFN into this many column slices so the packed
    # expert dim (n_experts * moe_ff_shards) matches the TP axis when
    # n_experts alone doesn't divide it (mixtral: 8 experts x 2 -> 16).
    # The combine is a cheap pairwise partial sum. 1 = plain layout.
    moe_ff_shards: int = 1
    # True: explicit shard_map expert parallelism — dispatch/compute/combine
    # run rank-local over the "model" axis with ONE activation psum per
    # layer, instead of letting SPMD reshard the (G,E,C,d) tensors
    # (EXPERIMENTS.md section Perf, mixtral iterations).
    moe_shard_map: bool = False

    # --- attention pattern ---
    sliding_window: int = 0          # >0: local window size for local layers
    local_global_ratio: int = 0      # gemma3: 5 => 5 local then 1 global
    rope_theta: float = 10_000.0
    mrope: bool = False              # qwen2-vl M-RoPE (sectioned rotary)

    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    hybrid_attn_every: int = 0       # zamba2: shared attn block every N layers

    # --- MLP / norm flavor ---
    mlp: Literal["swiglu", "relu2", "gelu"] = "swiglu"
    norm: Literal["rmsnorm", "nonparametric"] = "rmsnorm"
    tie_embeddings: bool = False

    # --- modality frontend (audio/vlm): stubbed, inputs are embeddings ---
    frontend: Literal["none", "audio_frames", "vision_patches"] = "none"

    # --- numerics / training ---
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"   # bf16 for the very largest archs
    remat: bool = True
    # "full"  — recompute everything in backward (min memory, 8ND FLOPs)
    # "dots"  — save matmul outputs, recompute element-wise only (~6ND)
    remat_policy: str = "full"

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Analytic parameter count (cross-checked against init in tests)."""
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        total = self.vocab * d                       # embed
        if not self.tie_embeddings:
            total += self.vocab * d                  # lm head
        n_attn = self._n_attn_layers()
        n_ssm = self._n_ssm_layers()
        hd = self.head_dim
        attn = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                + self.n_heads * hd * d) if self.n_heads else 0
        if self.is_moe:
            mlp_mult = 3 if self.mlp == "swiglu" else 2
            mlp = self.n_experts * mlp_mult * d * ff + d * self.n_experts
            total += L * (attn + mlp + 2 * self._norm_params())
        elif self.family == "ssm":
            total += L * (self._ssm_params() + self._norm_params())
        elif self.family == "hybrid":
            total += n_ssm * (self._ssm_params() + self._norm_params())
            # one shared attn+MLP block (weight-tied across its call sites)
            mlp_mult = 3 if self.mlp == "swiglu" else 2
            total += attn + mlp_mult * d * ff + 2 * self._norm_params()
        else:
            mlp_mult = 3 if self.mlp == "swiglu" else 2
            mlp = mlp_mult * d * ff
            total += n_attn * (attn + mlp + 2 * self._norm_params())
        total += self._norm_params()                 # final norm
        return total

    def _norm_params(self) -> int:
        return 0 if self.norm == "nonparametric" else self.d_model

    def _n_attn_layers(self) -> int:
        if self.family == "ssm":
            return 0
        return self.n_layers

    def _n_ssm_layers(self) -> int:
        if self.family == "ssm":
            return self.n_layers
        if self.family == "hybrid":
            return self.n_layers
        return 0

    def _ssm_params(self) -> int:
        d = self.d_model
        d_in = self.ssm_expand * d
        h = d_in // self.ssm_head_dim
        ng = 1
        conv_dim = d_in + 2 * ng * self.ssm_state
        in_proj = d * (2 * d_in + 2 * ng * self.ssm_state + h)
        conv = conv_dim * self.ssm_conv_width + conv_dim
        extra = 3 * h                                # A_log, dt_bias, D
        norm = d_in
        out = d_in * d
        return in_proj + conv + extra + norm + out


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned (shape-id -> step kind) cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

#: Archs for which long_500k is runnable (sub-quadratic long-context path).
#: Pure full-attention archs skip it (see DESIGN.md section 6).
LONG_CONTEXT_ARCHS = frozenset({"mamba2-2.7b", "zamba2-2.7b", "gemma3-27b"})


def cells_for(arch_name: str) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch_name in LONG_CONTEXT_ARCHS:
        out.append("long_500k")
    return out
