"""Mamba2 (SSD — state-space duality) blocks, training + decode paths.

Chunked SSD algorithm (Dao & Gu 2024): the sequence is split into chunks;
within-chunk interactions are an attention-like masked matmul (MXU-friendly),
cross-chunk interactions flow through a scanned per-chunk state recurrence.
Decode is the O(1)-per-token recurrent update on (B, H, P, N) state.

Used by ``mamba2-2.7b`` (pure SSM) and ``zamba2-2.7b`` (hybrid, with a shared
attention block interleaved by models/model.py).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Array = jax.Array
Params = dict[str, Any]


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    heads = d_in // cfg.ssm_head_dim
    n = cfg.ssm_state
    conv_dim = d_in + 2 * n                    # x, B, C share the conv
    return d_in, heads, n, conv_dim


def ssm_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_in, heads, n, conv_dim = _dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    proj_out = 2 * d_in + 2 * n + heads        # z, x, B, C, dt
    return {
        "in_proj": (jax.random.normal(k1, (d, proj_out)) * d ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv_width, conv_dim))
                   * cfg.ssm_conv_width ** -0.5).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, heads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "d_skip": jnp.ones((heads,), jnp.float32),
        "norm": jnp.ones((d_in,), dt),
        "out_proj": (jax.random.normal(k3, (d_in, d)) * d_in ** -0.5).astype(dt),
    }


def _split_proj(zxbcdt: Array, cfg: ModelConfig):
    d_in, heads, n, _ = _dims(cfg)
    z, xs, B, C, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    return z, xs, B, C, dt


def _conv_full(xbc: Array, params: Params, cfg: ModelConfig) -> Array:
    """Causal depthwise conv over (B, S, conv_dim)."""
    w = params["conv_w"].astype(jnp.float32)           # (kw, conv_dim)
    kw = w.shape[0]
    x = xbc.astype(jnp.float32)
    x = jnp.pad(x, ((0, 0), (kw - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        x, w[:, None, :],                              # (kw, 1, conv_dim)
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=xbc.shape[-1])
    return jax.nn.silu(out + params["conv_b"].astype(jnp.float32)).astype(xbc.dtype)


def _ssd_chunked(x: Array, dt: Array, a: Array, B: Array, C: Array,
                 chunk: int):
    """Chunked SSD. x: (b,s,h,p); dt: (b,s,h) (>0); a: (h,) (<0);
    B, C: (b,s,n) (single group, broadcast over heads).
    Returns y: (b,s,h,p)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc, cl = s // chunk, chunk

    xr = x.reshape(b, nc, cl, h, p)
    dtr = dt.reshape(b, nc, cl, h)
    Br = B.reshape(b, nc, cl, n)
    Cr = C.reshape(b, nc, cl, n)
    dA = dtr * a                                        # (b,nc,cl,h) negative
    dA_cs = jnp.cumsum(dA, axis=2)                      # within-chunk cumsum
    xdt = xr * dtr[..., None]

    # --- diagonal (within-chunk) term: attention-like masked matmul ---
    cb = jnp.einsum("bzin,bzjn->bzij", Cr, Br)          # (b,nc,cl,cl)
    li = dA_cs[:, :, :, None, :]                        # i index -> axis 2
    lj = dA_cs[:, :, None, :, :]                        # j index
    decay = jnp.exp(jnp.clip(li - lj, -60.0, 0.0))      # (b,nc,cl,cl,h)
    causal = jnp.tril(jnp.ones((cl, cl), bool))
    scores = cb[..., None] * jnp.where(causal[None, None, :, :, None],
                                       decay, 0.0)
    y_diag = jnp.einsum("bzijh,bzjhp->bzihp", scores, xdt)

    # --- per-chunk final states ---
    decay_to_end = jnp.exp(jnp.clip(dA_cs[:, :, -1:, :] - dA_cs, -60.0, 0.0))
    states = jnp.einsum("bzjn,bzjh,bzjhp->bzhpn", Br, decay_to_end, xdt)

    # --- cross-chunk recurrence ---
    chunk_decay = jnp.exp(jnp.clip(dA_cs[:, :, -1, :], -60.0, 0.0))  # (b,nc,h)

    def step(carry, inp):
        st, dec = inp                                   # (b,h,p,n), (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                               # emit PREVIOUS state

    init = jnp.zeros((b, h, p, n), x.dtype)
    final, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)       # (b,nc,h,p,n)

    # --- off-diagonal term: contribution of previous chunks' states ---
    c_decay = jnp.exp(jnp.clip(dA_cs, -60.0, 0.0))      # decay from chunk start
    y_off = jnp.einsum("bzin,bzih,bzhpn->bzihp", Cr, c_decay, prev_states)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final


def ssm_apply(params: Params, x: Array, cfg: ModelConfig):
    """Full-sequence SSD pass. x: (B, S, d) -> (y, decode_cache).

    decode_cache = {"state": (B,h,p,n), "conv": (B, kw-1, conv_dim)} — the
    recurrent state after the last token, so prefill hands off to
    ``ssm_decode_step`` directly."""
    d_in, heads, n, conv_dim = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, params["in_proj"])
    z, xs, B, C, dt = _split_proj(zxbcdt, cfg)
    xbc_pre = jnp.concatenate([xs, B, C], axis=-1)
    conv_tail = xbc_pre[:, -(cfg.ssm_conv_width - 1):, :]
    xbc = _conv_full(xbc_pre, params, cfg)
    xs, B, C = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])                       # (h,) negative
    xh = xs.reshape(*xs.shape[:-1], heads, cfg.ssm_head_dim)
    y, final = _ssd_chunked(xh.astype(jnp.float32), dt, a,
                            B.astype(jnp.float32), C.astype(jnp.float32),
                            cfg.ssm_chunk)
    y = y + params["d_skip"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(*x.shape[:-1], d_in)
    # gated RMSNorm then output projection
    y = y * jax.nn.silu(z.astype(jnp.float32))
    rms = jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-6)
    y = (y * rms).astype(x.dtype) * params["norm"]
    cache = {"state": final, "conv": conv_tail.astype(jnp.float32)}
    return jnp.einsum("bsk,kd->bsd", y, params["out_proj"]), cache


def ssm_decode_init(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    d_in, heads, n, conv_dim = _dims(cfg)
    return {
        "state": jnp.zeros((batch, heads, cfg.ssm_head_dim, n), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
    }


def ssm_decode_step(params: Params, x: Array, cache: Params,
                    cfg: ModelConfig):
    """Single-token recurrent update. x: (B, 1, d)."""
    d_in, heads, n, conv_dim = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, params["in_proj"])[:, 0]
    z, xs, B, C, dt = _split_proj(zxbcdt, cfg)
    xbc_new = jnp.concatenate([xs, B, C], axis=-1)      # (B, conv_dim)
    window = jnp.concatenate(
        [cache["conv"], xbc_new[:, None, :].astype(cache["conv"].dtype)], axis=1)
    w = params["conv_w"].astype(jnp.float32)            # (kw, conv_dim)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w)
    xbc = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32))
    xs, B, C = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,h)
    a = -jnp.exp(params["a_log"])
    xh = xs.reshape(-1, heads, cfg.ssm_head_dim)
    decay = jnp.exp(dt * a)                             # (B, h)
    state = cache["state"] * decay[..., None, None] + jnp.einsum(
        "bn,bhp,bh->bhpn", B, xh, dt)
    y = jnp.einsum("bn,bhpn->bhp", C, state)
    y = y + params["d_skip"][:, None] * xh
    y = y.reshape(-1, d_in) * jax.nn.silu(z.astype(jnp.float32))
    rms = jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-6)
    y = (y * rms).astype(x.dtype) * params["norm"]
    out = jnp.einsum("bk,kd->bd", y, params["out_proj"])[:, None, :]
    new_cache = {"state": state.astype(cache["state"].dtype),
                 "conv": window[:, 1:]}
    return out, new_cache
