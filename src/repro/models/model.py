"""Decoder LM assembly: stacked-layer scan, per-family block wiring,
train / prefill / decode entry points.

Layers are STACKED (leading L axis on every block parameter) and applied
with ``jax.lax.scan`` so the HLO stays one-block-sized regardless of depth —
essential for the 33-cell multi-pod dry-run compile budget. Heterogeneous
attention patterns (gemma3 local:global) ride along as a per-layer window
array; the zamba2 hybrid scans (groups x period) with the weight-tied shared
attention block applied once per group.

Entry points:
  init(rng, cfg)                      -> params
  train_loss(params, batch, cfg)      -> scalar loss      (train_4k)
  prefill(params, batch, cfg)         -> (logits, cache)  (prefill_32k)
  decode_step(params, batch, cache, cfg) -> (logits, cache)  (decode_*)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.sharding import annotate

Array = jax.Array
Params = dict[str, Any]


# ----------------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig) -> Params:
    """One transformer block (attention archs) or one SSM block."""
    if cfg.family == "ssm" or (cfg.family == "hybrid"):
        return {"ln": L.norm_init(cfg), "ssm": S.ssm_init(key, cfg)}
    k1, k2 = jax.random.split(key)
    p = {"ln1": L.norm_init(cfg), "ln2": L.norm_init(cfg),
         "attn": L.attention_init(k1, cfg)}
    if cfg.is_moe:
        p["moe"] = L.moe_init(k2, cfg)
    else:
        p["mlp"] = L.mlp_init(k2, cfg)
    return p


def init(rng: Array, cfg: ModelConfig) -> Params:
    ke, kb, kh, ks = jax.random.split(rng, 4)
    dt = jnp.dtype(cfg.param_dtype)
    params: Params = {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dt),
        "final_ln": L.norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(kh, cfg.d_model, (cfg.vocab,), dt)
    block_keys = jax.random.split(kb, cfg.n_layers)
    params["blocks"] = jax.vmap(lambda k: _block_init(k, cfg))(block_keys)
    if cfg.family == "hybrid":
        # zamba2: ONE weight-tied attention+MLP block reused every
        # ``hybrid_attn_every`` layers (the paper-config d_ff belongs here).
        ka, km = jax.random.split(ks)
        params["shared_attn"] = {"ln": L.norm_init(cfg),
                                 "attn": L.attention_init(ka, cfg),
                                 "ln2": L.norm_init(cfg),
                                 "mlp": L.mlp_init(km, cfg)}
    return params


def window_schedule(cfg: ModelConfig) -> Array:
    """Per-layer sliding-window sizes (0 = global full attention)."""
    if cfg.local_global_ratio > 0:
        period = cfg.local_global_ratio + 1
        idx = jnp.arange(cfg.n_layers)
        is_global = (idx % period) == cfg.local_global_ratio
        return jnp.where(is_global, 0, cfg.sliding_window).astype(jnp.int32)
    return jnp.full((cfg.n_layers,), cfg.sliding_window, jnp.int32)


# ----------------------------------------------------------------------------
# Forward (train / prefill): scan over stacked blocks
# ----------------------------------------------------------------------------

def _attn_block(bp: Params, x: Array, cfg: ModelConfig, positions: Array,
                window, collect_kv: bool):
    x = annotate.activations(x)
    h = L.norm_apply(bp["ln1"], x, cfg)
    a, kv = L.attention_apply(bp["attn"], h, cfg, positions=positions,
                              window=window, return_kv=collect_kv)
    x = x + a
    h = L.norm_apply(bp["ln2"], x, cfg)
    if cfg.is_moe:
        y, aux = L.moe_apply(bp["moe"], h, cfg)
    else:
        y, aux = L.mlp_apply(bp["mlp"], h, cfg), jnp.float32(0.0)
    return x + y, aux, kv


def _remat(body, cfg: ModelConfig):
    if not cfg.remat:
        return body
    if cfg.remat_policy == "dots":
        # Save matmul outputs AND the MoE combine (its psum would otherwise
        # re-fire on the wire during backward recompute).
        pol = jax.checkpoint_policies.save_from_both_policies(
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            jax.checkpoint_policies.save_only_these_names("moe_out"))
        return jax.checkpoint(body, policy=pol)
    return jax.checkpoint(body)


def _run_attn_stack(params: Params, x: Array, cfg: ModelConfig,
                    positions: Array, collect_kv: bool):
    windows = window_schedule(cfg)

    def body(carry, xs):
        x, aux_sum = carry
        bp, window = xs
        x, aux, kv = _attn_block(bp, x, cfg, positions, window, collect_kv)
        return (x, aux_sum + aux), kv

    body_fn = _remat(body, cfg)
    (x, aux), kvs = jax.lax.scan(body_fn, (x, jnp.float32(0.0)),
                                 (params["blocks"], windows))
    return x, aux, kvs


def _run_ssm_stack(params: Params, x: Array, cfg: ModelConfig):
    def body(x, bp):
        x = annotate.activations(x)
        h = L.norm_apply(bp["ln"], x, cfg)
        y, cache = S.ssm_apply(bp["ssm"], h, cfg)
        return x + y, cache

    body_fn = _remat(body, cfg)
    return jax.lax.scan(body_fn, x, params["blocks"])


def _run_hybrid_stack(params: Params, x: Array, cfg: ModelConfig,
                      positions: Array, collect_kv: bool):
    """zamba2: scan over groups of ``hybrid_attn_every`` SSM blocks, with the
    weight-tied shared attention block applied at the end of each group."""
    every = cfg.hybrid_attn_every
    n_groups = cfg.n_layers // every
    assert n_groups * every == cfg.n_layers, cfg.n_layers
    grouped = jax.tree.map(
        lambda a: a.reshape(n_groups, every, *a.shape[1:]), params["blocks"])
    shared = params["shared_attn"]

    def group_body(x, gbp):
        def inner(x, bp):
            x = annotate.activations(x)
            h = L.norm_apply(bp["ln"], x, cfg)
            y, cache = S.ssm_apply(bp["ssm"], h, cfg)
            return x + y, cache

        x, ssm_caches = jax.lax.scan(inner, x, gbp)
        x = annotate.activations(x)
        h = L.norm_apply(shared["ln"], x, cfg)
        a, kv = L.attention_apply(shared["attn"], h, cfg, positions=positions,
                                  window=0, return_kv=collect_kv)
        x = x + a
        h = L.norm_apply(shared["ln2"], x, cfg)
        x = x + L.mlp_apply(shared["mlp"], h, cfg)
        return x, (ssm_caches, kv)

    body_fn = _remat(group_body, cfg)
    return jax.lax.scan(body_fn, x, grouped)


def _embed_inputs(params: Params, batch: dict, cfg: ModelConfig) -> Array:
    """Token ids -> embeddings, or pass through stub frontend embeddings."""
    if cfg.frontend != "none":
        x = batch["embeddings"].astype(jnp.dtype(cfg.param_dtype))
    else:
        x = params["embed"][batch["tokens"]]
    return annotate.activations(x)


def _logits(params: Params, x: Array, cfg: ModelConfig) -> Array:
    x = L.norm_apply(params["final_ln"], x, cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return annotate.logits(jnp.einsum("bsd,dv->bsv", x, head))


def forward(params: Params, batch: dict, cfg: ModelConfig,
            collect_cache: bool = False, last_token_logits: bool = False):
    """Full-sequence forward. Returns (logits, aux_loss, caches).

    ``last_token_logits``: compute the LM head only for the final position
    (prefill serving — avoids the (B, S, vocab) buffer entirely).
    """
    x = _embed_inputs(params, batch, cfg)
    B, seq = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (B, seq))
    aux = jnp.float32(0.0)
    caches = None
    if cfg.family == "ssm":
        x, caches = _run_ssm_stack(params, x, cfg)
    elif cfg.family == "hybrid":
        x, caches = _run_hybrid_stack(params, x, cfg, positions, collect_cache)
    else:
        x, aux, caches = _run_attn_stack(params, x, cfg, positions,
                                         collect_cache)
    if last_token_logits:
        x = x[:, -1:, :]
    return _logits(params, x, cfg), aux, caches


def train_loss(params: Params, batch: dict, cfg: ModelConfig) -> Array:
    """Next-token cross-entropy (+ MoE router aux loss)."""
    logits, aux, _ = forward(params, batch, cfg)
    labels = batch["labels"]
    logits_f = logits.astype(jnp.float32)
    lmax = jax.lax.stop_gradient(jnp.max(logits_f, axis=-1, keepdims=True))
    shifted = logits_f - lmax
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    # Select the gold logit with an iota-compare reduce instead of
    # take_along_axis: a vocab-axis gather would force XLA to re-gather
    # model-sharded logits; select+max stays shard-local + one tiny psum.
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.max(jnp.where(vocab_iota == labels[..., None], shifted,
                             -jnp.inf), axis=-1)
    mask = batch.get("loss_mask")
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = nll.size
    loss = jnp.sum(nll) / denom
    return loss + 0.01 * aux


# ----------------------------------------------------------------------------
# Serving: prefill + single-token decode
# ----------------------------------------------------------------------------

def prefill(params: Params, batch: dict, cfg: ModelConfig):
    """Returns (last-token logits, decode cache)."""
    logits, _, caches = forward(params, batch, cfg, collect_cache=True,
                                last_token_logits=True)
    return logits, caches


def init_decode_cache(cfg: ModelConfig, batch: int, seq_len: int,
                      dtype=jnp.bfloat16) -> Params:
    """Empty decode cache sized for ``seq_len`` past tokens (+1 new)."""
    hd, KV = cfg.head_dim, cfg.n_kv_heads
    size = seq_len + 1
    kv = lambda: {"k": jnp.zeros((batch, size, KV, hd), dtype),
                  "v": jnp.zeros((batch, size, KV, hd), dtype)}
    if cfg.family == "ssm":
        return {"ssm": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)),
            S.ssm_decode_init(cfg, batch))}
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.hybrid_attn_every
        ssm0 = S.ssm_decode_init(cfg, batch)
        return {
            "ssm": jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (n_groups, cfg.hybrid_attn_every, *a.shape)), ssm0),
            "attn": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_groups, *a.shape)), kv()),
        }
    stack = lambda t: jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), t)
    return {"attn": stack(kv())}


def decode_step(params: Params, batch: dict, cache: Params,
                cfg: ModelConfig):
    """One-token decode. batch: {"tokens": (B,1)} (or embeddings) plus
    {"cache_index": scalar int32 — number of tokens already in the cache}."""
    x = _embed_inputs(params, batch, cfg)
    idx = batch["cache_index"]
    B = x.shape[0]
    positions = jnp.broadcast_to(idx, (B, 1)).astype(jnp.int32)

    if cfg.family == "ssm":
        def body(x, xs):
            bp, c = xs
            h = L.norm_apply(bp["ln"], x, cfg)
            y, c2 = S.ssm_decode_step(bp["ssm"], h, c, cfg)
            return x + y, c2
        x, new_ssm = jax.lax.scan(body, x, (params["blocks"], cache["ssm"]))
        new_cache = {"ssm": new_ssm}
    elif cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // every
        grouped = jax.tree.map(
            lambda a: a.reshape(n_groups, every, *a.shape[1:]),
            params["blocks"])
        shared = params["shared_attn"]

        def group_body(x, xs):
            gbp, ssm_c, attn_c = xs

            def inner(x, ys):
                bp, c = ys
                h = L.norm_apply(bp["ln"], x, cfg)
                y, c2 = S.ssm_decode_step(bp["ssm"], h, c, cfg)
                return x + y, c2

            x, new_ssm_c = jax.lax.scan(inner, x, (gbp, ssm_c))
            h = L.norm_apply(shared["ln"], x, cfg)
            a, new_attn_c = L.attention_apply(
                shared["attn"], h, cfg, positions=positions, window=0,
                cache=attn_c, cache_index=idx)
            x = x + a
            h = L.norm_apply(shared["ln2"], x, cfg)
            x = x + L.mlp_apply(shared["mlp"], h, cfg)
            return x, (new_ssm_c, new_attn_c)

        x, (new_ssm, new_attn) = jax.lax.scan(
            group_body, x, (grouped, cache["ssm"], cache["attn"]))
        new_cache = {"ssm": new_ssm, "attn": new_attn}
    else:
        windows = window_schedule(cfg)

        def body(x, xs):
            bp, window, c = xs
            h = L.norm_apply(bp["ln1"], x, cfg)
            a, c2 = L.attention_apply(bp["attn"], h, cfg, positions=positions,
                                      window=window, cache=c, cache_index=idx)
            x = x + a
            h = L.norm_apply(bp["ln2"], x, cfg)
            if cfg.is_moe:
                y, _ = L.moe_apply(bp["moe"], h, cfg)
            else:
                y = L.mlp_apply(bp["mlp"], h, cfg)
            return x + y, c2

        x, new_attn = jax.lax.scan(body, x,
                                   (params["blocks"], windows, cache["attn"]))
        new_cache = {"attn": new_attn}

    return _logits(params, x, cfg), new_cache
