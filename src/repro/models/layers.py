"""Transformer building blocks in pure JAX (no flax): norms, rotary
embeddings, GQA attention with KV cache, MLP flavors, and a sort-based
token-dropping MoE layer.

Parameters are plain nested dicts of jnp arrays. Every ``*_init`` returns a
param dict; every ``*_apply`` is a pure function of (params, inputs). Shapes
are chosen so stacked-layer scanning (models/model.py) and the sharding
rules (sharding/rules.py) can address leaves by path name.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Array = jax.Array
Params = dict[str, Any]

NEG_INF = -1e30


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, in_dim: int, out_shape: tuple[int, ...], dtype) -> Array:
    """Fan-in-scaled normal init, matmul weight of shape (in_dim, *out)."""
    scale = in_dim ** -0.5
    return (jax.random.normal(key, (in_dim, *out_shape)) * scale).astype(dtype)


# ----------------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------------

def norm_init(cfg: ModelConfig) -> Params:
    if cfg.norm == "nonparametric":
        return {}
    return {"scale": jnp.ones((cfg.d_model,), _dtype(cfg))}


def norm_apply(params: Params, x: Array, cfg: ModelConfig) -> Array:
    """RMSNorm, or OLMo-style non-parametric LayerNorm (no scale/bias)."""
    xf = x.astype(jnp.float32)
    if cfg.norm == "nonparametric":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        return ((xf - mu) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
    return (xf * rms).astype(x.dtype) * params["scale"]


# ----------------------------------------------------------------------------
# Rotary position embeddings (standard + sectioned M-RoPE)
# ----------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float,
               mrope: bool = False) -> Array:
    """x: (B, S, H, hd); positions: (B, S) int32.

    M-RoPE (qwen2-vl) splits the head dim into 3 sections (temporal/h/w);
    with the stubbed vision frontend all three share the same position id
    stream, so the math reduces to sectioned standard RoPE — kept explicit
    so real 3-D position ids drop in without a model change.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    if mrope:
        # 3 sections of the rotary spectrum, each driven by its own
        # position stream (identical streams under the stub frontend).
        sec = hd // 2 // 3
        sec_ids = jnp.minimum(jnp.arange(hd // 2) // max(sec, 1), 2)
        pos3 = jnp.stack([positions] * 3, axis=-1)      # (B, S, 3)
        angles = pos3[..., None, :].astype(jnp.float32)  # (B,S,1,3)
        ang = jnp.take_along_axis(
            angles * freqs[None, None, :, None],
            sec_ids[None, None, :, None], axis=-1)[..., 0]  # (B,S,hd/2)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# GQA attention with optional sliding window and KV cache
# ----------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    dt = _dtype(cfg)
    return {
        "wq": dense_init(kq, d, (cfg.n_heads, hd), dt),
        "wk": dense_init(kk, d, (cfg.n_kv_heads, hd), dt),
        "wv": dense_init(kv, d, (cfg.n_kv_heads, hd), dt),
        "wo": dense_init(ko, cfg.n_heads * hd, (d,), dt),
    }


#: Full-sequence attention switches to the chunked online-softmax (flash)
#: path above this length — the S x S score matrix must never materialize
#: for the 32k prefill cells (83 GB/device at 4k already, see EXPERIMENTS.md).
FLASH_THRESHOLD = 1024
FLASH_CHUNK = 512


def _flash_attention(q: Array, k: Array, v: Array, window: Array,
                     scale: float) -> Array:
    """Chunked causal attention with online softmax, pure JAX.

    q: (B, S, KV, G, hd) grouped queries; k, v: (B, S, KV, hd).
    Outer loop over query chunks is unrolled (static); each chunk scans only
    its causal prefix of KV chunks (ragged inner scan — exact-causal FLOPs,
    no S x S buffer). ``window`` may be a traced scalar (0 = global).
    """
    B, S, KV, G, hd = q.shape
    C = FLASH_CHUNK
    nq = S // C
    outs = []
    for i in range(nq):
        q_blk = jax.lax.slice_in_dim(q, i * C, (i + 1) * C, axis=1)
        q_blk = q_blk.astype(jnp.float32) * scale
        qpos = i * C + jnp.arange(C)[:, None]                  # (C, 1)

        def body(carry, j, q_blk=q_blk, qpos=qpos):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, j * C, C, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, j * C, C, axis=1)
            s = jnp.einsum("bqngh,btnh->bqngt", q_blk,
                           k_blk.astype(jnp.float32))          # (B,C,KV,G,C)
            kpos = j * C + jnp.arange(C)[None, :]              # (1, C)
            ok = kpos <= qpos
            ok &= jnp.where(window > 0, (qpos - kpos) < window, True)
            s = jnp.where(ok[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqngt,btnh->bqngh", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, C, KV, G), NEG_INF, jnp.float32),
                jnp.zeros((B, C, KV, G), jnp.float32),
                jnp.zeros((B, C, KV, G, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(i + 1))
        outs.append(acc / jnp.maximum(l, 1e-30)[..., None])
    return jnp.concatenate(outs, axis=1)                        # (B,S,KV,G,hd)


def attention_apply(params: Params, x: Array, cfg: ModelConfig, *,
                    positions: Array, window: Array | int = 0,
                    cache: Params | None = None,
                    cache_index: Array | None = None,
                    return_kv: bool = False):
    """Full-sequence (train/prefill) or single-token (decode) attention.

    ``window`` may be a traced int32 scalar (0 = full attention), so mixed
    local/global stacks (gemma3) scan over one stacked parameter tree with a
    per-layer window array instead of unrolling.

    cache: {"k","v"}: (B, S_cache, kvH, hd). When given, x is (B, 1, d) and
    the new KV is written at ``cache_index``; attention runs over the cache.
    Returns (out, new_cache_or_kv).
    """
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope)
    window = jnp.asarray(window, jnp.int32)

    if cache is not None:
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0))
        new_cache = {"k": k_cache, "v": v_cache}
        k, v = k_cache.astype(x.dtype), v_cache.astype(x.dtype)
        skv = k.shape[1]
        kpos = jnp.arange(skv)[None, :]
        ok = kpos <= cache_index
        ok &= jnp.where(window > 0, (cache_index - kpos) < window, True)
        mask = jnp.where(ok, 0.0, NEG_INF)[None, :, :]   # (1,1,skv)
        mask = mask[None]                                # (1,1,1,skv)
    else:
        new_cache = {"k": k, "v": v} if return_kv else None
        group = H // KV
        qg = q.reshape(B, S, KV, group, hd)
        if S > FLASH_THRESHOLD and S % FLASH_CHUNK == 0:
            out = _flash_attention(qg, k, v, window, hd ** -0.5)
            out = out.astype(x.dtype).reshape(B, S, H * hd)
            return jnp.einsum("bsk,kd->bsd", out, params["wo"]), new_cache
        qpos = jnp.arange(S)[:, None]
        kpos = jnp.arange(S)[None, :]
        ok = kpos <= qpos
        ok &= jnp.where(window > 0, (qpos - kpos) < window, True)
        mask = jnp.where(ok, 0.0, NEG_INF)[None, None, :, :]

    group = H // KV
    qg = q.reshape(B, S, KV, group, hd)
    scores = jnp.einsum("bsngk,btnk->bngst", qg, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    scores = scores + mask.astype(jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bngst,btnk->bsngk", probs, v)
    out = out.reshape(B, S, H * hd)
    return jnp.einsum("bsk,kd->bsd", out, params["wo"]), new_cache


# ----------------------------------------------------------------------------
# MLP flavors
# ----------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None) -> Params:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    dt = _dtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": dense_init(k1, d, (ff,), dt),
         "w_down": dense_init(k2, ff, (d,), dt)}
    if cfg.mlp == "swiglu":
        p["w_gate"] = dense_init(k3, d, (ff,), dt)
    return p


def mlp_apply(params: Params, x: Array, cfg: ModelConfig) -> Array:
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    if cfg.mlp == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        h = jax.nn.silu(gate) * up
    elif cfg.mlp == "relu2":                 # nemotron squared-ReLU
        r = jax.nn.relu(up)
        h = r * r
    else:
        h = jax.nn.gelu(up)
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])


# ----------------------------------------------------------------------------
# Mixture of Experts: sort-based capacity dispatch (GShard semantics,
# gather/scatter plumbing so HLO FLOPs stay ~= active-expert FLOPs)
# ----------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig) -> Params:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    s = cfg.moe_ff_shards
    dt = _dtype(cfg)
    kg, k1, k2, k3 = jax.random.split(key, 4)
    # Packed layout: (E*s, d, ff/s) — slice s of expert e lives at row e*s+s.
    p = {
        "router": dense_init(kg, d, (E,), jnp.float32),
        "w_up": (jax.random.normal(k1, (E * s, d, ff // s))
                 * d ** -0.5).astype(dt),
        "w_down": (jax.random.normal(k2, (E * s, ff // s, d))
                   * ff ** -0.5).astype(dt),
    }
    if cfg.mlp == "swiglu":
        p["w_gate"] = (jax.random.normal(k3, (E * s, d, ff // s))
                       * d ** -0.5).astype(dt)
    return p


def pack_moe_weights(w: Array, s: int) -> Array:
    """(E, d, ff) plain layout -> (E*s, d, ff/s) packed (tests/migration)."""
    E, d, ff = w.shape
    return (w.reshape(E, d, s, ff // s).transpose(0, 2, 1, 3)
            .reshape(E * s, d, ff // s))


def pack_moe_down(w: Array, s: int) -> Array:
    """(E, ff, d) -> (E*s, ff/s, d)."""
    E, ff, d = w.shape
    return w.reshape(E * s, ff // s, d)


def _moe_dispatch(params: Params, x: Array, cfg: ModelConfig, capacity: int):
    """Routing + capacity bucketing for one token group x: (tg, d).

    Returns (xe (E, C, d) expert inputs, slot/stok/sgate/keep for combine,
    aux load-balance loss). Group-local: vmapped over groups, so the only
    cross-device movement is the expert (EP) dimension of xe/ye.
    """
    tg, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    C = capacity
    logits = (x.astype(jnp.float32) @ params["router"])          # (tg, E)
    gate_top, ids = jax.lax.top_k(logits, k)                     # (tg, k)
    gates = jax.nn.softmax(gate_top, axis=-1)                    # mixtral-style

    flat_e = ids.reshape(-1)                                     # (tg*k,)
    flat_tok = jnp.arange(tg * k, dtype=jnp.int32) // k
    flat_gate = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    stok = flat_tok[order]
    sgate = flat_gate[order]
    # Position of each entry within its expert's run.
    first = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(tg * k, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)                  # drop row

    xe = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(x[stok])
    xe = xe[:E * C].reshape(E, C, d)
    # Router aux loss (load balancing, Switch-style).
    me = jnp.mean(jax.nn.one_hot(ids[:, 0], E), axis=0)
    pe = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=0)
    aux = E * jnp.sum(me * pe)
    return xe, (slot, stok, sgate, keep), aux


def _moe_combine(ye: Array, route, tg: int, dtype) -> Array:
    """Scatter expert outputs back to tokens for one group.
    ye: (E, C, d)."""
    slot, stok, sgate, keep = route
    EC, d = ye.shape[0] * ye.shape[1], ye.shape[2]
    ye_flat = jnp.concatenate([ye.reshape(EC, d),
                               jnp.zeros((1, d), ye.dtype)], axis=0)
    contrib = ye_flat[slot] * (sgate * keep)[:, None].astype(ye.dtype)
    return jnp.zeros((tg, d), dtype).at[stok].add(contrib.astype(dtype))


def _moe_routing(router: Array, xg: Array, k: int, E: int):
    """Shared routing math for one group. Returns sorted entry arrays."""
    tg = xg.shape[0]
    logits = xg.astype(jnp.float32) @ router                     # (tg, E)
    gate_top, ids = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(gate_top, axis=-1)
    flat_e = ids.reshape(-1)
    flat_tok = jnp.arange(tg * k, dtype=jnp.int32) // k
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    stok = flat_tok[order]
    sgate = gates.reshape(-1)[order]
    first = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(tg * k, dtype=jnp.int32) - first.astype(jnp.int32)
    me = jnp.mean(jax.nn.one_hot(ids[:, 0], E), axis=0)
    pe = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=0)
    aux = E * jnp.sum(me * pe)
    return se, stok, sgate, pos, aux


def moe_apply_shard_map(params: Params, x: Array, cfg: ModelConfig,
                        mesh) -> tuple[Array, Array]:
    """Explicit-EP MoE: every rank routes (replicated, cheap), builds ONLY
    its local packed-expert buckets, computes locally, and contributes a
    partial token-output — one activation psum over "model" per layer.

    No (G, E, C, d) tensor ever crosses the wire (vs ~100 GB/layer of
    SPMD resharding in the constraint-based path — EXPERIMENTS.md Perf).
    """
    from jax.sharding import PartitionSpec as P
    from repro.sharding.annotate import _dp_axes

    B, S, d = x.shape
    E, s, k = cfg.n_experts, cfg.moe_ff_shards, cfg.experts_per_token
    C = int(S * k / E * cfg.moe_capacity_factor) + 1
    dp = _dp_axes(mesh)
    dp_ax = dp if len(dp) > 1 else (dp[0] if dp else None)

    def local(router, w_up, w_gate, w_down, x_loc):
        e_loc = w_up.shape[0]                          # local packed rows
        rank = jax.lax.axis_index("model")
        row0 = rank * e_loc

        def per_group(xg):
            tg = xg.shape[0]
            se, stok, sgate, pos, aux = _moe_routing(router, xg, k, E)
            keep = pos < C
            y = jnp.zeros((tg, d), x.dtype)
            for j in range(e_loc):                     # static, small
                e_j = (row0 + j) // s                  # expert of local row
                mine = keep & (se == e_j)
                slot = jnp.where(mine, pos, C)
                xe = jnp.zeros((C + 1, d), x.dtype).at[slot].set(xg[stok])
                xe = xe[:C]
                up = xe @ w_up[j]
                if cfg.mlp == "swiglu":
                    h = jax.nn.silu(xe @ w_gate[j]) * up
                else:
                    r = jax.nn.relu(up)
                    h = r * r if cfg.mlp == "relu2" else jax.nn.gelu(up)
                ye = jnp.concatenate([h @ w_down[j],
                                      jnp.zeros((1, d), x.dtype)], axis=0)
                contrib = ye[slot] * (sgate * mine)[:, None].astype(x.dtype)
                y = y.at[stok].add(contrib)
            return y, aux

        y, aux = jax.vmap(per_group)(x_loc)
        y = jax.lax.psum(y, "model")                   # sums slices+experts
        return y, jnp.mean(aux)

    w_gate = params.get("w_gate", params["w_up"])      # placeholder if none
    in_specs = (P(), P("model", None, None), P("model", None, None),
                P("model", None, None), P(dp_ax, None, None))
    fn = jax.shard_map(local, mesh=mesh, in_specs=in_specs,
                       out_specs=(P(dp_ax, None, None), P()),
                       check_vma=False)
    y, aux = fn(params["router"], params["w_up"], w_gate, params["w_down"], x)
    from jax.ad_checkpoint import checkpoint_name
    y = checkpoint_name(y, "moe_out")
    return y, jnp.mean(aux)


def moe_apply(params: Params, x: Array, cfg: ModelConfig) -> tuple[Array, Array]:
    """x: (B, S, d). Groups = batch rows (sequence-local routing).

    Structure: per-group dispatch (vmap) -> globally-constrained expert
    compute (the packed expert dim is sharded over "model": true EP) ->
    per-group combine (vmap). With moe_ff_shards = s > 1 every expert's FFN
    is split into s column slices; the combine sums the s partial outputs
    (a pairwise psum on the wire instead of a full-mesh contraction psum).

    With cfg.moe_shard_map and an ambient mesh carrying a "model" axis, the
    explicit-EP shard_map path is used instead (see moe_apply_shard_map).
    """
    from repro.sharding import annotate

    if cfg.moe_shard_map:
        mesh = annotate._mesh()
        if mesh is not None and "model" in mesh.axis_names:
            return moe_apply_shard_map(params, x, cfg, mesh)

    B, S, d = x.shape
    E, s = cfg.n_experts, cfg.moe_ff_shards
    k = cfg.experts_per_token
    C = int(S * k / cfg.n_experts * cfg.moe_capacity_factor) + 1

    xe, route, aux = jax.vmap(
        lambda g: _moe_dispatch(params, g, cfg, C))(x)           # (G,E,C,d)
    if s > 1:
        xe = jnp.repeat(xe, s, axis=1)                           # (G,E*s,C,d)
    xe = annotate.moe_experts(xe)                                # EP boundary

    up = jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
    if cfg.mlp == "swiglu":
        g_ = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])
        h = jax.nn.silu(g_) * up
    else:
        r = jax.nn.relu(up)
        h = r * r if cfg.mlp == "relu2" else jax.nn.gelu(up)
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"])       # (G,E*s,C,d)
    if s > 1:
        ye = ye.reshape(B, E, s, C, d).sum(axis=2)               # pairwise sum
    ye = annotate.moe_tokens(ye)                                 # back to DP

    y = jax.vmap(lambda e, r: _moe_combine(e, r, S, x.dtype))(ye, route)
    return y, jnp.mean(aux)
