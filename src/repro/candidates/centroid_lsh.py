"""IVF/LSH candidate source over WCD centroids.

The paper's WCD baseline is already the cascade's cheap prefetch; this
source moves it BELOW linear: at build time every corpus row's weighted
centroid is quantized into one of ``n_buckets`` coarse cells (a k-means
codebook — classic IVF — or random hyperplane signs — classic LSH), and
the rows of each cell are packed into a dense ``(n_buckets, cap)``
table. At query time the step computes the query centroids, ranks the
bucket centroids (an ``(nq, n_buckets)`` matmul — buckets, not rows),
and gathers the rows of the ``probes`` nearest buckets: every op is a
dense matmul/gather over fixed-width tables, so the step jits, batches
over queries, and shards on the mesh with traffic proportional to
``probes * cap`` PROBED rows — never to the corpus. This is the
nearest-neighbor-search EMD approximation pattern of Meng et al. 2024
(arXiv:2401.07378) specialized to the WCD embedding the repo already
trusts as its prefetch heuristic.

Not admissible: a true neighbor whose bucket is not probed is lost, so
cascades sourced here always report MEASURED recall.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.candidates.base import (EMPTY_CENTER, SourceSpec,
                                   corpus_centroids, kmeans, pack_table,
                                   refine_by_centroid, register_source,
                                   slot_centroids)
from repro.core import lc


@register_source
@dataclasses.dataclass(frozen=True)
class CentroidLSHSpec(SourceSpec):
    """Build parameters of the coarse centroid quantizer.

    quantizer:   ``kmeans`` (IVF codebook, data-dependent) or
                 ``hyperplane`` (sign-pattern LSH, data-independent;
                 ``n_buckets`` must then be a power of two — one bit
                 per hyperplane).
    n_buckets:   coarse cells. More cells = finer probes; sqrt(n)-ish
                 is the usual IVF operating point.
    probes:      buckets gathered per query, nearest centroid first.
    bucket_cap:  rows kept per bucket; ``None`` sizes the table to the
                 fullest bucket (lossless, data-dependent shape — the
                 static checkers need an explicit cap), an int drops
                 overflow beyond it.
    refine:      optional exact-WCD refine: the source stores per-slot
                 row centroids and returns only the ``refine``
                 centroid-nearest of the probed rows (classic IVF-flat).
                 This IS the reference cascade's full-scan WCD stage
                 restricted to probed rows — without it, probed rows
                 outside the reference's WCD prefix crowd true
                 neighbors out of the next stage's budget.
    kmeans_iters/seed: quantizer fitting knobs.
    """

    kind = "centroid_lsh"
    admissible = False
    full_scan = False

    quantizer: str = "kmeans"
    n_buckets: int = 64
    probes: int = 8
    bucket_cap: int | None = None
    refine: int | None = None
    kmeans_iters: int = 4
    seed: int = 0

    def __post_init__(self) -> None:
        if self.quantizer not in ("kmeans", "hyperplane"):
            raise ValueError(f"unknown quantizer {self.quantizer!r}; "
                             "one of ('kmeans', 'hyperplane')")
        if self.n_buckets < 2 or self.probes < 1:
            raise ValueError("need n_buckets >= 2 and probes >= 1, got "
                             f"{self.n_buckets}/{self.probes}")
        if self.probes > self.n_buckets:
            raise ValueError(f"probes={self.probes} exceeds "
                             f"n_buckets={self.n_buckets}")
        if self.quantizer == "hyperplane" and \
                self.n_buckets & (self.n_buckets - 1):
            raise ValueError("hyperplane LSH needs a power-of-two "
                             f"n_buckets (one sign bit per plane), got "
                             f"{self.n_buckets}")
        if self.bucket_cap is not None and self.bucket_cap < 1:
            raise ValueError(f"bucket_cap must be >= 1 or None, got "
                             f"{self.bucket_cap}")
        if self.refine is not None:
            if self.refine < 1:
                raise ValueError(f"refine must be >= 1 or None, got "
                                 f"{self.refine}")
            if self.bucket_cap is not None and \
                    self.refine > self.probes * self.bucket_cap:
                raise ValueError(
                    f"refine={self.refine} exceeds the probed width "
                    f"probes*bucket_cap={self.probes * self.bucket_cap}")
        if self.kmeans_iters < 1:
            raise ValueError("kmeans_iters must be >= 1")

    @property
    def width(self) -> int | None:
        """Candidate columns the built source emits per query, when
        statically known (``None`` = known only after build)."""
        if self.refine is not None:
            return self.refine
        return None if self.bucket_cap is None \
            else self.probes * self.bucket_cap

    def build(self, corpus, *, n_valid: int | None = None):
        """Quantize the (real) corpus rows' centroids and pack the
        bucket table — host-side numpy, once, at ``EmdIndex.build``."""
        rng = np.random.default_rng(self.seed)
        x = corpus_centroids(corpus, n_valid=n_valid)
        if self.quantizer == "kmeans":
            centers, assign = kmeans(x, self.n_buckets, self.kmeans_iters,
                                     rng)
        else:
            nbits = self.n_buckets.bit_length() - 1
            planes = rng.standard_normal((nbits,
                                          x.shape[1])).astype(np.float32)
            bits = (x @ planes.T) > 0.0
            assign = bits @ (1 << np.arange(nbits, dtype=np.int64))
            centers = np.full((self.n_buckets, x.shape[1]), EMPTY_CENTER,
                              np.float32)
        rows, mask, dropped = pack_table(assign, self.n_buckets,
                                         self.bucket_cap)
        # Empirical bucket centroids (the probe targets) for BOTH
        # quantizers: hyperplane cells are ranked by where their members
        # actually sit, and empty cells keep the far sentinel so they
        # are probed last.
        counts = np.bincount(assign, minlength=self.n_buckets)
        sums = np.empty((self.n_buckets, x.shape[1]), np.float64)
        for j in range(x.shape[1]):
            sums[:, j] = np.bincount(assign, weights=x[:, j],
                                     minlength=self.n_buckets)
        live = counts > 0
        centers[live] = (sums[live] / counts[live, None]).astype(np.float32)
        centers[~live] = EMPTY_CENTER
        if self.refine is not None and \
                self.refine > self.probes * rows.shape[1]:
            raise ValueError(
                f"refine={self.refine} exceeds the probed width "
                f"probes*cap={self.probes * rows.shape[1]} of the built "
                "table")
        cents = slot_centroids(x, rows, mask) \
            if self.refine is not None else None
        return CentroidLSHSource(
            spec=self, centroids=jnp.asarray(centers),
            rows=jnp.asarray(rows), mask=jnp.asarray(mask),
            cents=None if cents is None else jnp.asarray(cents),
            dropped_rows=dropped)

    def state_structs(self, m: int) -> tuple:
        if self.bucket_cap is None:
            raise ValueError(
                "bucket_cap=None sizes the table to the data; the static "
                "checkers need an explicit bucket_cap to know the state "
                "shapes without building")
        nb, cap = self.n_buckets, self.bucket_cap
        out = (jax.ShapeDtypeStruct((nb, m), jnp.float32),
               jax.ShapeDtypeStruct((nb, cap), jnp.int32),
               jax.ShapeDtypeStruct((nb, cap), jnp.bool_))
        if self.refine is not None:
            out += (jax.ShapeDtypeStruct((nb, cap, m), jnp.float32),)
        return out

    def wrap(self, leaves):
        if self.refine is not None:
            centroids, rows, mask, cents = leaves
        else:
            (centroids, rows, mask), cents = leaves, None
        return CentroidLSHSource(spec=self, centroids=centroids,
                                 rows=rows, mask=mask, cents=cents)

    def describe(self) -> str:
        cap = "max" if self.bucket_cap is None else self.bucket_cap
        ref = "" if self.refine is None else f" r{self.refine}"
        return (f"centroid_lsh[{self.quantizer} b{self.n_buckets} "
                f"p{self.probes} cap{cap}{ref}]")


@dataclasses.dataclass(frozen=True)
class CentroidLSHSource:
    """Built IVF/LSH index: bucket centroids + dense row table. A jax
    pytree (arrays = leaves, spec = static), so it rides through jit and
    the checkpoint store unchanged."""

    spec: CentroidLSHSpec
    centroids: jax.Array                # (n_buckets, m) float32
    rows: jax.Array                     # (n_buckets, cap) int32 row ids
    mask: jax.Array                     # (n_buckets, cap) validity
    cents: jax.Array | None = None      # (n_buckets, cap, m) refine table
    dropped_rows: int = 0               # overflow beyond an explicit cap

    @property
    def width(self) -> int:
        if self.spec.refine is not None:
            return self.spec.refine
        return self.spec.probes * self.rows.shape[1]

    def candidates(self, corpus, q_ids, q_w, budget: int | None = None):
        """(nq, width) candidate row ids + validity mask — nearest probed
        bucket first, or ascending exact centroid distance under
        ``refine``; ``budget`` truncates to the best-ranked columns.
        Jittable; every shape is fixed by the spec, and the only data
        touched scales with probed rows."""
        qc = jnp.einsum("qh,qhm->qm", q_w, corpus.coords[q_ids])
        d = jnp.linalg.norm(self.centroids[None, :, :] - qc[:, None, :],
                            axis=-1)
        # EMPTY_CENTER distances overflow to +inf, which breaks the
        # min-extraction top-k (it masks winners to PAD_DIST < inf and
        # would re-pick them — duplicate probes). Clamp BELOW PAD_DIST
        # so empty buckets still rank last but stay distinct.
        d = jnp.minimum(d, 0.5 * lc.PAD_DIST)
        _, probe = lc.streaming_smallest_k(d, self.spec.probes)
        nq = q_ids.shape[0]
        rows = self.rows[probe].reshape(nq, -1)
        mask = self.mask[probe].reshape(nq, -1)
        if self.spec.refine is not None:
            cents = self.cents[probe].reshape(nq, rows.shape[1], -1)
            rows, mask = refine_by_centroid(qc, rows, mask, cents,
                                            self.spec.refine)
        if budget is not None and budget < rows.shape[1]:
            rows, mask = rows[:, :budget], mask[:, :budget]
        return rows, mask


jax.tree_util.register_dataclass(
    CentroidLSHSource, data_fields=["centroids", "rows", "mask", "cents"],
    meta_fields=["spec", "dropped_rows"])
