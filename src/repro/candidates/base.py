"""The ``CandidateSource`` protocol: pluggable cascade stage-0.

Every cascade used to score the FULL corpus with its cheapest bound —
an O(n) wall no ladder quality could move. A candidate source breaks it:
a build-time index over the corpus (arrays, built host-side at
``EmdIndex.build``) plus a jittable ``candidates(corpus, q_ids, q_w,
budget) -> (ids, mask)`` step that emits each query's candidate rows
with traffic proportional to the rows PROBED, never to the corpus. The
cascade's first stage then scores only the sourced candidates through
the registry's candidate-compacted engines (``retrieval.cand_scores``).

Two halves, mirroring ``CascadeSpec`` vs the built index:

* a **SourceSpec** — a frozen, hashable dataclass of build parameters
  (``FullScanSpec``, ``CentroidLSHSpec``, ``ClusterTreeSpec``). It rides
  in ``CascadeSpec.source``, keys jit caches, and JSON-round-trips
  through the serving snapshot codec. ``spec.build(corpus)`` produces
* a **source** — the spec plus its built index arrays, registered as a
  jax pytree (arrays = leaves, spec = static aux data) so it passes
  through ``jax.jit`` as an ordinary argument and its state serializes
  through the checkpoint store like any other leaf tree.

Admissibility: only the full scan sees every row, so only
``FullScanSpec`` is admissible — any sublinear source can miss a true
neighbor, which forces the owning ``CascadeSpec.admissible`` to False
and the recall number to be MEASURED (``cascade.topk_recall``,
``bench_cascade``'s sweep), never assumed.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lc

#: Sentinel coordinate of empty buckets / empty tree nodes: their
#: distance to any real query centroid overflows to +inf, so they are
#: probed only after every non-empty bucket (and their candidate slots
#: are masked anyway).
EMPTY_CENTER = 1e30

#: Registered source-spec classes by ``kind`` (filled by the concrete
#: modules at import; ``CascadeSpec.source`` accepts these names).
SOURCES: dict[str, type] = {}


def register_source(cls):
    """Class decorator: register a SourceSpec subclass under its
    ``kind`` and return it unchanged."""
    SOURCES[cls.kind] = cls
    return cls


def resolve_source(spec):
    """A SourceSpec passes through; a string resolves to its registered
    spec class built with defaults (``"centroid_lsh"`` etc.)."""
    if isinstance(spec, SourceSpec):
        return spec
    if isinstance(spec, str):
        if spec not in SOURCES:
            raise ValueError(f"unknown candidate source {spec!r}; "
                             f"registered: {sorted(SOURCES)}")
        return SOURCES[spec]()
    raise TypeError(f"expected a SourceSpec or a registered source name, "
                    f"got {type(spec).__name__}")


@dataclasses.dataclass(frozen=True)
class SourceSpec:
    """Base class of the frozen build-parameter dataclasses. Concrete
    subclasses set the class attributes and implement :meth:`build` /
    :meth:`state_structs` / :meth:`wrap`."""

    #: registry key (``CascadeSpec.source`` accepts it as a string).
    kind = "abstract"
    #: True only for the full scan: every row is a candidate, so an
    #: otherwise-admissible cascade keeps its exact-top-l guarantee.
    admissible = False
    #: True when the cascade driver should run the original full-corpus
    #: stage-1 path instead of candidate compaction.
    full_scan = False

    def build(self, corpus, *, n_valid: int | None = None):
        """Build the index state over ``corpus`` (host-side numpy; rows
        at index >= ``n_valid`` are padding and never enter a bucket)."""
        raise NotImplementedError

    def state_structs(self, m: int) -> tuple:
        """``jax.ShapeDtypeStruct`` of every state array, in the field
        order :meth:`wrap` consumes — what the static checkers compile
        the mesh step against without building anything. Requires the
        capacity knobs to be explicit (data-dependent ``None`` caps have
        no static shape)."""
        raise NotImplementedError

    def wrap(self, leaves):
        """Reassemble the built source from its state arrays (the mesh
        step passes them as trailing operands)."""
        raise NotImplementedError

    def describe(self) -> str:
        return self.kind


# --------------------------------------------------------------------------
# Host-side build helpers (numpy, shared by the concrete sources).
# --------------------------------------------------------------------------


def corpus_centroids(corpus, *, n_valid: int | None = None,
                     block: int = 131072) -> np.ndarray:
    """(n, m) float32 WCD centroid of every real corpus row, computed in
    ``block``-row shards so a 1M-row corpus never materializes the
    (n, hmax, m) gather."""
    ids = np.asarray(corpus.ids)
    w = np.asarray(corpus.w)
    coords = np.asarray(corpus.coords, np.float32)
    n = ids.shape[0] if n_valid is None else min(n_valid, ids.shape[0])
    out = np.empty((n, corpus.m), np.float32)
    for s in range(0, n, block):
        e = min(s + block, n)
        out[s:e] = np.einsum("bh,bhm->bm", w[s:e].astype(np.float32),
                             coords[ids[s:e]], optimize=True)
    return out


def kmeans(x: np.ndarray, k: int, iters: int, rng: np.random.Generator,
           *, block: int = 131072) -> tuple[np.ndarray, np.ndarray]:
    """Blocked Lloyd k-means: (k, m) float32 centers + (n,) assignment.

    Assignment passes stream ``block`` rows at a time (the distance
    matrix never exceeds block x k), center updates are per-dimension
    bincounts, and empty clusters reseed to random points — O(n k m)
    per iteration with O(block * k) extra memory, which is what lets
    ``EmdIndex.build`` quantize a 1M-row centroid table."""
    n, m = x.shape
    x = np.ascontiguousarray(x, np.float32)
    if n == 0:
        return np.full((k, m), EMPTY_CENTER, np.float32), \
            np.zeros((0,), np.int64)
    init = rng.choice(n, size=min(k, n), replace=False)
    c = x[init].copy()
    if len(init) < k:                      # fewer points than centers
        c = np.concatenate([c, x[rng.integers(0, n, k - len(init))]])
    assign = np.zeros(n, np.int64)

    def assign_pass():
        c2 = 0.5 * (c * c).sum(axis=1)
        for s in range(0, n, block):
            e = min(s + block, n)
            # argmin of ||x-c||^2 == argmin of c.c/2 - x.c (x^2 constant)
            assign[s:e] = np.argmin(c2[None, :] - x[s:e] @ c.T, axis=1)

    for _ in range(max(iters, 1)):
        assign_pass()
        counts = np.bincount(assign, minlength=k)
        sums = np.empty((k, m), np.float64)
        for j in range(m):
            sums[:, j] = np.bincount(assign, weights=x[:, j], minlength=k)
        live = counts > 0
        c[live] = (sums[live] / counts[live, None]).astype(np.float32)
        dead = int((~live).sum())
        if dead:
            c[~live] = x[rng.integers(0, n, dead)]
    assign_pass()                          # final labels match centers
    return c, assign


def pack_table(assign: np.ndarray, n_buckets: int, cap: int | None,
               ) -> tuple[np.ndarray, np.ndarray, int]:
    """Dense (n_buckets, cap) row table + validity mask from a bucket
    assignment. ``cap=None`` sizes to the fullest bucket (lossless);
    an explicit cap keeps each bucket's FIRST ``cap`` rows (assignment
    order) and reports the overflow drop count."""
    n = assign.shape[0]
    order = np.argsort(assign, kind="stable")
    sorted_a = assign[order]
    counts = np.bincount(assign, minlength=n_buckets)
    starts = np.zeros(n_buckets + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    within = np.arange(n, dtype=np.int64) - starts[sorted_a]
    cap_eff = max(int(counts.max()) if cap is None else int(cap), 1)
    keep = within < cap_eff
    rows = np.zeros((n_buckets, cap_eff), np.int32)
    mask = np.zeros((n_buckets, cap_eff), bool)
    rows[sorted_a[keep], within[keep]] = order[keep].astype(np.int32)
    mask[sorted_a[keep], within[keep]] = True
    return rows, mask, int(n - keep.sum())


def slot_centroids(x: np.ndarray, rows: np.ndarray, mask: np.ndarray,
                   ) -> np.ndarray:
    """(n_buckets, cap, m) float32 per-slot row centroids matching a
    :func:`pack_table` layout — the exact-WCD refine table. Dead slots
    are zero; the query-side refine masks them before ranking."""
    return (x[rows] * mask[..., None]).astype(np.float32)


# --------------------------------------------------------------------------
# Query-side (jittable) helpers.
# --------------------------------------------------------------------------


def refine_by_centroid(qc, rows, mask, cents, k: int):
    """Exact-WCD refine of gathered candidates: rank the (nq, W) probed
    rows by true centroid distance (``cents`` is their (nq, W, m) slot
    centroid gather) and keep the smallest ``k`` — the reference
    cascade's full-scan WCD stage, restricted to probed rows. Returned
    columns are ascending-distance, so any later budget truncation keeps
    the best.

    Selection is ``lax.top_k`` (sort-based), not the streaming register
    merge: ``k`` here is a stage-budget-scale count (hundreds to
    thousands) where the register merge's unrolled network blows up
    compile time, and the ranked width is fixed by the spec — never
    corpus-sized — so the unshardable sort costs probed-rows traffic
    only."""
    d = jnp.linalg.norm(cents - qc[:, None, :], axis=-1)
    d = jnp.where(mask, d, lc.PAD_DIST)
    neg, pos = jax.lax.top_k(-d, k)
    return (jnp.take_along_axis(rows, pos, axis=-1),
            jnp.take_along_axis(mask, pos, axis=-1))
