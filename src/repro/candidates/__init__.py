"""Candidate sources: pluggable, sublinear cascade stage-0.

See :mod:`repro.candidates.base` for the protocol. Importing this
package registers the built-in sources (``full_scan``, ``centroid_lsh``,
``cluster_tree``) in :data:`SOURCES`.
"""
from repro.candidates.base import (EMPTY_CENTER, SOURCES, SourceSpec,
                                   corpus_centroids, kmeans, pack_table,
                                   register_source, resolve_source)
from repro.candidates.centroid_lsh import CentroidLSHSource, CentroidLSHSpec
from repro.candidates.cluster_tree import ClusterTreeSource, ClusterTreeSpec
from repro.candidates.fullscan import FullScanSource, FullScanSpec

__all__ = [
    "EMPTY_CENTER",
    "SOURCES",
    "SourceSpec",
    "CentroidLSHSource",
    "CentroidLSHSpec",
    "ClusterTreeSource",
    "ClusterTreeSpec",
    "FullScanSource",
    "FullScanSpec",
    "corpus_centroids",
    "kmeans",
    "pack_table",
    "register_source",
    "resolve_source",
]
