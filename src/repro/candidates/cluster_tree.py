"""Hierarchical k-means tree source with triangle-inequality pruning.

The data-dependent cluster-tree idea of Ding et al. 2020
(arXiv:2002.12354) applied to WCD centroids: a ``branching``-ary tree of
``depth`` levels is fit by recursive k-means at build time; each node
stores its center and its RADIUS (max member distance), so at query
time ``max(d(q, center) - radius, 0)`` triangle-inequality lower-bounds
the distance to EVERY row under the node — the pruning signal a beam
descent keeps the ``beam`` most promising nodes by.

The tree is flattened to fixed-depth arrays (heap-layout node table,
one dense leaf-row table), so the whole descent is a ``lax.scan`` over
levels of fixed-shape gathers: jittable, query-batched, mesh-shardable,
and touching ``beam * branching`` nodes per level plus
``probes * leaf_cap`` leaf rows — never the corpus.

Not admissible (a pruned subtree can hide a true neighbor), so sourced
cascades report measured recall.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.candidates.base import (EMPTY_CENTER, SourceSpec,
                                   corpus_centroids, kmeans, pack_table,
                                   refine_by_centroid, register_source,
                                   slot_centroids)
from repro.core import lc


def _level_offset(branching: int, level: int) -> int:
    """Start index of 1-indexed ``level`` in the heap-flat node table
    (levels 1..depth stored contiguously; the root is implicit)."""
    return sum(branching ** j for j in range(1, level))


@register_source
@dataclasses.dataclass(frozen=True)
class ClusterTreeSpec(SourceSpec):
    """Build parameters of the cluster tree.

    branching/depth: tree shape — ``branching ** depth`` leaves.
    beam:            nodes kept per level during descent (<= branching,
                     so the frontier width is constant across levels).
    probes:          leaves whose rows are gathered (<= beam).
    leaf_cap:        rows kept per leaf; ``None`` = fullest leaf
                     (lossless; static checkers need an explicit cap).
    refine:          optional exact-WCD refine: keep only the ``refine``
                     centroid-nearest of the probed leaf rows (see
                     ``CentroidLSHSpec.refine``).
    kmeans_iters/seed: per-node k-means fitting knobs.
    """

    kind = "cluster_tree"
    admissible = False
    full_scan = False

    branching: int = 8
    depth: int = 2
    beam: int = 4
    probes: int = 4
    leaf_cap: int | None = None
    refine: int | None = None
    kmeans_iters: int = 4
    seed: int = 0

    def __post_init__(self) -> None:
        if self.branching < 2 or self.depth < 1:
            raise ValueError("need branching >= 2 and depth >= 1, got "
                             f"{self.branching}/{self.depth}")
        if not 1 <= self.beam <= self.branching:
            raise ValueError(
                f"beam must be in [1, branching={self.branching}] (the "
                f"descent frontier has constant width), got {self.beam}")
        if not 1 <= self.probes <= self.beam:
            raise ValueError(f"probes must be in [1, beam={self.beam}], "
                             f"got {self.probes}")
        if self.leaf_cap is not None and self.leaf_cap < 1:
            raise ValueError(f"leaf_cap must be >= 1 or None, got "
                             f"{self.leaf_cap}")
        if self.refine is not None:
            if self.refine < 1:
                raise ValueError(f"refine must be >= 1 or None, got "
                                 f"{self.refine}")
            if self.leaf_cap is not None and \
                    self.refine > self.probes * self.leaf_cap:
                raise ValueError(
                    f"refine={self.refine} exceeds the probed width "
                    f"probes*leaf_cap={self.probes * self.leaf_cap}")
        if self.kmeans_iters < 1:
            raise ValueError("kmeans_iters must be >= 1")

    @property
    def n_leaves(self) -> int:
        return self.branching ** self.depth

    @property
    def n_nodes(self) -> int:
        return _level_offset(self.branching, self.depth + 1)

    @property
    def width(self) -> int | None:
        if self.refine is not None:
            return self.refine
        return None if self.leaf_cap is None \
            else self.probes * self.leaf_cap

    def build(self, corpus, *, n_valid: int | None = None):
        """Recursive k-means over the row centroids, flattened level by
        level; radii are exact member maxima, so the descent's
        triangle-inequality bound is sound by construction."""
        rng = np.random.default_rng(self.seed)
        x = corpus_centroids(corpus, n_valid=n_valid)
        B = self.branching
        nodes = np.full((self.n_nodes, x.shape[1]), EMPTY_CENTER,
                        np.float32)
        radii = np.zeros(self.n_nodes, np.float32)
        parent = np.zeros(x.shape[0], np.int64)
        for level in range(1, self.depth + 1):
            off = _level_offset(B, level)
            child = np.zeros(x.shape[0], np.int64)
            for p in range(B ** (level - 1)):
                member = np.nonzero(parent == p)[0]
                if member.size == 0:
                    continue                 # whole subtree stays empty
                c, a = kmeans(x[member], B, self.kmeans_iters, rng)
                counts = np.bincount(a, minlength=B)
                c[counts == 0] = EMPTY_CENTER
                nodes[off + p * B:off + (p + 1) * B] = c
                child[member] = p * B + a
                dist = np.linalg.norm(x[member] - c[a], axis=1)
                np.maximum.at(radii, off + p * B + a, dist)
            parent = child
        rows, mask, dropped = pack_table(parent, self.n_leaves,
                                         self.leaf_cap)
        if self.refine is not None and \
                self.refine > self.probes * rows.shape[1]:
            raise ValueError(
                f"refine={self.refine} exceeds the probed width "
                f"probes*cap={self.probes * rows.shape[1]} of the built "
                "table")
        cents = slot_centroids(x, rows, mask) \
            if self.refine is not None else None
        return ClusterTreeSource(
            spec=self, nodes=jnp.asarray(nodes), radii=jnp.asarray(radii),
            rows=jnp.asarray(rows), mask=jnp.asarray(mask),
            cents=None if cents is None else jnp.asarray(cents),
            dropped_rows=dropped)

    def state_structs(self, m: int) -> tuple:
        if self.leaf_cap is None:
            raise ValueError(
                "leaf_cap=None sizes the leaf table to the data; the "
                "static checkers need an explicit leaf_cap to know the "
                "state shapes without building")
        out = (jax.ShapeDtypeStruct((self.n_nodes, m), jnp.float32),
               jax.ShapeDtypeStruct((self.n_nodes,), jnp.float32),
               jax.ShapeDtypeStruct((self.n_leaves, self.leaf_cap),
                                    jnp.int32),
               jax.ShapeDtypeStruct((self.n_leaves, self.leaf_cap),
                                    jnp.bool_))
        if self.refine is not None:
            out += (jax.ShapeDtypeStruct(
                (self.n_leaves, self.leaf_cap, m), jnp.float32),)
        return out

    def wrap(self, leaves):
        if self.refine is not None:
            nodes, radii, rows, mask, cents = leaves
        else:
            (nodes, radii, rows, mask), cents = leaves, None
        return ClusterTreeSource(spec=self, nodes=nodes, radii=radii,
                                 rows=rows, mask=mask, cents=cents)

    def describe(self) -> str:
        cap = "max" if self.leaf_cap is None else self.leaf_cap
        ref = "" if self.refine is None else f" r{self.refine}"
        return (f"cluster_tree[b{self.branching}^d{self.depth} "
                f"beam{self.beam} p{self.probes} cap{cap}{ref}]")


@dataclasses.dataclass(frozen=True)
class ClusterTreeSource:
    """Built tree: heap-flat node centers/radii + dense leaf-row table.
    Registered as a jax pytree (spec static)."""

    spec: ClusterTreeSpec
    nodes: jax.Array                    # (n_nodes, m) float32 centers
    radii: jax.Array                    # (n_nodes,) float32 max member dist
    rows: jax.Array                     # (n_leaves, cap) int32 row ids
    mask: jax.Array                     # (n_leaves, cap) validity
    cents: jax.Array | None = None      # (n_leaves, cap, m) refine table
    dropped_rows: int = 0

    @property
    def width(self) -> int:
        if self.spec.refine is not None:
            return self.spec.refine
        return self.spec.probes * self.rows.shape[1]

    def _bound(self, qc, node_ids):
        """Triangle-inequality descent key: ``d(q, center) - radius``.
        Clamped at zero it is a true lower bound on the centroid distance
        from the query to ANY row under the node (the admissible-pruning
        property the tests verify); the beam ranks by the UNCLAMPED
        value so overlapping balls (query inside several nodes' radii,
        where every clamped bound ties at 0) still order by how deep
        inside each ball the query sits."""
        cc = self.nodes[node_ids]
        d = jnp.linalg.norm(cc - qc[:, None, :], axis=-1)
        # EMPTY_CENTER distances overflow to +inf, which breaks the
        # min-extraction top-k (it masks winners to PAD_DIST < inf and
        # would re-pick them — duplicate beam slots). Clamp BELOW
        # PAD_DIST so empty subtrees still rank last but stay distinct.
        d = jnp.minimum(d, 0.5 * lc.PAD_DIST)
        return d - self.radii[node_ids]

    def candidates(self, corpus, q_ids, q_w, budget: int | None = None):
        """Beam descent as a ``lax.scan`` over levels, then a gather of
        the ``probes`` best leaves' rows. ``budget`` truncates to the
        best-ranked columns."""
        spec, B = self.spec, self.spec.branching
        qc = jnp.einsum("qh,qhm->qm", q_w, corpus.coords[q_ids])
        nq = q_ids.shape[0]
        # Level 1: score all B children of the (implicit) root.
        lb = self._bound(qc, jnp.broadcast_to(
            jnp.arange(B, dtype=jnp.int32)[None, :], (nq, B)))
        _, sel = lc.streaming_smallest_k(lb, spec.beam)
        ids = sel.astype(jnp.int32)          # absolute: level-1 offset is 0

        def descend(ids, offs):
            rel = ids - offs[0]
            child = (offs[1] + rel[:, :, None] * B
                     + jnp.arange(B, dtype=jnp.int32)).reshape(nq, -1)
            lb = self._bound(qc, child)
            _, pos = lc.streaming_smallest_k(lb, spec.beam)
            return jnp.take_along_axis(child, pos, axis=-1), None

        if spec.depth > 1:
            offs = jnp.asarray(
                [[_level_offset(B, lv - 1), _level_offset(B, lv)]
                 for lv in range(2, spec.depth + 1)], jnp.int32)
            ids, _ = jax.lax.scan(descend, ids, offs)
        leaf = ids - _level_offset(B, spec.depth)   # ascending-bound order
        leaf = leaf[:, :spec.probes]
        rows = self.rows[leaf].reshape(nq, -1)
        mask = self.mask[leaf].reshape(nq, -1)
        if spec.refine is not None:
            cents = self.cents[leaf].reshape(nq, rows.shape[1], -1)
            rows, mask = refine_by_centroid(qc, rows, mask, cents,
                                            spec.refine)
        if budget is not None and budget < rows.shape[1]:
            rows, mask = rows[:, :budget], mask[:, :budget]
        return rows, mask


jax.tree_util.register_dataclass(
    ClusterTreeSource,
    data_fields=["nodes", "radii", "rows", "mask", "cents"],
    meta_fields=["spec", "dropped_rows"])
