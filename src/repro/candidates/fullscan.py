"""The default source: every corpus row is a candidate.

``FullScanSpec`` exists so "scan the whole corpus" is one point in the
same protocol the sublinear sources implement — the cascade driver sees
``full_scan=True`` and runs its original stage-1 path (full-corpus
``retrieval.batch_scores`` + shard-blocked top-budget), bitwise
identical to the pre-source cascade and still the only ADMISSIBLE
source (seeing every row is what the exact-top-l guarantee needs).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.candidates.base import SourceSpec, register_source


@register_source
@dataclasses.dataclass(frozen=True)
class FullScanSpec(SourceSpec):
    """Stage-0 = the whole corpus. No build parameters, no state."""

    kind = "full_scan"
    admissible = True
    full_scan = True

    def build(self, corpus, *, n_valid: int | None = None):
        return FullScanSource(spec=self)

    def state_structs(self, m: int) -> tuple:
        return ()

    def wrap(self, leaves):
        if tuple(leaves):
            raise ValueError("FullScanSource carries no state arrays")
        return FullScanSource(spec=self)

    def describe(self) -> str:
        return "full_scan"


@dataclasses.dataclass(frozen=True)
class FullScanSource:
    """Stateless built form of :class:`FullScanSpec`. The cascade driver
    never calls :meth:`candidates` (it keeps the untouched full-corpus
    stage-1 path); the method exists so the protocol is total and tests
    can exercise the generic interface."""

    spec: FullScanSpec

    @property
    def width(self) -> int | None:
        return None                          # the corpus itself

    def candidates(self, corpus, q_ids, q_w, budget: int | None = None):
        n = corpus.n if budget is None else min(budget, corpus.n)
        nq = q_ids.shape[0]
        rows = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :],
                                (nq, n))
        return rows, jnp.ones((nq, n), bool)


jax.tree_util.register_dataclass(FullScanSource, data_fields=[],
                                 meta_fields=["spec"])
