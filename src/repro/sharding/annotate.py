"""Ambient-mesh activation sharding constraints.

Model code stays mesh-agnostic: it calls these helpers, which resolve the
current abstract mesh (set by the driver via ``jax.set_mesh``) and apply
``with_sharding_constraint`` only when an axis both exists in the mesh and
divides the dimension. Outside any mesh (unit tests, single-device smoke)
they are no-ops.

Without these, XLA's sharding propagation can replicate the batch through
the layer scan (observed: 65 GB/device temp on olmo-1b train_4k — see
EXPERIMENTS.md section Perf).
"""
from __future__ import annotations

import contextlib
import contextvars
import math

import jax
from jax.sharding import PartitionSpec as P

#: Sharding mode at trace time: "tp" (Megatron TP + FSDP hybrid) or "fsdp"
#: (pure ZeRO-3 — batch and params over the whole mesh, no TP).
_MODE = contextvars.ContextVar("repro_sharding_mode", default="tp")


@contextlib.contextmanager
def mode(name: str):
    tok = _MODE.set(name)
    try:
        yield
    finally:
        _MODE.reset(tok)


def _mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:  # noqa: BLE001
        return None
    if m is None or not m.axis_names:
        return None
    return m


def current_mesh():
    """The ambient abstract mesh (set by the driver via ``jax.set_mesh``),
    or ``None`` outside any mesh / on jax without an ambient-mesh API.
    Public surface for callers that pick schedules by mesh shape (e.g.
    ``core.lc``'s reverse-RWMD reduction)."""
    return _mesh()


def _dp_axes(mesh) -> tuple[str, ...]:
    names = (("pod", "data", "model") if _MODE.get() == "fsdp"
             else ("pod", "data"))
    return tuple(a for a in names if a in mesh.axis_names)


def _fits(dim: int, axes: tuple[str, ...], mesh) -> bool:
    return dim % math.prod(mesh.shape[a] for a in axes) == 0


def constrain(x, *spec):
    """with_sharding_constraint(x, P(*spec)) with axis validation; no-op
    outside a mesh. Axis entries not in the mesh / not dividing -> None."""
    mesh = _mesh()
    if mesh is None:
        return x
    fixed = []
    # strict=False: a spec shorter than the rank is PartitionSpec
    # shorthand for replicated trailing dims.
    for dim, ax in zip(x.shape, spec, strict=False):
        if ax is None:
            fixed.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        # progressive fallback: drop axes from the right until divisible
        # (e.g. batch 256 on a 512-device fsdp mesh -> (pod, data) only).
        while axes and not _fits(dim, axes, mesh):
            axes = axes[:-1]
        if axes:
            fixed.append(axes if len(axes) > 1 else axes[0])
        else:
            fixed.append(None)
    fixed += [None] * (x.ndim - len(fixed))
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def activations(x):
    """(B, S, d) residual-stream constraint: batch over DP, d replicated
    (Megatron convention: weights sharded, activations replicated over TP)."""
    mesh = _mesh()
    if mesh is None:
        return x
    return constrain(x, _dp_axes(mesh), None, None)


def moe_experts(x):
    """(G, E_packed, C, d) expert inputs: groups over DP, packed experts
    over "model" — the EP boundary (XLA inserts the dispatch a2a here)."""
    mesh = _mesh()
    if mesh is None:
        return x
    if _MODE.get() == "fsdp":
        return constrain(x, _dp_axes(mesh), None, None, None)
    return constrain(x, _dp_axes(mesh), "model", None, None)


def moe_tokens(x):
    """(G, E, C, d) combined expert outputs back on the DP layout."""
    mesh = _mesh()
    if mesh is None:
        return x
    return constrain(x, _dp_axes(mesh), None, None, None)


def logits(x):
    """(B, S, V): batch over DP, vocab over model — the loss is computed on
    vocab-sharded logits (never materialized unsharded). In fsdp mode the
    model axis already carries batch, so vocab stays unsharded."""
    mesh = _mesh()
    if mesh is None:
        return x
    v_ax = None if _MODE.get() == "fsdp" else "model"
    return constrain(x, _dp_axes(mesh), None, v_ax)


def emd_stacked_dist(D):
    """(v, nq, h) stacked Phase-1 distance tensor of the batched LC
    pipeline: vocabulary rows over "model" (the matmul is TP-sharded),
    queries over DP, histogram slots replicated. Pinning this layout keeps
    the one big Phase-1 product sharded both ways; the per-row top-k /
    min that follows is local."""
    mesh = _mesh()
    if mesh is None:
        return D
    return constrain(D, "model", _dp_axes(mesh), None)


def emd_shard_topk(x):
    """(nq, blocks, n/blocks) shard-blocked score view for the cascade's
    stage-wise top-budget: queries over DP, the block axis over "model"
    (each block IS one model shard's column slice, so the per-block
    ``lax.top_k`` that follows is shard-local), block contents replicated.
    The small (nq, blocks, b) winner tensors are then pinned to the
    :func:`emd_ladder` layout — the ladder merge all-gathers b rows per
    shard instead of the full (nq, n) score matrix."""
    mesh = _mesh()
    if mesh is None:
        return x
    return constrain(x, _dp_axes(mesh), "model", None)


def emd_ladder(x):
    """Phase-1 -> Phase-2 handoff arrays, query-major — the (nq, v, k)
    cost/capacity ladders, the (nq, v) masked-min row, or the (nq, v, h)
    reverse-direction slice: queries stay on their DP shards, everything
    else replicated. This IS the ladder all-gather over "model": without
    pinning the OUTPUT layout here, XLA hoists the resharding above the
    top-k and all-gathers the full (v, nq, h) distance tensor instead —
    36 GB/device at 20News scale (EXPERIMENTS.md section Perf, emd-20news
    iteration 1).

    Reduced-precision handoffs (a precision policy's bf16 storage) cross
    the resharding boundary BITCAST to a same-width unsigned integer.
    Two float-convert rewrites otherwise put full-width f32 back on the
    wire and silently undo the policy's halved collective bytes: XLA
    commutes the producer's downcast / consumer's accumulator-upcast
    pair past the all-gather (gathering the pre-downcast f32 value), and
    the CPU host-mesh oracle widens the bf16 collectives it cannot run
    natively to f32 around converts. Neither rewrite can cross a
    ``bitcast_convert_type`` (not a value-preserving float convert), and
    integer all-gathers run natively 2-byte everywhere. Float32
    handoffs take the original path (bitwise-identical graphs)."""
    mesh = _mesh()
    if mesh is None:
        return x
    if x.dtype == jax.numpy.float32:
        return constrain(x, _dp_axes(mesh), *([None] * (x.ndim - 1)))
    u = jax.lax.bitcast_convert_type(
        x, jax.numpy.dtype(f"uint{x.dtype.itemsize * 8}"))
    u = constrain(u, _dp_axes(mesh), *([None] * (u.ndim - 1)))
    return jax.lax.bitcast_convert_type(u, x.dtype)
