"""Sharding rules: parameter / optimizer / activation / cache PartitionSpecs.

The scheme (DESIGN.md section 4):
  * DP    — batch over ("pod", "data")
  * FSDP  — parameter d_model-like dims over "data" (ZeRO-3: all-gather on
            use, reduce-scatter on grad; expressed through PartitionSpecs,
            XLA SPMD inserts the collectives)
  * TP    — heads / ffn / vocab dims over "model"
  * EP    — MoE expert dim over "model"
  * SP    — long-context KV cache sequence over "model" (and "data" when
            the batch can't fill it)

Every leaf is resolved through an ordered CANDIDATE list; the first spec
whose every named dim divides evenly into the mesh is taken, ending in full
replication — so one rule table serves all 10 architectures (28-head
qwen2-vl falls through head-sharding to d_model-sharding, 8-expert mixtral
falls through EP to within-expert TP, etc.).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

Axis = str | tuple[str, ...] | None

# name -> list of (ndim, core spec) candidates, tried in order.
# Specs are written for the FULL array ndim (stacked L / group dims included).
_CAND: dict[str, list[tuple[int, tuple[Axis, ...]]]] = {
    "embed": [(2, ("model", "data")), (2, (None, "data")), (2, (None, None))],
    "lm_head": [(2, ("data", "model")), (2, (None, "model"))],
    # attention projections (stacked (L, d, h, hd) / shared (d, h, hd))
    "wq": [(4, (None, "data", "model", None)), (4, (None, "data", None, "model")),
           (4, (None, ("data", "model"), None, None)), (4, (None, "data", None, None)),
           (3, ("data", "model", None)), (3, ("data", None, "model")),
           (3, ("data", None, None))],
    "wo": [(3, (None, "model", "data")), (3, (None, None, "data")),
           (2, ("model", "data")), (2, (None, "data"))],
    # dense MLP (L, d, ff) / shared (d, ff); MoE (L, E, d, ff)
    "w_up": [(4, (None, "model", "data", None)), (4, (None, None, "data", "model")),
             (4, (None, None, "data", None)),
             (3, (None, "data", "model")), (3, (None, "data", None)),
             (2, ("data", "model")), (2, ("data", None))],
    "w_down": [(4, (None, "model", None, "data")), (4, (None, None, "model", "data")),
               (4, (None, None, None, "data")),
               (3, (None, "model", "data")), (3, (None, None, "data")),
               (2, ("model", "data")), (2, (None, "data"))],
    "router": [(3, (None, "data", None)), (2, ("data", None))],
    # SSM
    "in_proj": [(3, (None, "data", "model")), (3, (None, "data", None)),
                (2, ("data", None))],
    "out_proj": [(3, (None, "model", "data")), (3, (None, None, "data")),
                 (2, (None, "data"))],
}
_CAND["wk"] = _CAND["wq"]
_CAND["wv"] = _CAND["wq"]
_CAND["w_gate"] = _CAND["w_up"]
# Small leaves (norm scales, conv, per-head scalars): replicate.
_REPLICATED = {"scale", "norm", "conv_w", "conv_b", "a_log", "dt_bias",
               "d_skip"}


def _divides(shape: tuple[int, ...], spec: tuple[Axis, ...],
             mesh: Mesh) -> bool:
    for dim, ax in zip(shape, spec, strict=True):
        if ax is None:
            continue
        axes = (ax,) if isinstance(ax, str) else ax
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % size != 0:
            return False
    return True


def _fsdp_axis(spec: tuple[Axis, ...]) -> tuple[Axis, ...]:
    """Rewrite a TP/FSDP-hybrid candidate into pure ZeRO-3: drop TP dims,
    shard the FSDP dim over the flattened ("data", "model") axes."""
    out: list[Axis] = []
    for ax in spec:
        if ax == "data" or (isinstance(ax, tuple) and "data" in ax):
            out.append(("data", "model"))
        else:
            out.append(None)
    return tuple(out)


_MOE_LEAVES = {"w_up", "w_gate", "w_down"}


def _leaf_spec(name: str, shape: tuple[int, ...], mesh: Mesh,
               mode: str = "tp") -> P:
    if name in _REPLICATED or name not in _CAND:
        return P()
    # mode "ep": FSDP for the dense stack, native EP for expert tensors
    # (4-D moe leaves keep their "model"-sharded expert dim).
    fsdp_this = (mode == "fsdp"
                 or (mode == "ep" and not (name in _MOE_LEAVES
                                           and len(shape) == 4)))
    for ndim, spec in _CAND[name]:
        if fsdp_this:
            spec = _fsdp_axis(spec)
        if ndim == len(shape) and _divides(shape, spec, mesh):
            return P(*spec)
    return P()


def param_specs(params: Any, mesh: Mesh, mode: str = "tp") -> Any:
    """PartitionSpec tree matching ``params`` (works on shapes or arrays).

    mode="tp"   — Megatron TP over "model" + FSDP over "data" (baseline).
    mode="fsdp" — pure ZeRO-3 over the flattened mesh; no TP collectives.
    """
    def spec_of(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        return _leaf_spec(name or "", tuple(leaf.shape), mesh, mode)

    return jax.tree_util.tree_map_with_path(spec_of, params)


def param_shardings(params: Any, mesh: Mesh, mode: str = "tp") -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh, mode))


# ----------------------------------------------------------------------------
# Batch / cache specs
# ----------------------------------------------------------------------------

def _dp(mesh: Mesh) -> Axis:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if len(axes) > 1 else axes[0]


def _fits(dim: int, ax: Axis, mesh: Mesh) -> bool:
    axes = (ax,) if isinstance(ax, str) else ax
    return dim % int(np.prod([mesh.shape[a] for a in axes])) == 0


def batch_specs(batch: Any, mesh: Mesh, mode: str = "tp") -> Any:
    """Specs for a train/prefill/decode input batch pytree.

    Leading dim = global batch, sharded over DP axes when divisible
    (long_500k batch=1 falls back to replication); trailing dims replicated.
    In fsdp mode the batch spreads over the whole mesh.
    """
    dp = _dp(mesh)
    if mode == "fsdp":
        axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
        dp = axes if len(axes) > 1 else axes[0]

    def spec_of(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        axes = (dp,) if isinstance(dp, str) else tuple(dp)
        # progressive fallback: drop axes from the right until divisible
        while axes and shape[0] % int(np.prod([mesh.shape[a]
                                               for a in axes])) != 0:
            axes = axes[:-1]
        first = (axes if len(axes) > 1 else axes[0]) if axes else None
        return P(first, *([None] * (len(shape) - 1)))

    return jax.tree.map(spec_of, batch)


def cache_specs(cache: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    """Decode-cache specs.

    Attention KV leaves (L, B, S, KV, hd): batch over DP when divisible;
    KV heads over "model" when divisible, else SP — sequence over "model"
    (and over DP too when the batch can't use it, e.g. long_500k B=1).
    SSM state leaves (L, B, H, P, N) / conv (L, B, kw-1, C): batch over DP,
    SSM heads over "model".
    """
    dp = _dp(mesh)
    msize = mesh.shape.get("model", 1)

    def spec_of(path, leaf):
        shape = tuple(leaf.shape)
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        # find batch dim: first dim after the leading stack dims — caches are
        # built as (stack..., B, ...): stack depth is 1 (L or groups) or 2
        # (zamba groups x every). Identify B as the dim matching no stack.
        if name in ("k", "v"):
            # (..., B, S, KV, hd)
            lead = len(shape) - 4
            b, s, kv, hd = shape[-4:]
            b_ax = dp if _fits(b, dp, mesh) else None
            if kv % msize == 0:
                spec = (None,) * lead + (b_ax, None, "model", None)
            else:
                s_ax: Axis = "model"
                if b_ax is None and _fits(s, tuple(mesh.axis_names), mesh):
                    s_ax = tuple(mesh.axis_names)   # SP over the whole mesh
                if not _fits(s, s_ax, mesh):
                    s_ax = None
                spec = (None,) * lead + (b_ax, s_ax, None, None)
            return P(*spec)
        if name == "state":
            # (..., B, H, P, N)
            lead = len(shape) - 4
            b, h = shape[-4], shape[-3]
            b_ax = dp if _fits(b, dp, mesh) else None
            h_ax = "model" if h % msize == 0 else None
            return P(*((None,) * lead + (b_ax, h_ax, None, None)))
        if name == "conv":
            lead = len(shape) - 3
            b = shape[-3]
            b_ax = dp if _fits(b, dp, mesh) else None
            return P(*((None,) * lead + (b_ax, None, None)))
        return P()

    return jax.tree_util.tree_map_with_path(spec_of, cache)


def logits_spec(mesh: Mesh, batch: int, vocab: int) -> P:
    dp = _dp(mesh)
    b_ax = dp if _fits(batch, dp, mesh) else None
    v_ax = "model" if vocab % mesh.shape.get("model", 1) == 0 else None
    return P(b_ax, None, v_ax)
