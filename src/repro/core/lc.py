"""Linear-complexity batch engines: LC-RWMD, LC-OMR, LC-ACT (Section 5).

One query histogram is scored against ``n`` database histograms that share a
vocabulary ``V`` of ``v`` coordinates in R^m. Per-query work against the
vocabulary is done ONCE (Phase 1), then reused across all database rows
(Phases 2/3). The ``*_batched`` engines lift that amortization one level
further: a whole query batch shares one stacked Phase-1 matmul and a
query-blocked Phase-2 schedule (see the "Batched multi-query engines"
section below). Single-query structure:

  Phase 1:  D = dist(V, Qcoords)            (v, h)   -- one MXU matmul
            Z, S = row-top-k smallest of D  (v, k)
            W[i, l] = q_w[S[i, l]]          (v, k)   -- capacities
  Phase 2:  k-1 rounds of Y = min(X, w_l); X -= Y; t += Y . z_l
  Phase 3:  t += X . z_k                    (dump remainder)

TPU adaptation (DESIGN.md section 2): the database is stored in a padded
dense-bucket layout (ids, weights) instead of CSR, and Phase 2 gathers the
per-entry (cost, capacity) ladders Zg/Wg once and then runs a fused
element-wise pour — the v x h distance matrix of Phase 1 and the n x v
dense X of the paper never hit HBM at production sizes (see
``kernels/dist_topk`` and ``kernels/act_phase2`` for the fused versions;
this module is the readable pjit-able reference engine that the kernels are
validated against).

NOTE (serving callers): prefer ``repro.api.EmdIndex`` — these engines are
the thin compute layer behind its ``backend="reference"``/``"pallas"``
paths; calling them directly bypasses batching, symmetric scoring, and
backend selection.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.geometry import pairwise_dist
from repro.core.precision import pad_dist_for, resolve as resolve_precision
from repro.sharding import annotate

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Corpus:
    """Padded dense-bucket histogram database over a shared vocabulary.

    ids: (n, hmax) int32 vocabulary indices; padding slots carry weight 0.
    w:   (n, hmax) float32 L1-normalized weights (padding = 0).
    coords: (v, m) float32 vocabulary embedding vectors.
    """
    ids: Array
    w: Array
    coords: Array

    @property
    def n(self) -> int:
        return self.ids.shape[0]

    @property
    def hmax(self) -> int:
        return self.ids.shape[1]

    @property
    def v(self) -> int:
        return self.coords.shape[0]

    @property
    def m(self) -> int:
        return self.coords.shape[1]


#: Finite sentinel for padding query slots. Large enough never to be chosen
#: over a real bin, finite so 0-mass remainders cost 0.0 (inf would NaN).
#: This is the float32 value; reduced-precision arrays must use
#: ``pad_dist_for(dtype)`` instead (1e30 overflows float16 to inf and
#: rounds in bfloat16 — the sentinel must be exactly representable so a
#: downcast/upcast round-trip stays a sentinel). ``pad_dist_for(float32)``
#: is bitwise this constant.
PAD_DIST = 1e30


def _accum(x: Array) -> Array:
    """Upcast a reduced-precision handoff block to the float32
    accumulator dtype. All reductions and sentinel writes run on the
    result, never in bfloat16 storage. A no-op for float32 inputs, so
    the default policy's graph is unchanged bit for bit."""
    return x.astype(jnp.promote_types(x.dtype, jnp.float32))


def _pad_const(dtype):
    """The :func:`pad_dist_for` sentinel as a 0-d array of ``dtype``."""
    return jnp.asarray(pad_dist_for(dtype), dtype)


def mask_pad_rows(scores: Array, n_valid: int | None) -> Array:
    """Push score columns of pad rows (index >= ``n_valid``) to PAD_DIST.

    Zero-weight pad rows score 0 for the LC methods — the best possible
    score — so every top-k consumer (distributed search, cascade
    top-budget) must mask them FIRST. The single home of that invariant.
    """
    if n_valid is None or n_valid >= scores.shape[-1]:
        return scores
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, scores.ndim - 1)
    return jnp.where(col < n_valid, scores, _pad_const(scores.dtype))


_INT_MAX = jnp.int32(2**31 - 1)


def _extract_smallest_k(work: Array, col_ids: Array, k: int):
    """k rounds of masked min-extraction over the last axis: per row the
    (value, global column id) of the k smallest entries, ascending, ties
    to the lowest id. Extracted entries are masked to PAD_DIST, matching
    the historical ``smallest_k`` semantics on degenerate rows."""
    zs, ss = [], []
    for _ in range(k):
        mv = jnp.min(work, axis=-1, keepdims=True)
        cand = jnp.where(work == mv, col_ids, _INT_MAX)
        mi = jnp.min(cand, axis=-1, keepdims=True)
        work = jnp.where(col_ids == mi, _pad_const(work.dtype), work)
        zs.append(mv)
        ss.append(mi)
    return (jnp.concatenate(zs, axis=-1),
            jnp.concatenate(ss, axis=-1).astype(jnp.int32))


def _merge_smallest_k(zr: Array, sr: Array, zt: Array, st: Array, k: int):
    """Merge running (value, index) registers with a tile's top-k: k
    extraction rounds over the 2k candidates, masking exactly one winner
    position per round (indices may legitimately repeat on degenerate
    rows, so masking by id alone would drop candidates)."""
    zc = jnp.concatenate([zr, zt], axis=-1)              # (..., 2k)
    sc = jnp.concatenate([sr, st], axis=-1)
    pos = jax.lax.broadcasted_iota(jnp.int32, zc.shape, zc.ndim - 1)
    out_z, out_s = [], []
    work = zc
    for _ in range(k):
        mv = jnp.min(work, axis=-1, keepdims=True)
        is_min = work == mv
        mi = jnp.min(jnp.where(is_min, sc, _INT_MAX), axis=-1, keepdims=True)
        win = jnp.min(jnp.where(is_min & (sc == mi), pos, _INT_MAX),
                      axis=-1, keepdims=True)
        work = jnp.where(pos == win, _pad_const(work.dtype), work)
        out_z.append(mv)
        out_s.append(mi)
    return (jnp.concatenate(out_z, axis=-1),
            jnp.concatenate(out_s, axis=-1).astype(jnp.int32))


def smallest_k(D: Array, k: int):
    """Row-wise k smallest (values, indices), ascending, via k rounds of
    masked min-extraction — identical selection to ``lax.top_k`` (lowest
    index wins ties) but built from min/where/iota only, so XLA's SPMD
    partitioner shards it on batch dims. The TopK custom-call does NOT
    partition and forces a full all-gather of D (EXPERIMENTS.md section
    Perf, emd-20news iteration 2). k is small (<= 16) per the paper.

    Each extraction round re-scans the full matrix, so D is read k times;
    ``streaming_smallest_k`` performs the same selection reading D once
    and is what the engines use. This version is kept as the reference
    the streaming path is property-tested against.
    """
    col = jax.lax.broadcasted_iota(jnp.int32, D.shape, D.ndim - 1)
    return _extract_smallest_k(D, col, k)


def streaming_smallest_k(D: Array, k: int, chunk: int = 512):
    """Row-wise k smallest (values, indices) along the last axis in a
    SINGLE pass over ``D``: the columns stream through in tiles of
    ``chunk`` and k running (value, index) registers per row are updated
    by an insertion-compare merge with each tile's candidates — D is read
    once instead of k times (``smallest_k`` re-scans the full matrix per
    extraction round, which at production column counts means k trips to
    HBM). Selection is identical to ``smallest_k`` (ascending values;
    ties resolve to the lowest column index) whenever every row has at
    least k columns; when the column count fits one tile the schedule
    degenerates to a single in-register extraction with no merge.
    """
    h = D.shape[-1]
    if h <= chunk:
        return smallest_k(D, k)
    nchunks = -(-h // chunk)
    # Pad with the sentinel at column ids >= h: real columns win all ties.
    Dp = jnp.pad(D, ((0, 0),) * (D.ndim - 1) + ((0, nchunks * chunk - h),),
                 constant_values=pad_dist_for(D.dtype))
    Dt = jnp.moveaxis(Dp.reshape(D.shape[:-1] + (nchunks, chunk)), -2, 0)
    tile_col = jax.lax.broadcasted_iota(jnp.int32, Dt.shape[1:], D.ndim - 1)
    Z0, S0 = _extract_smallest_k(Dt[0], tile_col, k)

    def body(i, carry):
        d = jax.lax.dynamic_index_in_dim(Dt, i, 0, keepdims=False)
        zt, st = _extract_smallest_k(d, i * chunk + tile_col, k)
        return _merge_smallest_k(*carry, zt, st, k)

    return jax.lax.fori_loop(1, nchunks, body, (Z0, S0))


def phase1(coords: Array, q_ids: Array, q_w: Array, k: int):
    """Phase 1: fused distance + row-top-k against the query.

    Padding query slots (weight 0) are pushed to PAD_DIST so they are never
    selected as a nearest destination. Returns Z (v, k) ascending distances,
    W (v, k) matching query capacities.
    """
    qc = coords[q_ids]                                   # (h, m)
    D = pairwise_dist(coords, qc)                        # (v, h)
    D = jnp.where(q_w[None, :] > 0.0, D, pad_dist_for(D.dtype))
    Z, S = streaming_smallest_k(D, k)                    # (v, k)
    W = q_w[S]
    return Z, W


#: Dedup the Phase-1 column stack only when it exceeds the vocabulary by
#: this factor. Unique-bin stacking trades the stacked matmul's FLOPs
#: (cut by the dedup ratio) for a sort + an extra (v, nq*h) gather, so it
#: pays off on matmul-bound hardware (TPU MXU) at high duplication —
#: corpus-as-queries all-pairs batches — but NOT on small serving batches
#: (and on gather-bound CPU it is roughly a wash even at 16x; see
#: BENCH_batch.json notes).
DEDUP_STACK_RATIO = 4


def stack_query_bins(coords: Array, Q_ids: Array):
    """Phase-1 column stacking with duplicate-bin dedup.

    Stacks every query histogram's bins into one (cols, m) coordinate
    matrix for the single Phase-1 matmul. When the stack far exceeds the
    vocabulary (corpus-as-queries all-pairs batches:
    nq*h >= DEDUP_STACK_RATIO * v), the same vocabulary id appears in
    many histograms and re-embedding it per slot wastes Phase-1 FLOPs —
    so the distinct ids are computed once (``jnp.unique`` with static
    size v, the hard upper bound) and a (nq*h,) inverse map re-expands
    the deduped columns after the matmul. Returns (qc, inv) where
    ``inv`` is None on the no-dedup path.
    """
    nq, h = Q_ids.shape
    flat = Q_ids.reshape(-1)
    v = coords.shape[0]
    if nq * h < DEDUP_STACK_RATIO * v:
        return coords[flat], None
    uniq, inv = jnp.unique(flat, size=v, fill_value=0, return_inverse=True)
    return coords[uniq], inv.reshape(-1)


def phase1_stacked_dist(coords: Array, Q_ids: Array, Q_w: Array,
                        precision: str = "f32") -> Array:
    """Stacked Phase-1 distance tensor for the WHOLE query batch: one
    (v, nq*h) matmul (one MXU call instead of nq), reshaped query-major to
    (v, nq, h). Padding query slots (weight 0) are masked to the padding
    sentinel so they are never selected as a nearest destination (finite,
    so 0-mass remainders still cost 0). Mesh-aware: the tensor is pinned
    vocabulary-over-"model" / queries-over-DP
    (``annotate.emd_stacked_dist``; no-op outside a mesh), so the same
    code serves the single-host batched engines and the distributed step.

    ``precision`` (a ``core.precision`` policy name): the matmul operands
    run in the policy's compute dtype (f32 accumulation either way), the
    sentinel mask is applied in float32 with the STORAGE dtype's exactly
    representable sentinel, and the returned tensor is downcast to the
    storage dtype — halving the handoff bytes under the bf16 policies.
    The default leaves the float32 graph bitwise unchanged.
    """
    policy = resolve_precision(precision)
    nq, h = Q_ids.shape
    v = coords.shape[0]
    qc, inv = stack_query_bins(coords, Q_ids)
    compute = None if policy.compute == "float32" else policy.compute
    D = pairwise_dist(coords, qc, compute_dtype=compute)  # one stacked matmul
    if inv is not None:
        D = D[:, inv]                                    # re-expand dedup
    D = annotate.emd_stacked_dist(D.reshape(v, nq, h))
    D = jnp.where(Q_w[None] > 0.0, D, pad_dist_for(policy.storage))
    return D.astype(policy.storage)


def phase1_batched(coords: Array, Q_ids: Array, Q_w: Array, k: int,
                   precision: str = "f32"):
    """Batched Phase 1: stacked distance tensor + single-pass top-k.

    The per-query top-k runs on the (v, nq, h) view of the one stacked
    matmul. Returns the query-major handoff ladders Z, W of shape
    (nq, v, k), pinned to their Phase-2 layout (queries on their DP
    shards, ladders replicated — the all-gather over "model").

    Selection (and its winner-masking sentinel writes) runs in the
    policy's float32 accumulator dtype — the bf16 -> f32 upcast is exact,
    so the selected (value, index) registers are identical to selecting
    on the storage values — and the handoff ladders are downcast to the
    storage dtype only after it.
    """
    policy = resolve_precision(precision)
    D = phase1_stacked_dist(coords, Q_ids, Q_w, precision=precision)
    Z, S = streaming_smallest_k(_accum(D), k)            # (v, nq, k)
    Zq = annotate.emd_ladder(
        jnp.moveaxis(Z, 1, 0).astype(policy.storage))    # (nq, v, k)
    Sq = jnp.moveaxis(S, 1, 0)
    W = annotate.emd_ladder(
        jax.vmap(lambda w, s: w[s])(Q_w, Sq).astype(policy.storage))
    return Zq, W


def _min_handoff(D: Array) -> Array:
    """(nq, v) masked-min handoff from the stacked (v, nq, h) Phase-1
    tensor, on the Phase-2 layout (single derivation point, shared by the
    directional and symmetric engines so the annotation cannot diverge)."""
    return annotate.emd_ladder(jnp.min(D, axis=-1).T)


def _rev_handoff(D: Array) -> Array:
    """(nq, v, h) query-major reverse-direction handoff from the stacked
    (v, nq, h) Phase-1 tensor, on the Phase-2 layout (single derivation
    point — see :func:`_min_handoff`)."""
    return annotate.emd_ladder(jnp.moveaxis(D, 1, 0))


def phase1_min_batched(coords: Array, Q_ids: Array, Q_w: Array,
                       precision: str = "f32") -> Array:
    """Masked-min Phase-1 fast path (LC-RWMD / zero Phase-2 rounds): only
    the nearest distance is ever read, so ranked (value, index) registers
    and the W capacities are skipped entirely — one stacked matmul, one
    row-min. Returns the (nq, v) handoff on the Phase-2 layout (in the
    policy's storage dtype — a min selects an existing value, so it is
    safe directly on the reduced-precision tensor)."""
    return _min_handoff(phase1_stacked_dist(coords, Q_ids, Q_w,
                                            precision=precision))


def pour(x: Array, Zg: Array, Wg: Array, iters: int) -> Array:
    """Phases 2+3 as a single fused pour over padded entries.

    x:  (..., hmax) residual database weights.
    Zg: (..., hmax, iters+1) ascending per-entry transport costs.
    Wg: (..., hmax, iters)   per-entry capacities (query weights).
    Returns (...,) transport-cost lower bounds.

    The per-entry greedy pour is the same exclusive-prefix-sum trick as
    ``relaxations._greedy_pour_rows`` — mathematically identical to the
    paper's k-1 sequential min/subtract rounds, but reads x once.
    """
    if iters == 0:
        return jnp.sum(x * Zg[..., 0], axis=-1)
    prefix = jnp.cumsum(Wg, axis=-1) - Wg                # exclusive prefix
    r = jnp.clip(x[..., None] - prefix, 0.0, Wg)         # (..., hmax, iters)
    poured = jnp.sum(r * Zg[..., :iters], axis=(-1, -2))
    remainder = jnp.maximum(x - jnp.sum(r, axis=-1), 0.0)
    return poured + jnp.sum(remainder * Zg[..., iters], axis=-1)


@functools.partial(jax.jit, static_argnames=("iters", "use_kernels",
                                             "block_v", "block_h", "block_n"))
def lc_act_scores(corpus: Corpus, q_ids: Array, q_w: Array, iters: int = 1,
                  *, use_kernels: bool = False, block_v: int = 256,
                  block_h: int = 256, block_n: int = 256) -> Array:
    """LC-ACT: lower bounds on EMD(x_u, q) — cost of moving each database
    histogram INTO the query — for all n database rows. O(vhm + nhk).

    ``use_kernels`` routes both phases through the fused Pallas kernels
    (``kernels/dist_topk``, ``kernels/act_phase2``) with the given block
    sizes; otherwise the pjit-able jnp reference path runs.
    """
    k = iters + 1
    if use_kernels:
        from repro.kernels import ops as kops
        Z, S = kops.dist_topk(corpus.coords, corpus.coords[q_ids], k,
                              qmask=(q_w > 0.0), block_v=block_v,
                              block_h=block_h)
        W = q_w[S]
    else:
        Z, W = phase1(corpus.coords, q_ids, q_w, k)
    Zg = Z[corpus.ids]                                   # (n, hmax, k)
    if iters == 0:
        return jnp.sum(corpus.w * Zg[..., 0], axis=-1)
    Wg = W[corpus.ids][..., :iters]                      # (n, hmax, iters)
    if use_kernels:
        from repro.kernels import ops as kops
        return kops.act_phase2(corpus.w, Zg, Wg, block_n=block_n,
                               block_h=block_h)
    return pour(corpus.w, Zg, Wg, iters)


@functools.partial(jax.jit, static_argnames=("use_kernels", "block_v",
                                             "block_h"))
def lc_rwmd_scores(corpus: Corpus, q_ids: Array, q_w: Array, *,
                   use_kernels: bool = False, block_v: int = 256,
                   block_h: int = 256) -> Array:
    """LC-RWMD direction db -> query (== LC-ACT with zero Phase-2 rounds)."""
    return lc_act_scores(corpus, q_ids, q_w, iters=0, use_kernels=use_kernels,
                         block_v=block_v, block_h=block_h)


@functools.partial(jax.jit, static_argnames=("block",))
def lc_rwmd_scores_rev(corpus: Corpus, q_ids: Array, q_w: Array,
                       block: int = 256) -> Array:
    """LC-RWMD direction query -> db: each query bin ships to the nearest
    coordinate PRESENT in each database histogram.

    This is the 2017 paper's masked (min,+) sparse-dense product, expressed
    on the padded layout: for db row u and query bin j,
        c[u, j] = min over valid slots s of D[ids[u, s], j].
    Work is O(n * hmax * h) element-wise minima — the quadratic-in-h term
    LC-RWMD tolerates because it is pure VPU streaming (no matmul, no sort).
    Processed in row blocks to bound memory.
    """
    qc = corpus.coords[q_ids]                            # (h, m)
    D = pairwise_dist(corpus.coords, qc)                 # (v, h)
    valid = corpus.w > 0.0                               # (n, hmax)
    # The finite sentinel, not inf, matching the batched rev engines: an
    # all-padding db row then scores huge-but-finite instead of NaN
    # (inf * a weight-0 query bin), so the scan oracle agrees with them
    # on padded corpora.
    big = _pad_const(D.dtype)

    def one_block(ids_blk, valid_blk):
        Dg = D[ids_blk]                                  # (b, hmax, h)
        Dg = jnp.where(valid_blk[..., None], Dg, big)
        cmin = jnp.min(Dg, axis=1)                       # (b, h)
        return cmin @ q_w                                # (b,)

    n = corpus.n
    pad = (-n) % block
    ids_p = jnp.pad(corpus.ids, ((0, pad), (0, 0)))
    valid_p = jnp.pad(valid, ((0, pad), (0, 0)), constant_values=True)
    out = jax.lax.map(
        lambda args: one_block(*args),
        (ids_p.reshape(-1, block, corpus.hmax), valid_p.reshape(-1, block, corpus.hmax)),
    )
    return out.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("use_kernels", "block_v",
                                             "block_h"))
def lc_omr_scores(corpus: Corpus, q_ids: Array, q_w: Array, *,
                  use_kernels: bool = False, block_v: int = 256,
                  block_h: int = 256) -> Array:
    """LC-OMR: Algorithm 1 batched over the corpus (top-2 per vocab row)."""
    if use_kernels:
        from repro.kernels import ops as kops
        Z, S = kops.dist_topk(corpus.coords, corpus.coords[q_ids], 2,
                              qmask=(q_w > 0.0), block_v=block_v,
                              block_h=block_h)
        W = q_w[S]
    else:
        Z, W = phase1(corpus.coords, q_ids, q_w, 2)
    Zg = Z[corpus.ids]                                   # (n, hmax, 2)
    W0g = W[corpus.ids][..., 0]                          # one gather each
    x = corpus.w
    overlap = Zg[..., 0] == 0.0
    rest = x - jnp.minimum(x, W0g)
    per_entry = jnp.where(overlap, rest * Zg[..., 1], x * Zg[..., 0])
    return jnp.sum(per_entry, axis=-1)


# --------------------------------------------------------------------------
# Batched multi-query pipeline: the query batch is a first-class axis.
#
# The pipeline is three composable stages with EXPLICIT handoff arrays, so
# the single-host engines below and the distributed step in
# ``launch/search.py`` run the SAME code (the stages carry their own
# ``sharding.annotate`` constraints, which no-op outside a mesh):
#
#   stage 1  phase1_stacked_dist / phase1_batched / phase1_min_batched
#            -> handoff: (v, nq, h) D, (nq, v, k) Z/W, or (nq, v) Z0
#   stage 2  pour_blocked / pour_min_blocked / omr_reduce_blocked /
#            rev_min_blocked — query-blocked Phase 2/3 consumers of the
#            handoff; the (nq, n, hmax, k) gather tensor never
#            materializes.
#   stage 3  (callers) ranking / symmetrization on the (nq, n) scores.
# --------------------------------------------------------------------------


def _map_query_blocks(fn, arrays, nq: int, block_q: int):
    """``lax.map`` ``fn`` over blocks of ``block_q`` queries.

    Each array has leading query axis ``nq``; the axis is zero-padded to a
    block multiple (padding scores are dropped) and ``fn`` receives one
    ``(block_q, ...)`` slice per array. Output re-flattened to (nq, ...).
    A batch that fits one block runs ``fn`` directly, fully vectorized.
    """
    if nq <= block_q:
        return fn(*arrays)
    pad = (-nq) % block_q
    padded = tuple(jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
                   for a in arrays)
    blocked = tuple(a.reshape((-1, block_q) + a.shape[1:]) for a in padded)
    # Reduced-precision handoffs (a policy's bf16 storage) enter the
    # scan BITCAST to a same-width unsigned integer and come back to
    # their float dtype inside the body: the consumers upcast to their
    # f32 accumulator first thing, and XLA otherwise hoists that convert
    # out of the loop — ahead of the scan-axis resharding — so the mesh
    # gathers full-width f32 again. A float convert cannot commute
    # across the bitcast. Float32 inputs take the original body
    # (bitwise-identical graphs).
    def _fence(a):
        if jnp.issubdtype(a.dtype, jnp.floating) and a.dtype != jnp.float32:
            return jax.lax.bitcast_convert_type(
                a, jnp.dtype(f"uint{a.dtype.itemsize * 8}"))
        return a

    dtypes = tuple(a.dtype for a in blocked)
    fenced = tuple(_fence(a) for a in blocked)

    def body(args):
        return fn(*(jax.lax.bitcast_convert_type(a, dt)
                    if a.dtype != dt else a
                    for a, dt in zip(args, dtypes)))

    out = jax.lax.map(body, fenced)
    return out.reshape((-1,) + out.shape[2:])[:nq]


def _phase1_batched_dispatch(corpus: Corpus, Q_ids: Array, Q_w: Array,
                             k: int, use_kernels: bool, block_v: int,
                             block_h: int, mesh=None,
                             precision: str = "f32"):
    """Batched Phase 1 via the fused Pallas kernel or the jnp reference.
    Returns query-major Z, W of shape (nq, v, k) on the handoff layout.
    On a ``mesh`` whose axes divide (queries over DP, vocabulary over
    "model") the kernel runs inside a ``shard_map`` partitioning shim.
    ``precision`` threads the policy's compute dtype into the kernel's
    matmul operands and its storage dtype into the handoff ladders
    (``out_dtype`` — the kernel's Z block buffers shrink with it)."""
    if use_kernels:
        from repro.kernels import ops as kops
        policy = resolve_precision(precision)
        coords, qcs = corpus.coords, corpus.coords[Q_ids]
        if policy.compute != "float32":
            coords = coords.astype(policy.compute)
            qcs = qcs.astype(policy.compute)
        if mesh is not None:
            from repro.kernels import partition
            if partition.phase1_shardable(mesh, Q_ids.shape[0], corpus.v):
                Z, W = partition.dist_topk_sharded(
                    mesh, coords, qcs, Q_w, k,
                    block_v=block_v, block_h=block_h,
                    out_dtype=policy.storage)
                return annotate.emd_ladder(Z), annotate.emd_ladder(W)
        Z, S = kops.dist_topk_batched(coords, qcs, k,
                                      qmask=(Q_w > 0.0), block_v=block_v,
                                      block_h=block_h,
                                      out_dtype=policy.storage)
        W = jax.vmap(lambda w, s: w[s])(Q_w, S).astype(policy.storage)
        return annotate.emd_ladder(Z), annotate.emd_ladder(W)
    return phase1_batched(corpus.coords, Q_ids, Q_w, k, precision=precision)


def pour_min_blocked(corpus: Corpus, Z0: Array, block_q: int) -> Array:
    """Zero-round Phase 2 on the masked-min handoff: each block of
    ``block_q`` queries gathers its (bq, n, hmax) nearest-distance slice
    once and reduces. Z0: (nq, v) -> (nq, n) scores."""
    def blk(Zb):                                         # (bq, v)
        return jnp.sum(corpus.w * Zb[:, corpus.ids], axis=-1)
    return _map_query_blocks(blk, (Z0,), Z0.shape[0], block_q)


def pour_blocked(corpus: Corpus, Z: Array, W: Array, iters: int,
                 block_q: int, *, use_kernels: bool = False,
                 block_n: int = 256, block_h: int = 256, mesh=None) -> Array:
    """Query-blocked Phase 2/3 pour: (nq, v, k) handoff ladders ->
    (nq, n) lower bounds. Each block of ``block_q`` queries gathers its
    (bq, n, hmax, k) cost/capacity ladders once and pours (fused Pallas
    kernel when ``use_kernels``); ``iters=0`` degenerates to the
    nearest-cost dump of Phase 3. On a ``mesh`` whose axes divide, the
    kernel path runs inside a ``shard_map`` shim with the query blocking
    per shard (queries over DP, database rows over "model")."""
    nq = Z.shape[0]
    x = corpus.w
    if iters == 0:
        def blk0(Zb):                                    # (bq, v, k)
            return jnp.sum(x * Zb[..., 0][:, corpus.ids], axis=-1)
        return _map_query_blocks(blk0, (Z,), nq, block_q)
    W = W[..., :iters]
    if use_kernels:
        from repro.kernels import ops as kops
        if mesh is not None:
            from repro.kernels import partition
            if partition.rows_shardable(mesh, nq, corpus.n):
                return partition.act_pour_sharded(
                    mesh, corpus.ids, corpus.w, Z, W, iters,
                    block_q=block_q, block_n=block_n, block_h=block_h)

        def blk_k(Zb, Wb):
            Zg = Zb[:, corpus.ids]                       # (bq, n, hmax, k)
            Wg = Wb[:, corpus.ids]                       # (bq, n, hmax, iters)
            return kops.act_phase2_batched(x, Zg, Wg, block_n=block_n,
                                           block_h=block_h)
        return _map_query_blocks(blk_k, (Z, W), nq, block_q)

    def blk(Zb, Wb):
        # Gather in storage dtype (half the HBM traffic under bf16),
        # pour in the f32 accumulator dtype (cumsum/clip never run on
        # bf16). Both upcasts are no-ops for the default f32 policy.
        Zg = _accum(Zb[:, corpus.ids])                   # (bq, n, hmax, k)
        Wg = _accum(Wb[:, corpus.ids])                   # (bq, n, hmax, iters)
        return pour(x, Zg, Wg, iters)                    # (bq, n)
    return _map_query_blocks(blk, (Z, W), nq, block_q)


def omr_reduce_blocked(corpus: Corpus, Z: Array, W0: Array,
                       block_q: int) -> Array:
    """Query-blocked Algorithm-1 reduction on the top-2 handoff:
    Z (nq, v, 2), W0 (nq, v) -> (nq, n) LC-OMR bounds."""
    x = corpus.w

    def blk(Zb, W0b):                                    # (bq, v, 2), (bq, v)
        Zg = Zb[:, corpus.ids]                           # (bq, n, hmax, 2)
        W0g = W0b[:, corpus.ids]                         # (bq, n, hmax)
        overlap = Zg[..., 0] == 0.0
        rest = x - jnp.minimum(x, W0g)
        per_entry = jnp.where(overlap, rest * Zg[..., 1], x * Zg[..., 0])
        return jnp.sum(per_entry, axis=-1)
    return _map_query_blocks(blk, (Z, W0), Z.shape[0], block_q)


def rev_min_blocked(corpus: Corpus, Dq: Array, Q_w: Array, block: int,
                    block_q: int) -> Array:
    """Reverse-direction masked (min,+) reduction on the query-major
    distance handoff Dq (nq, v, h): for db row u and query bin j,
    c[u, j] = min over valid slots s of Dq[:, ids[u, s], j], streamed in
    (row-block, query-block) tiles so the (nq, n, hmax, h) gather never
    materializes. Invalid slots mask to PAD_DIST (finite — all-padding
    rows score huge instead of NaN when a padded query bin's weight-0
    product would otherwise hit inf * 0). Sentinel masking and the
    (min,+) contraction run in the f32 accumulator dtype (the gather
    itself stays in the handoff's storage dtype)."""
    valid = corpus.w > 0.0                               # (n, hmax)
    acc = jnp.promote_types(Dq.dtype, jnp.float32)
    big = _pad_const(acc)
    n = corpus.n
    pad = (-n) % block
    ids_b = jnp.pad(corpus.ids, ((0, pad), (0, 0))).reshape(-1, block,
                                                            corpus.hmax)
    valid_b = jnp.pad(valid, ((0, pad), (0, 0)),
                      constant_values=True).reshape(-1, block, corpus.hmax)

    def qblock(Db, Wb):                                  # (bq, v, h), (bq, h)
        def rblock(args):
            ids_blk, valid_blk = args
            Dg = _accum(Db[:, ids_blk])                  # (bq, b, hmax, h)
            Dg = jnp.where(valid_blk[None, ..., None], Dg, big)
            cmin = jnp.min(Dg, axis=2)                   # (bq, b, h)
            return jnp.einsum("qbh,qh->qb", cmin, Wb)
        out = jax.lax.map(rblock, (ids_b, valid_b))      # (nrb, bq, b)
        return jnp.moveaxis(out, 1, 0).reshape(Db.shape[0], -1)[:, :n]
    return _map_query_blocks(qblock, (Dq, Q_w), Dq.shape[0], block_q)


def rev_min_full(corpus: Corpus, Dq: Array, Q_w: Array,
                 block_q: int) -> Array:
    """Mesh variant of :func:`rev_min_blocked`: no row-blocking ``lax.map``
    (XLA SPMD cannot iterate a scan over the "model"-sharded row axis
    without gathering it), so the (bq, n, hmax, h) gather stays on the
    model shards and memory is bounded by the query blocks alone."""
    valid = corpus.w > 0.0
    acc = jnp.promote_types(Dq.dtype, jnp.float32)
    big = _pad_const(acc)

    def qblock(Db, Wb):                                  # (bq, v, h), (bq, h)
        Dg = jnp.where(valid[None, ..., None],
                       _accum(Db[:, corpus.ids]), big)
        cmin = jnp.min(Dg, axis=2)                       # (bq, n, h)
        return jnp.einsum("qnh,qh->qn", cmin, Wb)
    return _map_query_blocks(qblock, (Dq, Q_w), Dq.shape[0], block_q)


# ------------------------------------------------------- batched engines


@functools.partial(jax.jit, static_argnames=("iters", "use_kernels",
                                             "block_q", "block_v", "block_h",
                                             "block_n", "mesh", "precision"))
def lc_act_scores_batched(corpus: Corpus, Q_ids: Array, Q_w: Array,
                          iters: int = 1, *, use_kernels: bool = False,
                          block_q: int = 8, block_v: int = 256,
                          block_h: int = 256, block_n: int = 256,
                          mesh=None, precision: str = "f32") -> Array:
    """Batched LC-ACT: (nq, h) query batch -> (nq, n) lower bounds
    (stage-1 ranked Phase 1 composed with the query-blocked pour).
    ``mesh`` (static, hashable) routes the kernel path through the
    ``kernels/partition`` shard_map shims when its axes divide;
    ``precision`` (static policy name) sets the handoff storage / matmul
    compute dtypes — reductions always accumulate in float32."""
    if iters == 0 and not use_kernels:
        Z0 = phase1_min_batched(corpus.coords, Q_ids, Q_w,
                                precision=precision)
        return pour_min_blocked(corpus, Z0, block_q)
    Z, W = _phase1_batched_dispatch(corpus, Q_ids, Q_w, iters + 1,
                                    use_kernels, block_v, block_h, mesh,
                                    precision=precision)
    return pour_blocked(corpus, Z, W, iters, block_q,
                        use_kernels=use_kernels, block_n=block_n,
                        block_h=block_h, mesh=mesh)


@functools.partial(jax.jit, static_argnames=("use_kernels", "block_q",
                                             "block_v", "block_h", "mesh",
                                             "precision"))
def lc_rwmd_scores_batched(corpus: Corpus, Q_ids: Array, Q_w: Array, *,
                           use_kernels: bool = False, block_q: int = 8,
                           block_v: int = 256, block_h: int = 256,
                           mesh=None, precision: str = "f32") -> Array:
    """Batched LC-RWMD db -> query (== batched LC-ACT with zero rounds)."""
    return lc_act_scores_batched(corpus, Q_ids, Q_w, iters=0,
                                 use_kernels=use_kernels, block_q=block_q,
                                 block_v=block_v, block_h=block_h, mesh=mesh,
                                 precision=precision)


def _rows_model_sharded() -> bool:
    """True when the ambient mesh actually splits database rows over
    "model" — the precondition for :func:`rev_min_full`'s memory bound.
    On a model-size-1 mesh (or outside any mesh / on jax without an
    ambient-mesh API) the full-row gather would sit on ONE device, so
    callers must keep the row-blocked schedule instead."""
    mesh = annotate.current_mesh()
    return mesh is not None and mesh.shape.get("model", 1) > 1


@functools.partial(jax.jit, static_argnames=("block", "block_q",
                                             "precision"))
def lc_rwmd_scores_rev_batched(corpus: Corpus, Q_ids: Array, Q_w: Array,
                               block: int = 256, block_q: int = 8,
                               precision: str = "f32") -> Array:
    """Batched LC-RWMD query -> db: one stacked distance tensor for the
    WHOLE batch, streamed through the (row-block, query-block) masked
    (min,+) reduction."""
    Dq = _rev_handoff(phase1_stacked_dist(corpus.coords, Q_ids, Q_w,
                                          precision=precision))
    return rev_min_blocked(corpus, Dq, Q_w, block, block_q)


@functools.partial(jax.jit, static_argnames=("block", "block_q",
                                             "precision"))
def lc_rwmd_scores_rev_dist(corpus: Corpus, Q_ids: Array, Q_w: Array, *,
                            block: int = 256, block_q: int = 8,
                            precision: str = "f32") -> Array:
    """Mesh-sharded batched LC-RWMD query -> db: same stacked Phase 1, but
    when database rows are genuinely split over "model" the reduction
    keeps them on their shards (:func:`rev_min_full`) instead of scanning
    row blocks — the row scan would force XLA to gather the sharded rows
    onto every device. Without real model sharding (single-device default
    mesh) the full-row gather has nothing bounding it, so the row-blocked
    schedule is kept."""
    Dq = _rev_handoff(phase1_stacked_dist(corpus.coords, Q_ids, Q_w,
                                          precision=precision))
    if _rows_model_sharded():
        return rev_min_full(corpus, Dq, Q_w, block_q)
    return rev_min_blocked(corpus, Dq, Q_w, block, block_q)


@functools.partial(jax.jit, static_argnames=("use_kernels", "block_q",
                                             "block_v", "block_h", "mesh",
                                             "precision"))
def lc_omr_scores_batched(corpus: Corpus, Q_ids: Array, Q_w: Array, *,
                          use_kernels: bool = False, block_q: int = 8,
                          block_v: int = 256, block_h: int = 256,
                          mesh=None, precision: str = "f32") -> Array:
    """Batched LC-OMR: shared batched Phase 1 (top-2 per vocabulary row),
    query-blocked Algorithm-1 reduction."""
    Z, W = _phase1_batched_dispatch(corpus, Q_ids, Q_w, 2, use_kernels,
                                    block_v, block_h, mesh,
                                    precision=precision)
    return omr_reduce_blocked(corpus, Z, W[..., 0], block_q)


@functools.partial(jax.jit, static_argnames=("block", "block_q",
                                             "full_rows", "precision"))
def lc_rwmd_symmetric_scores_batched(corpus: Corpus, Q_ids: Array,
                                     Q_w: Array, *, block: int = 256,
                                     block_q: int = 8,
                                     full_rows: bool = False,
                                     precision: str = "f32") -> Array:
    """Symmetric batched LC-RWMD: max of the two directional bounds
    sharing ONE stacked Phase-1 distance tensor — the forward masked-min
    row and the reverse (min,+) reduction both read the same (v, nq, h) D
    (previously each direction recomputed the (v, nq*h) matmul).
    ``full_rows`` requests the mesh-friendly reverse reduction (honored
    only when rows are really model-sharded; see
    :func:`_rows_model_sharded`)."""
    D = phase1_stacked_dist(corpus.coords, Q_ids, Q_w, precision=precision)
    fwd = pour_min_blocked(corpus, _min_handoff(D), block_q)
    Dq = _rev_handoff(D)                                 # (nq, v, h)
    rev = (rev_min_full(corpus, Dq, Q_w, block_q)
           if full_rows and _rows_model_sharded()
           else rev_min_blocked(corpus, Dq, Q_w, block, block_q))
    return jnp.maximum(fwd, rev)


def symmetric_scores(asym: Array) -> Array:
    """Corpus-vs-corpus symmetrization: asym[a, b] = cost(move b into a);
    the paper's symmetric measure is max(asym, asym.T)."""
    return jnp.maximum(asym, asym.T)


# --------------------------------------------------------------------------
# LC-ICT: the paper's tightest linear-complexity bound (Algorithm 2), as a
# batch engine. ICT pours each database entry's mass through the FULL
# cost-sorted ladder of query bins (not a truncated top-k), so Phase 2 is a
# per-entry sort over h instead of the k-register selection — O(n h log h)
# on top of the shared Phase-1 distance work. It exists here primarily as a
# cascade rescorer: too expensive for full-corpus serving, ideal on a
# pruned candidate set.
# --------------------------------------------------------------------------


def ict_pour(x: Array, cap: Array, C: Array) -> Array:
    """Full-ladder greedy pour (Algorithm 2) over padded entries.

    x:   (..., hmax) residual database weights.
    cap: (..., hmax, h) per-edge capacities (query weights; 0 at padded
         query bins).
    C:   (..., hmax, h) transport costs (PAD_DIST at padded query bins, so
         they sort last and their zero capacity absorbs nothing).
    Returns (...,) transport-cost bounds.

    L1-normalized histograms leave no remainder; any float residue is
    dumped at the max FINITE cost — never at PAD_DIST, where a ~1e-7
    cumsum residue would explode to ~1e23 (the reason this does not reuse
    ``relaxations.ict_dir``'s last-slot dump on padded layouts).
    """
    order = jnp.argsort(C, axis=-1)
    cost_sorted = jnp.take_along_axis(C, order, axis=-1)
    cap_sorted = jnp.take_along_axis(cap, order, axis=-1)
    prefix = jnp.cumsum(cap_sorted, axis=-1) - cap_sorted  # exclusive prefix
    r = jnp.clip(x[..., None] - prefix, 0.0, cap_sorted)
    poured = jnp.sum(r * cost_sorted, axis=-1)
    remainder = jnp.maximum(x - jnp.sum(r, axis=-1), 0.0)
    # Strict < : sentinel entries (written in any storage dtype, upcast
    # or not) compare >= their dtype's pad value and are excluded.
    dump = jnp.max(jnp.where(C < _pad_const(C.dtype), C, 0.0), axis=-1)
    return jnp.sum(poured + remainder * dump, axis=-1)


def _ict_caps(Q_w: Array, shape) -> Array:
    """Broadcast (…, h) query weights to the (…, hmax, h) per-edge
    capacity tensor of :func:`ict_pour`."""
    return jnp.broadcast_to(Q_w[..., None, :], shape)


@jax.jit
def lc_ict_scores(corpus: Corpus, q_ids: Array, q_w: Array) -> Array:
    """LC-ICT: Algorithm 2 batched over the corpus — lower bounds on
    EMD(x_u, q) for all n database rows, O(vhm + n hmax h log h)."""
    qc = corpus.coords[q_ids]                            # (h, m)
    D = pairwise_dist(corpus.coords, qc)                 # (v, h)
    D = jnp.where(q_w[None, :] > 0.0, D, pad_dist_for(D.dtype))
    C = D[corpus.ids]                                    # (n, hmax, h)
    return ict_pour(corpus.w, _ict_caps(q_w, C.shape), C)


def ict_reduce_blocked(corpus: Corpus, Dq: Array, Q_w: Array,
                       block_q: int) -> Array:
    """Query-blocked Algorithm-2 reduction on the query-major distance
    handoff Dq (nq, v, h) -> (nq, n) LC-ICT bounds. Each block of
    ``block_q`` queries gathers its (bq, n, hmax, h) cost tensor once and
    pours through the full sorted ladder."""
    def blk(Db, Wb):                                     # (bq, v, h), (bq, h)
        # Gather in storage dtype, sort + pour the ladder in the f32
        # accumulator (the sort itself is exact in any dtype, but the
        # pour's cumulative caps are not).
        C = _accum(Db[:, corpus.ids])                    # (bq, n, hmax, h)
        cap = _ict_caps(Wb[:, None, :], C.shape)
        return ict_pour(corpus.w, cap, C)
    return _map_query_blocks(blk, (Dq, Q_w), Dq.shape[0], block_q)


@functools.partial(jax.jit, static_argnames=("block_q", "precision"))
def lc_ict_scores_batched(corpus: Corpus, Q_ids: Array, Q_w: Array, *,
                          block_q: int = 8, precision="f32") -> Array:
    """Batched LC-ICT: one stacked Phase-1 distance tensor for the whole
    query batch, query-blocked full-ladder pour."""
    Dq = _rev_handoff(phase1_stacked_dist(corpus.coords, Q_ids, Q_w,
                                          precision=precision))
    return ict_reduce_blocked(corpus, Dq, Q_w, block_q)


# --------------------------------------------------------------------------
# Candidate-compacted Phase 2/3: the cascade's gather-compaction layer.
#
# A prune-and-rescore cascade (``repro.cascade``) scores stage s+1 only on
# the (nq, b) candidate rows that survived stage s. Phase 1 is UNCHANGED —
# the vocabulary-vs-query work never depends on which database rows are
# scored — so candidate compaction is purely a Phase-2/3 concern: the same
# blocked consumers as above, but gathering each query's own (b, hmax)
# sub-corpus (``corpus.ids[cand[u]]`` — Corpus row-slicing with the padded
# layout preserved, no re-bucketing needed) instead of all n rows. Per
# (query, row) the reduction order matches the full-corpus consumers, so
# scores agree with the full engines at the candidate rows — bitwise for
# the ladder consumers; ``rev_min_cand_blocked`` is within an ulp of
# ``rev_min_blocked`` (its reduction is mul+sum where the full engine
# contracts with einsum — see the comment there for why).
#
# ``use_kernels`` routes each consumer through the fused candidate Pallas
# kernels (``kernels/cand_pour``): the per-query ladder gather and the
# reduction run in ONE launch on a query-batch x candidate-block grid, so
# the (nq, b, hmax, k) gather tensor never hits HBM (only the small
# (nq, b, hmax) sub-corpus ids/weights do). Phase 1 stays the shared jnp
# pipeline on BOTH paths and the kernels reuse the reference reductions
# (``pour``/``ict_pour``/the expressions below) on identically shaped
# tiles, so kernel and reference candidate scores agree to within a
# few ulps (the gather itself is bitwise-exact) — the conformance contract
# ``tests/test_cand_kernels.py`` pins, with the residual ulp explained
# in ``kernels/cand_pour``'s module docstring.
# --------------------------------------------------------------------------


def gather_per_query(A: Array, idx: Array) -> Array:
    """Per-query gather: A (bq, v, ...) indexed on axis 1 by each query's
    own idx (bq, b, hmax) -> (bq, b, hmax, ...)."""
    return jax.vmap(lambda a, i: a[i])(A, idx)


def pour_min_cand_blocked(corpus: Corpus, Z0: Array, cand: Array,
                          block_q: int, *, use_kernels: bool = False,
                          block_n: int = 128, block_v: int = 256,
                          mesh=None) -> Array:
    """Candidate-compacted zero-round pour: Z0 (nq, v), cand (nq, b)
    -> (nq, b) scores at the candidate rows. ``use_kernels`` fuses the
    gather + dump into one ``kernels/cand_pour`` launch (block_n
    candidate rows x block_v vocabulary rows per tile); on a ``mesh``
    whose DP axes divide the query batch, the launch runs inside a
    ``shard_map`` shim with the sub-corpus gather kept outside."""
    if use_kernels:
        from repro.kernels import ops as kops
        if mesh is not None:
            from repro.kernels import partition
            if partition.queries_shardable(mesh, Z0.shape[0]):
                idsg, xg = corpus.ids[cand], corpus.w[cand]

                def sh_k(idsb, xb, Zb):
                    return kops.cand_pour(idsb, xb, Zb[..., None], None, 0,
                                          block_n=block_n, block_v=block_v)
                return partition.cand_sharded(mesh, sh_k, (idsg, xg, Z0),
                                              block_q)

        def blk_k(Zb, cb):                               # (bq, v), (bq, b)
            return kops.cand_pour(corpus.ids[cb], corpus.w[cb],
                                  Zb[..., None], None, 0, block_n=block_n,
                                  block_v=block_v)
        return _map_query_blocks(blk_k, (Z0, cand), Z0.shape[0], block_q)

    def blk(Zb, cb):                                     # (bq, v), (bq, b)
        Zg = gather_per_query(Zb, corpus.ids[cb])       # (bq, b, hmax)
        return jnp.sum(corpus.w[cb] * Zg, axis=-1)
    return _map_query_blocks(blk, (Z0, cand), Z0.shape[0], block_q)


def pour_cand_blocked(corpus: Corpus, Z: Array, W: Array, cand: Array,
                      iters: int, block_q: int, *,
                      use_kernels: bool = False, block_n: int = 128,
                      block_v: int = 256, mesh=None) -> Array:
    """Candidate-compacted Phase 2/3 pour: (nq, v, k) handoff ladders +
    (nq, b) candidate rows -> (nq, b) lower bounds. ``use_kernels`` fuses
    gather + pour into one ``kernels/cand_pour`` launch (``shard_map``
    shim on a dividing ``mesh``)."""
    nq = Z.shape[0]
    if iters == 0:
        return pour_min_cand_blocked(corpus, Z[..., 0], cand, block_q,
                                     use_kernels=use_kernels,
                                     block_n=block_n, block_v=block_v,
                                     mesh=mesh)
    W = W[..., :iters]
    if use_kernels:
        from repro.kernels import ops as kops
        if mesh is not None:
            from repro.kernels import partition
            if partition.queries_shardable(mesh, nq):
                idsg, xg = corpus.ids[cand], corpus.w[cand]

                def sh_k(idsb, xb, Zb, Wb):
                    return kops.cand_pour(idsb, xb, Zb, Wb, iters,
                                          block_n=block_n, block_v=block_v)
                return partition.cand_sharded(mesh, sh_k, (idsg, xg, Z, W),
                                              block_q)

        def blk_k(Zb, Wb, cb):
            return kops.cand_pour(corpus.ids[cb], corpus.w[cb], Zb, Wb,
                                  iters, block_n=block_n, block_v=block_v)
        return _map_query_blocks(blk_k, (Z, W, cand), nq, block_q)

    def blk(Zb, Wb, cb):
        ids_g = corpus.ids[cb]                           # (bq, b, hmax)
        # Gather in storage dtype; pour in the f32 accumulator (its
        # capacity cumsum must not round in bf16).
        Zg = _accum(gather_per_query(Zb, ids_g))        # (bq, b, hmax, k)
        Wg = _accum(gather_per_query(Wb, ids_g))        # (bq, b, hmax, iters)
        return pour(corpus.w[cb], Zg, Wg, iters)         # (bq, b)
    return _map_query_blocks(blk, (Z, W, cand), nq, block_q)


def omr_reduce_cand_blocked(corpus: Corpus, Z: Array, W0: Array,
                            cand: Array, block_q: int, *,
                            use_kernels: bool = False, block_n: int = 128,
                            block_v: int = 256, mesh=None) -> Array:
    """Candidate-compacted Algorithm-1 reduction: Z (nq, v, 2), W0 (nq, v),
    cand (nq, b) -> (nq, b) LC-OMR bounds. ``use_kernels`` fuses gather +
    reduce into one ``kernels/cand_pour`` launch (mode "omr";
    ``shard_map`` shim on a dividing ``mesh``)."""
    if use_kernels:
        from repro.kernels import ops as kops
        if mesh is not None:
            from repro.kernels import partition
            if partition.queries_shardable(mesh, Z.shape[0]):
                idsg, xg = corpus.ids[cand], corpus.w[cand]

                def sh_k(idsb, xb, Zb, W0b):
                    return kops.cand_omr(idsb, xb, Zb, W0b,
                                         block_n=block_n, block_v=block_v)
                return partition.cand_sharded(mesh, sh_k, (idsg, xg, Z, W0),
                                              block_q)

        def blk_k(Zb, W0b, cb):
            return kops.cand_omr(corpus.ids[cb], corpus.w[cb], Zb, W0b,
                                 block_n=block_n, block_v=block_v)
        return _map_query_blocks(blk_k, (Z, W0, cand), Z.shape[0], block_q)

    def blk(Zb, W0b, cb):
        ids_g = corpus.ids[cb]
        x = corpus.w[cb]                                 # (bq, b, hmax)
        Zg = gather_per_query(Zb, ids_g)                # (bq, b, hmax, 2)
        W0g = gather_per_query(W0b, ids_g)              # (bq, b, hmax)
        overlap = Zg[..., 0] == 0.0
        rest = x - jnp.minimum(x, W0g)
        per_entry = jnp.where(overlap, rest * Zg[..., 1], x * Zg[..., 0])
        return jnp.sum(per_entry, axis=-1)
    return _map_query_blocks(blk, (Z, W0, cand), Z.shape[0], block_q)


def rev_min_cand_blocked(corpus: Corpus, Dq: Array, Q_w: Array,
                         cand: Array, block_q: int, *,
                         use_kernels: bool = False, block_n: int = 128,
                         block_v: int = 256, mesh=None) -> Array:
    """Candidate-compacted reverse masked (min,+) reduction: Dq (nq, v, h),
    cand (nq, b) -> (nq, b) reverse-RWMD bounds. ``use_kernels`` fuses
    gather + reduce into one ``kernels/cand_pour`` launch (``shard_map``
    shim on a dividing ``mesh``)."""
    if use_kernels:
        from repro.kernels import ops as kops
        if mesh is not None:
            from repro.kernels import partition
            if partition.queries_shardable(mesh, Dq.shape[0]):
                idsg, xg = corpus.ids[cand], corpus.w[cand]

                def sh_k(idsb, xb, Db, Wb):
                    return kops.cand_rev_min(idsb, xb, Db, Wb,
                                             block_n=block_n,
                                             block_v=block_v)
                return partition.cand_sharded(mesh, sh_k,
                                              (idsg, xg, Dq, Q_w), block_q)

        def blk_k(Db, Wb, cb):
            return kops.cand_rev_min(corpus.ids[cb], corpus.w[cb], Db, Wb,
                                     block_n=block_n, block_v=block_v)
        return _map_query_blocks(blk_k, (Dq, Q_w, cand), Dq.shape[0],
                                 block_q)
    # Mask + reduce in the accumulator dtype: the pad-row sentinel is
    # written in f32 (never a reduced storage dtype) so it cannot round
    # into the range of real costs.
    acc = jnp.promote_types(Dq.dtype, jnp.float32)
    big = _pad_const(acc)

    def blk(Db, Wb, cb):                                 # (bq, v, h), (bq, h)
        ids_g = corpus.ids[cb]                           # (bq, b, hmax)
        valid = corpus.w[cb] > 0.0
        Dg = _accum(gather_per_query(Db, ids_g))        # (bq, b, hmax, h)
        Dg = jnp.where(valid[..., None], Dg, big)
        cmin = jnp.min(Dg, axis=2)                       # (bq, b, h)
        # multiply + last-axis reduce, NOT einsum: the dot op's
        # accumulation varies with the row count, so a candidate-blocked
        # kernel tile could never reproduce its bits — this form is
        # block-shape-stable (the kernel conformance contract).
        return jnp.sum(cmin * Wb[:, None, :], axis=-1)
    return _map_query_blocks(blk, (Dq, Q_w, cand), Dq.shape[0], block_q)


def ict_reduce_cand_blocked(corpus: Corpus, Dq: Array, Q_w: Array,
                            cand: Array, block_q: int, *,
                            use_kernels: bool = False, block_n: int = 128,
                            block_v: int = 256, mesh=None) -> Array:
    """Candidate-compacted Algorithm-2 reduction: Dq (nq, v, h),
    cand (nq, b) -> (nq, b) LC-ICT bounds. ``use_kernels`` fuses gather +
    full-ladder pour into one ``kernels/cand_pour`` launch (``shard_map``
    shim on a dividing ``mesh``); both paths run :func:`ict_pour`, so the
    remainder dump stays at the max FINITE cost (a PAD_DIST dump would
    explode float residue — see its doc)."""
    if use_kernels:
        from repro.kernels import ops as kops
        if mesh is not None:
            from repro.kernels import partition
            if partition.queries_shardable(mesh, Dq.shape[0]):
                idsg, xg = corpus.ids[cand], corpus.w[cand]

                def sh_k(idsb, xb, Db, Wb):
                    return kops.cand_ict(idsb, xb, Db, Wb, block_n=block_n,
                                         block_v=block_v)
                return partition.cand_sharded(mesh, sh_k,
                                              (idsg, xg, Dq, Q_w), block_q)

        def blk_k(Db, Wb, cb):
            return kops.cand_ict(corpus.ids[cb], corpus.w[cb], Db, Wb,
                                 block_n=block_n, block_v=block_v)
        return _map_query_blocks(blk_k, (Dq, Q_w, cand), Dq.shape[0],
                                 block_q)

    def blk(Db, Wb, cb):
        ids_g = corpus.ids[cb]
        # Gather in storage dtype; ladder pour in the f32 accumulator.
        C = _accum(gather_per_query(Db, ids_g))         # (bq, b, hmax, h)
        cap = _ict_caps(Wb[:, None, :], C.shape)
        return ict_pour(corpus.w[cb], cap, C)
    return _map_query_blocks(blk, (Dq, Q_w, cand), Dq.shape[0], block_q)


# ------------------------------------------- candidate-compacted engines
#
# ``use_kernels`` on every engine routes Phase 2/3 through the fused
# candidate kernels; Phase 1 is the SAME shared jnp pipeline either way
# (the kernels fuse only the gather + reduction), so both paths score
# identically to within a few ulps at the candidate rows.


def _pin_handoff(*arrays):
    """Materialize the Phase-1 handoff behind an optimization barrier.

    The kernel and reference candidate paths are DIFFERENT XLA programs;
    without the barrier XLA fuses Phase 1 into whichever consumer follows
    (e.g. FMA-contracting the distance expansion), and the two programs
    would start from handoffs that already disagree by ulps. With it,
    Phase 1 compiles as the same standalone subgraph in both, so the
    handoff bits are identical and any residual divergence is confined
    to the reference reduction's own per-program fusion (a few ulps; see
    ``kernels/cand_pour``). Cost: the handoff materializes — it is the
    explicit stage boundary anyway (tiny next to Phase 2's reads).
    """
    out = jax.lax.optimization_barrier(arrays)
    return out[0] if len(arrays) == 1 else out


_CAND_STATIC = ("use_kernels", "block_q", "block_n", "block_v", "mesh",
                "precision")


@functools.partial(jax.jit, static_argnames=("iters",) + _CAND_STATIC)
def lc_act_scores_cand(corpus: Corpus, Q_ids: Array, Q_w: Array,
                       cand: Array, iters: int = 1, *,
                       use_kernels: bool = False, block_q: int = 8,
                       block_n: int = 128, block_v: int = 256,
                       mesh=None, precision="f32") -> Array:
    """Candidate-compacted batched LC-ACT: (nq, h) queries scored against
    each query's own (b,) candidate rows -> (nq, b)."""
    kw = dict(use_kernels=use_kernels, block_n=block_n, block_v=block_v,
              mesh=mesh)
    if iters == 0:
        Z0 = _pin_handoff(phase1_min_batched(corpus.coords, Q_ids, Q_w,
                                             precision=precision))
        return pour_min_cand_blocked(corpus, Z0, cand, block_q, **kw)
    Z, W = _pin_handoff(*phase1_batched(corpus.coords, Q_ids, Q_w,
                                        iters + 1, precision=precision))
    return pour_cand_blocked(corpus, Z, W, cand, iters, block_q, **kw)


@functools.partial(jax.jit, static_argnames=_CAND_STATIC)
def lc_rwmd_scores_cand(corpus: Corpus, Q_ids: Array, Q_w: Array,
                        cand: Array, *, use_kernels: bool = False,
                        block_q: int = 8, block_n: int = 128,
                        block_v: int = 256, mesh=None,
                        precision="f32") -> Array:
    """Candidate-compacted batched LC-RWMD db -> query."""
    return lc_act_scores_cand(corpus, Q_ids, Q_w, cand, iters=0,
                              use_kernels=use_kernels, block_q=block_q,
                              block_n=block_n, block_v=block_v, mesh=mesh,
                              precision=precision)


@functools.partial(jax.jit, static_argnames=_CAND_STATIC)
def lc_rwmd_scores_rev_cand(corpus: Corpus, Q_ids: Array, Q_w: Array,
                            cand: Array, *, use_kernels: bool = False,
                            block_q: int = 8, block_n: int = 128,
                            block_v: int = 256, mesh=None,
                            precision="f32") -> Array:
    """Candidate-compacted batched LC-RWMD query -> db."""
    Dq = _pin_handoff(_rev_handoff(phase1_stacked_dist(
        corpus.coords, Q_ids, Q_w, precision=precision)))
    return rev_min_cand_blocked(corpus, Dq, Q_w, cand, block_q,
                                use_kernels=use_kernels, block_n=block_n,
                                block_v=block_v, mesh=mesh)


@functools.partial(jax.jit, static_argnames=_CAND_STATIC)
def lc_omr_scores_cand(corpus: Corpus, Q_ids: Array, Q_w: Array,
                       cand: Array, *, use_kernels: bool = False,
                       block_q: int = 8, block_n: int = 128,
                       block_v: int = 256, mesh=None,
                       precision="f32") -> Array:
    """Candidate-compacted batched LC-OMR."""
    Z, W = _pin_handoff(*phase1_batched(corpus.coords, Q_ids, Q_w, 2,
                                        precision=precision))
    return omr_reduce_cand_blocked(corpus, Z, W[..., 0], cand, block_q,
                                   use_kernels=use_kernels, block_n=block_n,
                                   block_v=block_v, mesh=mesh)


@functools.partial(jax.jit, static_argnames=_CAND_STATIC)
def lc_ict_scores_cand(corpus: Corpus, Q_ids: Array, Q_w: Array,
                       cand: Array, *, use_kernels: bool = False,
                       block_q: int = 8, block_n: int = 128,
                       block_v: int = 256, mesh=None,
                       precision="f32") -> Array:
    """Candidate-compacted batched LC-ICT (the cascade's tight rescorer)."""
    Dq = _pin_handoff(_rev_handoff(phase1_stacked_dist(
        corpus.coords, Q_ids, Q_w, precision=precision)))
    return ict_reduce_cand_blocked(corpus, Dq, Q_w, cand, block_q,
                                   use_kernels=use_kernels, block_n=block_n,
                                   block_v=block_v, mesh=mesh)
