"""Linear-complexity batch engines: LC-RWMD, LC-OMR, LC-ACT (Section 5).

One query histogram is scored against ``n`` database histograms that share a
vocabulary ``V`` of ``v`` coordinates in R^m. Per-query work against the
vocabulary is done ONCE (Phase 1), then reused across all database rows
(Phases 2/3):

  Phase 1:  D = dist(V, Qcoords)            (v, h)   -- one MXU matmul
            Z, S = row-top-k smallest of D  (v, k)
            W[i, l] = q_w[S[i, l]]          (v, k)   -- capacities
  Phase 2:  k-1 rounds of Y = min(X, w_l); X -= Y; t += Y . z_l
  Phase 3:  t += X . z_k                    (dump remainder)

TPU adaptation (DESIGN.md section 2): the database is stored in a padded
dense-bucket layout (ids, weights) instead of CSR, and Phase 2 gathers the
per-entry (cost, capacity) ladders Zg/Wg once and then runs a fused
element-wise pour — the v x h distance matrix of Phase 1 and the n x v
dense X of the paper never hit HBM at production sizes (see
``kernels/dist_topk`` and ``kernels/act_phase2`` for the fused versions;
this module is the readable pjit-able reference engine that the kernels are
validated against).

NOTE (serving callers): prefer ``repro.api.EmdIndex`` — these engines are
the thin compute layer behind its ``backend="reference"``/``"pallas"``
paths; calling them directly bypasses batching, symmetric scoring, and
backend selection.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.geometry import pairwise_dist

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Corpus:
    """Padded dense-bucket histogram database over a shared vocabulary.

    ids: (n, hmax) int32 vocabulary indices; padding slots carry weight 0.
    w:   (n, hmax) float32 L1-normalized weights (padding = 0).
    coords: (v, m) float32 vocabulary embedding vectors.
    """
    ids: Array
    w: Array
    coords: Array

    @property
    def n(self) -> int:
        return self.ids.shape[0]

    @property
    def hmax(self) -> int:
        return self.ids.shape[1]

    @property
    def v(self) -> int:
        return self.coords.shape[0]

    @property
    def m(self) -> int:
        return self.coords.shape[1]


#: Finite sentinel for padding query slots. Large enough never to be chosen
#: over a real bin, finite so 0-mass remainders cost 0.0 (inf would NaN).
PAD_DIST = 1e30


def smallest_k(D: Array, k: int):
    """Row-wise k smallest (values, indices), ascending, via k rounds of
    masked min-extraction — identical selection to ``lax.top_k`` (lowest
    index wins ties) but built from min/where/iota only, so XLA's SPMD
    partitioner shards it on batch dims. The TopK custom-call does NOT
    partition and forces a full all-gather of D (EXPERIMENTS.md section
    Perf, emd-20news iteration 2). k is small (<= 16) per the paper.
    """
    col = jax.lax.broadcasted_iota(jnp.int32, D.shape, D.ndim - 1)
    work = D
    zs, ss = [], []
    for _ in range(k):
        mv = jnp.min(work, axis=-1, keepdims=True)
        cand = jnp.where(work == mv, col, jnp.int32(2**31 - 1))
        mi = jnp.min(cand, axis=-1, keepdims=True)
        work = jnp.where(col == mi, jnp.asarray(PAD_DIST, D.dtype), work)
        zs.append(mv)
        ss.append(mi)
    return (jnp.concatenate(zs, axis=-1),
            jnp.concatenate(ss, axis=-1).astype(jnp.int32))


def phase1(coords: Array, q_ids: Array, q_w: Array, k: int):
    """Phase 1: fused distance + row-top-k against the query.

    Padding query slots (weight 0) are pushed to PAD_DIST so they are never
    selected as a nearest destination. Returns Z (v, k) ascending distances,
    W (v, k) matching query capacities.
    """
    qc = coords[q_ids]                                   # (h, m)
    D = pairwise_dist(coords, qc)                        # (v, h)
    D = jnp.where(q_w[None, :] > 0.0, D, PAD_DIST)
    Z, S = smallest_k(D, k)                              # (v, k)
    W = q_w[S]
    return Z, W


def pour(x: Array, Zg: Array, Wg: Array, iters: int) -> Array:
    """Phases 2+3 as a single fused pour over padded entries.

    x:  (..., hmax) residual database weights.
    Zg: (..., hmax, iters+1) ascending per-entry transport costs.
    Wg: (..., hmax, iters)   per-entry capacities (query weights).
    Returns (...,) transport-cost lower bounds.

    The per-entry greedy pour is the same exclusive-prefix-sum trick as
    ``relaxations._greedy_pour_rows`` — mathematically identical to the
    paper's k-1 sequential min/subtract rounds, but reads x once.
    """
    if iters == 0:
        return jnp.sum(x * Zg[..., 0], axis=-1)
    prefix = jnp.cumsum(Wg, axis=-1) - Wg                # exclusive prefix
    r = jnp.clip(x[..., None] - prefix, 0.0, Wg)         # (..., hmax, iters)
    poured = jnp.sum(r * Zg[..., :iters], axis=(-1, -2))
    remainder = jnp.maximum(x - jnp.sum(r, axis=-1), 0.0)
    return poured + jnp.sum(remainder * Zg[..., iters], axis=-1)


@functools.partial(jax.jit, static_argnames=("iters", "use_kernels",
                                             "block_v", "block_h", "block_n"))
def lc_act_scores(corpus: Corpus, q_ids: Array, q_w: Array, iters: int = 1,
                  *, use_kernels: bool = False, block_v: int = 256,
                  block_h: int = 256, block_n: int = 256) -> Array:
    """LC-ACT: lower bounds on EMD(x_u, q) — cost of moving each database
    histogram INTO the query — for all n database rows. O(vhm + nhk).

    ``use_kernels`` routes both phases through the fused Pallas kernels
    (``kernels/dist_topk``, ``kernels/act_phase2``) with the given block
    sizes; otherwise the pjit-able jnp reference path runs.
    """
    k = iters + 1
    if use_kernels:
        from repro.kernels import ops as kops
        Z, S = kops.dist_topk(corpus.coords, corpus.coords[q_ids], k,
                              qmask=(q_w > 0.0), block_v=block_v,
                              block_h=block_h)
        W = q_w[S]
    else:
        Z, W = phase1(corpus.coords, q_ids, q_w, k)
    Zg = Z[corpus.ids]                                   # (n, hmax, k)
    if iters == 0:
        return jnp.sum(corpus.w * Zg[..., 0], axis=-1)
    Wg = W[corpus.ids][..., :iters]                      # (n, hmax, iters)
    if use_kernels:
        from repro.kernels import ops as kops
        return kops.act_phase2(corpus.w, Zg, Wg, block_n=block_n,
                               block_h=block_h)
    return pour(corpus.w, Zg, Wg, iters)


@functools.partial(jax.jit, static_argnames=("use_kernels", "block_v",
                                             "block_h"))
def lc_rwmd_scores(corpus: Corpus, q_ids: Array, q_w: Array, *,
                   use_kernels: bool = False, block_v: int = 256,
                   block_h: int = 256) -> Array:
    """LC-RWMD direction db -> query (== LC-ACT with zero Phase-2 rounds)."""
    return lc_act_scores(corpus, q_ids, q_w, iters=0, use_kernels=use_kernels,
                         block_v=block_v, block_h=block_h)


@functools.partial(jax.jit, static_argnames=("block",))
def lc_rwmd_scores_rev(corpus: Corpus, q_ids: Array, q_w: Array,
                       block: int = 256) -> Array:
    """LC-RWMD direction query -> db: each query bin ships to the nearest
    coordinate PRESENT in each database histogram.

    This is the 2017 paper's masked (min,+) sparse-dense product, expressed
    on the padded layout: for db row u and query bin j,
        c[u, j] = min over valid slots s of D[ids[u, s], j].
    Work is O(n * hmax * h) element-wise minima — the quadratic-in-h term
    LC-RWMD tolerates because it is pure VPU streaming (no matmul, no sort).
    Processed in row blocks to bound memory.
    """
    qc = corpus.coords[q_ids]                            # (h, m)
    D = pairwise_dist(corpus.coords, qc)                 # (v, h)
    valid = corpus.w > 0.0                               # (n, hmax)
    big = jnp.asarray(jnp.inf, D.dtype)

    def one_block(ids_blk, valid_blk):
        Dg = D[ids_blk]                                  # (b, hmax, h)
        Dg = jnp.where(valid_blk[..., None], Dg, big)
        cmin = jnp.min(Dg, axis=1)                       # (b, h)
        return cmin @ q_w                                # (b,)

    n = corpus.n
    pad = (-n) % block
    ids_p = jnp.pad(corpus.ids, ((0, pad), (0, 0)))
    valid_p = jnp.pad(valid, ((0, pad), (0, 0)), constant_values=True)
    out = jax.lax.map(
        lambda args: one_block(*args),
        (ids_p.reshape(-1, block, corpus.hmax), valid_p.reshape(-1, block, corpus.hmax)),
    )
    return out.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("use_kernels", "block_v",
                                             "block_h"))
def lc_omr_scores(corpus: Corpus, q_ids: Array, q_w: Array, *,
                  use_kernels: bool = False, block_v: int = 256,
                  block_h: int = 256) -> Array:
    """LC-OMR: Algorithm 1 batched over the corpus (top-2 per vocab row)."""
    if use_kernels:
        from repro.kernels import ops as kops
        Z, S = kops.dist_topk(corpus.coords, corpus.coords[q_ids], 2,
                              qmask=(q_w > 0.0), block_v=block_v,
                              block_h=block_h)
        W = q_w[S]
    else:
        Z, W = phase1(corpus.coords, q_ids, q_w, 2)
    Z0g = Z[corpus.ids][..., 0]
    Z1g = Z[corpus.ids][..., 1]
    W0g = W[corpus.ids][..., 0]
    x = corpus.w
    overlap = Z0g == 0.0
    rest = x - jnp.minimum(x, W0g)
    per_entry = jnp.where(overlap, rest * Z1g, x * Z0g)
    return jnp.sum(per_entry, axis=-1)


def symmetric_scores(asym: Array) -> Array:
    """Corpus-vs-corpus symmetrization: asym[a, b] = cost(move b into a);
    the paper's symmetric measure is max(asym, asym.T)."""
    return jnp.maximum(asym, asym.T)
