"""Ground-distance utilities shared by every EMD approximation.

The paper uses the Euclidean (L2) distance between embedding vectors as the
transportation cost. Cost matrices are built with the stable
``||a-b||^2 = ||a||^2 + ||b||^2 - 2 a.b`` expansion so the heavy term is a
single MXU matmul.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

#: RELATIVE zero-snap: squared distances below ZERO_SNAP^2 x (|a|^2+|b|^2)
#: collapse to exact 0. The matmul expansion leaves ~eps_f32 x (|a|^2+|b|^2)
#: cancellation residue on IDENTICAL coordinates, which would silently
#: defeat the paper's zero-cost overlap detection (OMR, Theorem 3). Exact
#: zeros are load-bearing here; the threshold scales with the coordinate
#: magnitude because the rounding error does.
ZERO_SNAP = 1e-3


def pairwise_sqdist(a: Array, b: Array) -> Array:
    """Squared Euclidean distances between rows of ``a`` (na,m) and ``b`` (nb,m)."""
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    a2 = jnp.sum(a * a, axis=-1, keepdims=True)          # (na, 1)
    b2 = jnp.sum(b * b, axis=-1, keepdims=True).T        # (1, nb)
    cross = a @ b.T                                      # (na, nb) — MXU
    return jnp.maximum(a2 + b2 - 2.0 * cross, 0.0)


def pairwise_dist(a: Array, b: Array, snap: float = ZERO_SNAP, *,
                  compute_dtype=None) -> Array:
    """Euclidean distances between rows of ``a`` and ``b``; near-zero values
    collapse to exact 0 relative to pair magnitude (see ZERO_SNAP).

    ``compute_dtype`` (a precision policy's compute role) drops only the
    MXU matmul OPERANDS to the reduced dtype — the contraction still
    accumulates into float32 (``preferred_element_type``), and the norm
    terms, snap, and sqrt stay in the input dtype, so the result dtype
    is unchanged. ``None`` (or the input dtype itself) leaves the
    original graph untouched.
    """
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    a2 = jnp.sum(a * a, axis=-1, keepdims=True)          # (na, 1)
    b2 = jnp.sum(b * b, axis=-1, keepdims=True).T        # (1, nb)
    if compute_dtype is not None and jnp.dtype(compute_dtype) != a.dtype:
        cross = jax.lax.dot_general(
            a.astype(compute_dtype), b.astype(compute_dtype),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).astype(a.dtype)
    else:
        cross = a @ b.T
    d2 = jnp.maximum(a2 + b2 - 2.0 * cross, 0.0)
    if snap:
        d2 = jnp.where(d2 < snap * snap * (a2 + b2), 0.0, d2)
    return jnp.sqrt(d2)


def l1_normalize(w: Array, axis: int = -1, eps: float = 1e-12) -> Array:
    """L1-normalize nonnegative weights along ``axis`` (histogram convention)."""
    s = jnp.sum(w, axis=axis, keepdims=True)
    return w / jnp.maximum(s, eps)


def l2_normalize(x: Array, axis: int = -1, eps: float = 1e-12) -> Array:
    n = jnp.linalg.norm(x, axis=axis, keepdims=True)
    return x / jnp.maximum(n, eps)
