"""Top-l nearest-neighbor retrieval on top of the LC engines.

This is the paper's evaluation harness (Section 6) as a library: every
document is a query, scored against the whole corpus, and precision@top-l
is the fraction of retrieved neighbors sharing the query's label.

``search`` runs one query; ``all_pairs_scores`` builds the full n x n
asymmetric bound matrix (vmapped/jitted) and symmetrizes it with the max of
the two directions, exactly as the paper evaluates. The distributed version
(database rows sharded over the ``data`` mesh axis, vocabulary matmul over
``model``) lives in ``launch/search.py``.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import lc

Array = jax.Array

METHODS: dict[str, Callable] = {}


def _register(name):
    def deco(fn):
        METHODS[name] = fn
        return fn
    return deco


@_register("rwmd")
def _rwmd(corpus, q_ids, q_w, **kw):
    return lc.lc_rwmd_scores(corpus, q_ids, q_w)


@_register("omr")
def _omr(corpus, q_ids, q_w, **kw):
    return lc.lc_omr_scores(corpus, q_ids, q_w)


@_register("act")
def _act(corpus, q_ids, q_w, iters: int = 1, **kw):
    return lc.lc_act_scores(corpus, q_ids, q_w, iters=iters, **kw)


@_register("bow")
def _bow(corpus, q_ids, q_w, **kw):
    """Bag-of-words cosine baseline (O(nh)): 1 - cosine as a distance."""
    qv = jnp.zeros((corpus.v,), corpus.w.dtype).at[q_ids].add(q_w)
    qv = qv / jnp.maximum(jnp.linalg.norm(qv), 1e-12)
    wn = corpus.w / jnp.maximum(
        jnp.linalg.norm(corpus.w, axis=1, keepdims=True), 1e-12)
    dots = jnp.sum(wn * qv[corpus.ids], axis=1)
    return 1.0 - dots


@_register("wcd")
def _wcd(corpus, q_ids, q_w, **kw):
    """Word Centroid Distance baseline (O(nm))."""
    qc = q_w @ corpus.coords[q_ids]                       # (m,)
    cent = jax.vmap(lambda i, w: w @ corpus.coords[i])(corpus.ids, corpus.w)
    return jnp.linalg.norm(cent - qc[None, :], axis=1)


def search(corpus: lc.Corpus, q_ids: Array, q_w: Array, top_l: int,
           method: str = "act", **kw):
    """Return (scores, indices) of the top-l most similar database rows."""
    scores = METHODS[method](corpus, q_ids, q_w, **kw)
    neg, idx = jax.lax.top_k(-scores, top_l)
    return -neg, idx


@functools.partial(jax.jit, static_argnames=("method", "iters"))
def all_pairs_scores(corpus: lc.Corpus, method: str = "act",
                     iters: int = 1) -> Array:
    """n x n symmetric bound matrix over the corpus (paper's eval mode).

    asym[a, b] = directional bound of moving histogram b INTO histogram a
    (query = row a); symmetric = max(asym, asym^T).
    """
    def one(q_ids, q_w):
        if method == "act":
            return lc.lc_act_scores(corpus, q_ids, q_w, iters=iters)
        return METHODS[method](corpus, q_ids, q_w)

    asym = jax.lax.map(lambda ab: one(*ab), (corpus.ids, corpus.w))
    if method in ("bow", "wcd"):
        return asym                                     # already symmetric
    return lc.symmetric_scores(asym)


def precision_at_l(scores: Array, labels: Array, top_l: int) -> float:
    """Average precision@top-l: fraction of each row's top-l neighbors
    (self excluded) sharing the row's label."""
    n = scores.shape[0]
    big = jnp.asarray(jnp.finfo(scores.dtype).max, scores.dtype)
    s = jnp.where(jnp.eye(n, dtype=bool), big, scores)     # exclude self
    _, idx = jax.lax.top_k(-s, top_l)                      # (n, top_l)
    same = labels[idx] == labels[:, None]
    return float(jnp.mean(jnp.mean(same.astype(jnp.float32), axis=1)))
