"""Top-l nearest-neighbor retrieval on top of the LC engines.

This is the paper's evaluation harness (Section 6) as a library: every
document is a query, scored against the whole corpus, and precision@top-l
is the fraction of retrieved neighbors sharing the query's label.

The registry is typed: every entry is a :class:`MethodSpec` whose scorer
shares one uniform signature, so ``search`` / ``all_pairs_scores`` jit
end-to-end with no per-method special-casing. ``search`` runs one query;
``batch_scores`` runs a query batch through the method's multi-query
engine (Phase 1 amortized across the batch; ``engine="scan"`` falls back
to the per-query graph); ``all_pairs_scores`` builds the full n x n bound
matrix and symmetrizes it unless the method is already symmetric.

NOTE (serving callers): prefer ``repro.api.EmdIndex`` — the unified facade
over this module, the Pallas kernels, and the distributed engine in
``launch/search.py``. This module remains the thin compute layer the
facade composes.
"""
from __future__ import annotations

import dataclasses
import functools
from collections.abc import Callable
from typing import Protocol

import jax
import jax.numpy as jnp

from repro.core import lc

Array = jax.Array


class ScoreFn(Protocol):
    """Uniform scorer signature every registered method implements.

    Scores ONE query histogram (``q_ids``/``q_w``, each ``(h,)``) against
    all ``n`` database rows, returning ``(n,)`` distances (lower = more
    similar). Methods ignore the kwargs they do not use.
    """

    def __call__(self, corpus: lc.Corpus, q_ids: Array, q_w: Array, *,
                 iters: int = 1, use_kernels: bool = False,
                 block_v: int = 256, block_h: int = 256, block_n: int = 256,
                 rev_block: int = 256, block_q: int = 8) -> Array: ...


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """Typed registry entry for one scoring method.

    name:        registry key (``EngineConfig.method`` value).
    paper_name:  the paper's name for the measure (README table).
    fn:          uniform-signature scorer (see :class:`ScoreFn`).
    symmetric:   True if the measure is symmetric in (query, db) — its
                 all-pairs matrix needs no max-symmetrization (BoW, WCD).
    uses_iters:  True if ``iters`` changes the result (LC-ACT only).
    supports_kernels: True if ``use_kernels=True`` routes through the
                 fused Pallas kernels rather than silently falling back.
    reverse:     registry name of the opposite-direction bound, if one
                 exists (rwmd <-> rwmd_rev); enables the per-query
                 symmetric path ``symmetric_query_scores``.
    batch_fn:    multi-query scorer with the same uniform signature but
                 (nq, h) queries -> (nq, n) scores; amortizes Phase 1
                 across the batch. ``None`` falls back to the scanned
                 per-query path in ``batch_scores``.
    dist_fn:     mesh-specialized multi-query scorer for the distributed
                 step (``engine="dist"``). Most methods distribute via
                 their ``batch_fn`` unchanged — the lc pipeline stages
                 carry their own ``sharding.annotate`` constraints — so
                 ``None`` means "use batch_fn". Register one only when
                 the single-host schedule fights the partitioner (e.g.
                 rwmd_rev's row-block scan would gather the
                 model-sharded rows).
    symmetric_batch_fn: multi-query scorer for the SYMMETRIC measure
                 (max of both directions) that shares intermediate work
                 between the two — rwmd/rwmd_rev share one stacked
                 Phase-1 distance tensor. ``None`` falls back to two
                 directional calls.
    dist_out:    PartitionSpec-shaped hint for the (nq, n) score matrix
                 the distributed step emits; ``"data"`` resolves to the
                 mesh's DP axes. Default: queries on their data shards,
                 database columns on the model shards that scored them.
    cand_fn:     candidate-compacted multi-query scorer for the cascade
                 subsystem (``repro.cascade``): same uniform signature
                 plus a ``cand`` (nq, b) array of per-query candidate row
                 ids, returning (nq, b) scores at those rows only (Phase 1
                 unchanged, Phase 2/3 gather-compacted). ``None`` means
                 the method cannot serve as a cascade stage or rescorer.
                 For the five LC methods ``use_kernels=True`` routes the
                 gather + reduction through the fused candidate Pallas
                 kernels (``kernels/cand_pour``; ``block_n`` tiles the
                 candidate rows, ``block_v`` the in-kernel gather),
                 matching the reference path to within a few ulps
                 (gather exact, same reduction formulas); the bow/wcd baselines
                 have no kernel form and ignore the flag.
    """
    name: str
    paper_name: str
    fn: ScoreFn
    symmetric: bool = False
    uses_iters: bool = False
    supports_kernels: bool = False
    reverse: str | None = None
    batch_fn: ScoreFn | None = None
    dist_fn: ScoreFn | None = None
    symmetric_batch_fn: ScoreFn | None = None
    dist_out: tuple = ("data", "model")
    cand_fn: Callable | None = None


METHODS: dict[str, MethodSpec] = {}


def _register(name: str, *, paper_name: str, symmetric: bool = False,
              uses_iters: bool = False, supports_kernels: bool = False,
              reverse: str | None = None) -> Callable[[ScoreFn], ScoreFn]:
    def deco(fn: ScoreFn) -> ScoreFn:
        METHODS[name] = MethodSpec(name=name, paper_name=paper_name, fn=fn,
                                   symmetric=symmetric, uses_iters=uses_iters,
                                   supports_kernels=supports_kernels,
                                   reverse=reverse)
        return fn
    return deco


def _register_batch(name: str) -> Callable[[ScoreFn], ScoreFn]:
    """Attach a batched (multi-query) scorer to an already-registered
    method; the single-query ``fn`` stays the parity oracle."""
    def deco(fn: ScoreFn) -> ScoreFn:
        METHODS[name] = dataclasses.replace(METHODS[name], batch_fn=fn)
        return fn
    return deco


def _register_dist(name: str) -> Callable[[ScoreFn], ScoreFn]:
    """Attach a mesh-specialized scorer (``engine="dist"`` override)."""
    def deco(fn: ScoreFn) -> ScoreFn:
        METHODS[name] = dataclasses.replace(METHODS[name], dist_fn=fn)
        return fn
    return deco


def _register_cand(name: str) -> Callable[[Callable], Callable]:
    """Attach a candidate-compacted scorer (cascade stages/rescoring)."""
    def deco(fn: Callable) -> Callable:
        METHODS[name] = dataclasses.replace(METHODS[name], cand_fn=fn)
        return fn
    return deco


def _register_symmetric_batch(*names: str) -> Callable[[ScoreFn], ScoreFn]:
    """Attach a shared-work symmetric multi-query scorer to a
    reverse-linked method pair (both directions symmetrize identically)."""
    def deco(fn: ScoreFn) -> ScoreFn:
        for name in names:
            METHODS[name] = dataclasses.replace(METHODS[name],
                                                symmetric_batch_fn=fn)
        return fn
    return deco


@_register("rwmd", paper_name="LC-RWMD (db -> query)",
           supports_kernels=True, reverse="rwmd_rev")
def _rwmd(corpus, q_ids, q_w, *, use_kernels=False, block_v=256,
          block_h=256, **_):
    return lc.lc_rwmd_scores(corpus, q_ids, q_w, use_kernels=use_kernels,
                             block_v=block_v, block_h=block_h)


@_register_batch("rwmd")
def _rwmd_batch(corpus, q_ids, q_w, *, use_kernels=False, block_v=256,
                block_h=256, block_q=8, mesh=None, precision="f32", **_):
    return lc.lc_rwmd_scores_batched(corpus, q_ids, q_w,
                                     use_kernels=use_kernels,
                                     block_q=block_q, block_v=block_v,
                                     block_h=block_h, mesh=mesh,
                                     precision=precision)


@_register("rwmd_rev", paper_name="LC-RWMD (query -> db)", reverse="rwmd")
def _rwmd_rev(corpus, q_ids, q_w, *, rev_block=256, **_):
    return lc.lc_rwmd_scores_rev(corpus, q_ids, q_w, block=rev_block)


@_register_batch("rwmd_rev")
def _rwmd_rev_batch(corpus, q_ids, q_w, *, rev_block=256, block_q=8,
                    precision="f32", **_):
    return lc.lc_rwmd_scores_rev_batched(corpus, q_ids, q_w, block=rev_block,
                                         block_q=block_q,
                                         precision=precision)


@_register_dist("rwmd_rev")
def _rwmd_rev_dist(corpus, q_ids, q_w, *, rev_block=256, block_q=8,
                   precision="f32", **_):
    return lc.lc_rwmd_scores_rev_dist(corpus, q_ids, q_w, block=rev_block,
                                      block_q=block_q, precision=precision)


@_register_cand("rwmd")
def _rwmd_cand(corpus, q_ids, q_w, cand, *, block_q=8, use_kernels=False,
               block_n=256, block_v=256, mesh=None, precision="f32", **_):
    return lc.lc_rwmd_scores_cand(corpus, q_ids, q_w, cand, block_q=block_q,
                                  use_kernels=use_kernels, block_n=block_n,
                                  block_v=block_v, mesh=mesh,
                                  precision=precision)


@_register_cand("rwmd_rev")
def _rwmd_rev_cand(corpus, q_ids, q_w, cand, *, block_q=8, use_kernels=False,
                   block_n=256, block_v=256, mesh=None, precision="f32",
                   **_):
    return lc.lc_rwmd_scores_rev_cand(corpus, q_ids, q_w, cand,
                                      block_q=block_q,
                                      use_kernels=use_kernels,
                                      block_n=block_n, block_v=block_v,
                                      mesh=mesh, precision=precision)


@_register_symmetric_batch("rwmd", "rwmd_rev")
def _rwmd_symmetric_batch(corpus, q_ids, q_w, *, rev_block=256, block_q=8,
                          dist=False, precision="f32", **_):
    # ``dist`` is passed by batch_scores(engine="dist") only: it selects
    # the mesh-friendly full-row reverse reduction.
    return lc.lc_rwmd_symmetric_scores_batched(corpus, q_ids, q_w,
                                               block=rev_block,
                                               block_q=block_q,
                                               full_rows=dist,
                                               precision=precision)


@_register("omr", paper_name="LC-OMR", supports_kernels=True)
def _omr(corpus, q_ids, q_w, *, use_kernels=False, block_v=256,
         block_h=256, **_):
    return lc.lc_omr_scores(corpus, q_ids, q_w, use_kernels=use_kernels,
                            block_v=block_v, block_h=block_h)


@_register_batch("omr")
def _omr_batch(corpus, q_ids, q_w, *, use_kernels=False, block_v=256,
               block_h=256, block_q=8, mesh=None, precision="f32", **_):
    return lc.lc_omr_scores_batched(corpus, q_ids, q_w,
                                    use_kernels=use_kernels, block_q=block_q,
                                    block_v=block_v, block_h=block_h,
                                    mesh=mesh, precision=precision)


@_register_cand("omr")
def _omr_cand(corpus, q_ids, q_w, cand, *, block_q=8, use_kernels=False,
              block_n=256, block_v=256, mesh=None, precision="f32", **_):
    return lc.lc_omr_scores_cand(corpus, q_ids, q_w, cand, block_q=block_q,
                                 use_kernels=use_kernels, block_n=block_n,
                                 block_v=block_v, mesh=mesh,
                                 precision=precision)


@_register("act", paper_name="LC-ACT-k", uses_iters=True,
           supports_kernels=True)
def _act(corpus, q_ids, q_w, *, iters=1, use_kernels=False, block_v=256,
         block_h=256, block_n=256, **_):
    return lc.lc_act_scores(corpus, q_ids, q_w, iters=iters,
                            use_kernels=use_kernels, block_v=block_v,
                            block_h=block_h, block_n=block_n)


@_register_batch("act")
def _act_batch(corpus, q_ids, q_w, *, iters=1, use_kernels=False,
               block_v=256, block_h=256, block_n=256, block_q=8, mesh=None,
               precision="f32", **_):
    return lc.lc_act_scores_batched(corpus, q_ids, q_w, iters=iters,
                                    use_kernels=use_kernels, block_q=block_q,
                                    block_v=block_v, block_h=block_h,
                                    block_n=block_n, mesh=mesh,
                                    precision=precision)


@_register_cand("act")
def _act_cand(corpus, q_ids, q_w, cand, *, iters=1, block_q=8,
              use_kernels=False, block_n=256, block_v=256, mesh=None,
              precision="f32", **_):
    return lc.lc_act_scores_cand(corpus, q_ids, q_w, cand, iters=iters,
                                 block_q=block_q, use_kernels=use_kernels,
                                 block_n=block_n, block_v=block_v, mesh=mesh,
                                 precision=precision)


@_register("ict", paper_name="LC-ICT (db -> query)")
def _ict(corpus, q_ids, q_w, **_):
    """The paper's tightest linear-complexity bound (Algorithm 2, full
    cost-sorted ladder): Theorem 2 places it between ACT-k and exact EMD.
    Too heavy for full-corpus serving (per-entry sort over h); its role
    is the cascade rescorer on pruned candidate sets."""
    return lc.lc_ict_scores(corpus, q_ids, q_w)


@_register_batch("ict")
def _ict_batch(corpus, q_ids, q_w, *, block_q=8, precision="f32", **_):
    return lc.lc_ict_scores_batched(corpus, q_ids, q_w, block_q=block_q,
                                    precision=precision)


@_register_cand("ict")
def _ict_cand(corpus, q_ids, q_w, cand, *, block_q=8, use_kernels=False,
              block_n=256, block_v=256, mesh=None, precision="f32", **_):
    return lc.lc_ict_scores_cand(corpus, q_ids, q_w, cand, block_q=block_q,
                                 use_kernels=use_kernels, block_n=block_n,
                                 block_v=block_v, mesh=mesh,
                                 precision=precision)


@_register("bow", paper_name="BoW cosine baseline", symmetric=True)
def _bow(corpus, q_ids, q_w, **_):
    """Bag-of-words cosine baseline (O(nh)): 1 - cosine as a distance."""
    qv = jnp.zeros((corpus.v,), corpus.w.dtype).at[q_ids].add(q_w)
    qv = qv / jnp.maximum(jnp.linalg.norm(qv), 1e-12)
    wn = corpus.w / jnp.maximum(
        jnp.linalg.norm(corpus.w, axis=1, keepdims=True), 1e-12)
    dots = jnp.sum(wn * qv[corpus.ids], axis=1)
    return 1.0 - dots


@_register_batch("bow")
def _bow_batch(corpus, q_ids, q_w, **_):
    nq = q_ids.shape[0]
    qv = jnp.zeros((nq, corpus.v), corpus.w.dtype)
    qv = qv.at[jnp.arange(nq)[:, None], q_ids].add(q_w)
    qv = qv / jnp.maximum(jnp.linalg.norm(qv, axis=1, keepdims=True), 1e-12)
    wn = corpus.w / jnp.maximum(
        jnp.linalg.norm(corpus.w, axis=1, keepdims=True), 1e-12)
    dots = jnp.einsum("us,qus->qu", wn, qv[:, corpus.ids])
    return 1.0 - dots


@_register_cand("bow")
def _bow_cand(corpus, q_ids, q_w, cand, **_):
    nq = q_ids.shape[0]
    qv = jnp.zeros((nq, corpus.v), corpus.w.dtype)
    qv = qv.at[jnp.arange(nq)[:, None], q_ids].add(q_w)
    qv = qv / jnp.maximum(jnp.linalg.norm(qv, axis=1, keepdims=True), 1e-12)
    w_c = corpus.w[cand]                                  # (nq, b, hmax)
    wn = w_c / jnp.maximum(
        jnp.linalg.norm(w_c, axis=-1, keepdims=True), 1e-12)
    qg = lc.gather_per_query(qv, corpus.ids[cand])
    return 1.0 - jnp.einsum("qbs,qbs->qb", wn, qg)


def _corpus_centroids(corpus) -> Array:
    """(n, m) weight-centroid of every corpus row."""
    return jax.vmap(lambda i, w: w @ corpus.coords[i])(corpus.ids, corpus.w)


@_register("wcd", paper_name="Word Centroid Distance baseline",
           symmetric=True)
def _wcd(corpus, q_ids, q_w, **_):
    """Word Centroid Distance baseline (O(nm))."""
    qc = q_w @ corpus.coords[q_ids]                       # (m,)
    return jnp.linalg.norm(_corpus_centroids(corpus) - qc[None, :], axis=1)


@_register_batch("wcd")
def _wcd_batch(corpus, q_ids, q_w, **_):
    qc = jnp.einsum("qh,qhm->qm", q_w, corpus.coords[q_ids])
    cent = _corpus_centroids(corpus)
    return jnp.linalg.norm(cent[None, :] - qc[:, None], axis=-1)


@_register_cand("wcd")
def _wcd_cand(corpus, q_ids, q_w, cand, **_):
    # Centroids only for the (nq, b) candidate rows — materializing all
    # n through the gather would waste O(n/b) of the work.
    qc = jnp.einsum("qh,qhm->qm", q_w, corpus.coords[q_ids])
    cent = jnp.einsum("qbh,qbhm->qbm", corpus.w[cand],
                      corpus.coords[corpus.ids[cand]])
    return jnp.linalg.norm(cent - qc[:, None, :], axis=-1)


_STATIC_KW = ("method", "iters", "use_kernels", "block_v", "block_h",
              "block_n", "rev_block", "block_q", "precision")


@functools.partial(jax.jit,
                   static_argnames=("method", "symmetric") + _STATIC_KW[1:])
def query_scores(corpus: lc.Corpus, q_ids: Array, q_w: Array, *,
                 method: str = "act", symmetric: bool = False,
                 iters: int = 1, use_kernels: bool = False,
                 block_v: int = 256, block_h: int = 256, block_n: int = 256,
                 rev_block: int = 256, block_q: int = 8,
                 precision: str = "f32") -> Array:
    """One query against the whole database, jitted end-to-end.

    ``symmetric=True`` returns the paper's symmetric measure for a single
    query: the max of the two directional bounds (requires a method with a
    registered ``reverse``, i.e. rwmd / rwmd_rev).

    ``precision`` is accepted for kwarg parity with :func:`batch_scores`,
    but the single-query engines are the full-precision parity oracle —
    they always run float32, so it has no effect here.
    """
    spec = METHODS[method]
    kw = dict(iters=iters, use_kernels=use_kernels, block_v=block_v,
              block_h=block_h, block_n=block_n, rev_block=rev_block)
    fwd = spec.fn(corpus, q_ids, q_w, **kw)
    if not symmetric or spec.symmetric:
        return fwd
    if spec.reverse is None:
        raise ValueError(
            f"method {method!r} has no reverse direction registered; "
            "per-query symmetric scoring needs one (use rwmd/rwmd_rev)")
    return jnp.maximum(fwd, METHODS[spec.reverse].fn(corpus, q_ids, q_w, **kw))


@functools.partial(jax.jit,
                   static_argnames=("method", "symmetric", "engine", "mesh")
                   + _STATIC_KW[1:])
def batch_scores(corpus: lc.Corpus, q_ids: Array, q_w: Array, *,
                 method: str = "act", symmetric: bool = False,
                 engine: str = "batched", iters: int = 1,
                 use_kernels: bool = False, block_v: int = 256,
                 block_h: int = 256, block_n: int = 256,
                 rev_block: int = 256, block_q: int = 8, mesh=None,
                 precision: str = "f32") -> Array:
    """Batch of queries ``(nq, h)`` -> ``(nq, n)`` score matrix.

    ``engine="batched"`` (default) dispatches to the method's multi-query
    engine: Phase 1 (the vocabulary-vs-query distance work) runs ONCE for
    the whole batch and Phase 2/3 stream query blocks of ``block_q`` —
    this is the serving hot path. ``engine="dist"`` is the same pipeline
    with mesh-specialized overrides where registered (``spec.dist_fn``);
    it is what the distributed step in ``launch/search.py`` traces — the
    pipeline stages carry their own sharding constraints, so on a single
    host it scores identically to ``batched``. ``mesh`` (static, hashable)
    additionally routes the kernel path through the ``kernels/partition``
    shard_map shims when its axes divide the problem — required for
    COMPILED ``pallas_call`` on a mesh, which has no SPMD partitioning
    rule of its own. ``engine="scan"`` is the
    fallback that runs each query through the exact single-query compute
    graph via ``lax.map``, matching a Python loop of ``query_scores``
    calls bit-for-bit; use it to verify the batched engine or on methods
    without a registered ``batch_fn``.
    """
    if engine not in ("batched", "scan", "dist"):
        raise ValueError(f"unknown engine {engine!r}; "
                         "one of ('batched', 'scan', 'dist')")
    spec = METHODS[method]
    if engine != "scan" and spec.batch_fn is not None:
        def pick(s):
            return (s.dist_fn or s.batch_fn) if engine == "dist" \
                else s.batch_fn
        kw = dict(iters=iters, use_kernels=use_kernels, block_v=block_v,
                  block_h=block_h, block_n=block_n, rev_block=rev_block,
                  block_q=block_q, mesh=mesh, precision=precision)
        if symmetric and not spec.symmetric:
            if spec.reverse is None:
                raise ValueError(
                    f"method {method!r} has no reverse direction "
                    "registered; symmetric scoring needs one (use "
                    "rwmd/rwmd_rev)")
            if spec.symmetric_batch_fn is not None and not use_kernels:
                # Shared-work symmetric engine: both directions read one
                # stacked Phase-1 distance tensor (kernel Phase 1 has no
                # shared form — fall through to two directional calls).
                return spec.symmetric_batch_fn(corpus, q_ids, q_w,
                                               dist=(engine == "dist"), **kw)
            fwd = pick(spec)(corpus, q_ids, q_w, **kw)
            rspec = METHODS[spec.reverse]
            if rspec.batch_fn is not None:
                return jnp.maximum(fwd, pick(rspec)(corpus, q_ids, q_w,
                                                    **kw))
            rev = jax.lax.map(lambda ab: rspec.fn(corpus, ab[0], ab[1],
                                                  **kw), (q_ids, q_w))
            return jnp.maximum(fwd, rev)
        return pick(spec)(corpus, q_ids, q_w, **kw)

    def one(ab):
        return query_scores(corpus, ab[0], ab[1], method=method,
                            symmetric=symmetric, iters=iters,
                            use_kernels=use_kernels, block_v=block_v,
                            block_h=block_h, block_n=block_n,
                            rev_block=rev_block)
    return jax.lax.map(one, (q_ids, q_w))


@functools.partial(jax.jit,
                   static_argnames=("top_l", "symmetric") + _STATIC_KW)
def search(corpus: lc.Corpus, q_ids: Array, q_w: Array, top_l: int,
           method: str = "act", iters: int = 1, *, symmetric: bool = False,
           use_kernels: bool = False, block_v: int = 256, block_h: int = 256,
           block_n: int = 256, rev_block: int = 256, block_q: int = 8,
           precision: str = "f32"):
    """Return (scores, indices) of the top-l most similar database rows.

    Jitted end-to-end (method dispatch is static), so scoring + top-k
    compile into one program instead of re-tracing the method per call.
    """
    scores = query_scores(corpus, q_ids, q_w, method=method,
                          symmetric=symmetric, iters=iters,
                          use_kernels=use_kernels, block_v=block_v,
                          block_h=block_h, block_n=block_n,
                          rev_block=rev_block)
    neg, idx = jax.lax.top_k(-scores, top_l)
    return -neg, idx


@functools.partial(jax.jit, static_argnames=_STATIC_KW + ("engine",))
def all_pairs_scores(corpus: lc.Corpus, method: str = "act",
                     iters: int = 1, *, engine: str = "batched",
                     use_kernels: bool = False,
                     block_v: int = 256, block_h: int = 256,
                     block_n: int = 256, rev_block: int = 256,
                     block_q: int = 8, precision: str = "f32") -> Array:
    """n x n symmetric bound matrix over the corpus (paper's eval mode).

    asym[a, b] = directional bound of moving histogram b INTO histogram a
    (query = row a); symmetric = max(asym, asym^T) unless the method's
    spec declares the measure already symmetric. ``engine`` selects the
    batched multi-query engine or the scanned per-query fallback (see
    ``batch_scores``).
    """
    spec = METHODS[method]
    asym = batch_scores(corpus, corpus.ids, corpus.w, method=method,
                        engine=engine, iters=iters, use_kernels=use_kernels,
                        block_v=block_v, block_h=block_h, block_n=block_n,
                        rev_block=rev_block, block_q=block_q,
                        precision=precision)
    if spec.symmetric:
        return asym
    return lc.symmetric_scores(asym)


@functools.partial(jax.jit,
                   static_argnames=("method", "mesh") + _STATIC_KW[1:])
def cand_scores(corpus: lc.Corpus, q_ids: Array, q_w: Array, cand: Array, *,
                method: str = "act", iters: int = 1,
                use_kernels: bool = False, block_v: int = 256,
                block_h: int = 256, block_n: int = 256,
                rev_block: int = 256, block_q: int = 8, mesh=None,
                precision: str = "f32") -> Array:
    """Candidate-compacted scoring: ``(nq, h)`` queries against each
    query's own ``(b,)`` candidate rows -> ``(nq, b)`` scores.

    This is the cascade subsystem's stage primitive (Phase 1 is shared
    with the full-corpus engines; only Phase 2/3 compacts to the
    candidates), dispatched through ``MethodSpec.cand_fn``.
    ``use_kernels=True`` fuses the per-query candidate gather and the
    reduction into one ``kernels/cand_pour`` launch for the LC methods,
    matching the reference path to within a few ulps (see the
    ``cand_fn`` field doc and ``kernels/cand_pour``'s conformance notes).
    """
    spec = METHODS[method]
    if spec.cand_fn is None:
        raise ValueError(f"method {method!r} has no candidate-compacted "
                         "scorer registered (MethodSpec.cand_fn)")
    return spec.cand_fn(corpus, q_ids, q_w, cand, iters=iters,
                        use_kernels=use_kernels, block_v=block_v,
                        block_h=block_h, block_n=block_n,
                        rev_block=rev_block, block_q=block_q, mesh=mesh,
                        precision=precision)


def _mask_self(scores: Array) -> Array:
    """Push the diagonal of a square corpus-as-queries score matrix to the
    dtype max so a row never retrieves itself.

    The mask is written in the float32 ACCUMULATOR dtype, never a reduced
    storage dtype: ``finfo(bfloat16).max`` is also what bf16 overflow
    saturates to, so masking in-dtype would tie the diagonal with any
    saturated entry and let ``top_k``'s index order pick between self and
    a real row. Upcasting first (exact for bf16/f16) keeps the sentinel
    strictly above every finite score; float32 inputs pass through
    bit-unchanged."""
    n = scores.shape[0]
    acc = jnp.promote_types(scores.dtype, jnp.float32)
    scores = scores.astype(acc)
    big = jnp.asarray(jnp.finfo(acc).max, acc)
    return jnp.where(jnp.eye(n, dtype=bool), big, scores)


def precision_at_l(scores: Array, labels: Array, top_l: int) -> float:
    """Average precision@top-l: fraction of each row's top-l neighbors
    (self excluded) sharing the row's label."""
    _, idx = jax.lax.top_k(-_mask_self(scores), top_l)     # (n, top_l)
    same = labels[idx] == labels[:, None]
    return float(jnp.mean(jnp.mean(same.astype(jnp.float32), axis=1)))


def topl_overlap(got_idx, ref_idx) -> float:
    """Mean fraction of each row's reference index set retrieved by the
    row's ``got_idx`` set — the single home of the top-l agreement
    metric (``recall_at_l`` and ``cascade.topk_recall`` both delegate
    here)."""
    got = jnp.asarray(got_idx)
    ref = jnp.asarray(ref_idx)
    if got.shape != ref.shape:
        raise ValueError(f"index sets must share a shape, got "
                         f"{got.shape} vs {ref.shape}")
    hit = (got[..., :, None] == ref[..., None, :]).any(axis=-1)
    return float(jnp.mean(hit.astype(jnp.float32)))


def recall_at_l(scores: Array, ref_scores: Array, top_l: int, *,
                exclude_self: bool = False) -> float:
    """Average recall@top-l of ``scores`` against a reference ranking:
    the fraction of each row's reference top-l (by ``ref_scores``, e.g.
    exact EMD or full-corpus ACT) that the row's top-l under ``scores``
    retrieves. Shapes must match — (nq, n) query batches or (n, n)
    corpus-as-queries matrices (``exclude_self=True`` masks the diagonal
    of both, the all-pairs convention of :func:`precision_at_l`)."""
    if scores.shape != ref_scores.shape:
        raise ValueError(f"score matrices must share a shape, got "
                         f"{scores.shape} vs {ref_scores.shape}")
    if exclude_self:
        scores = _mask_self(scores)
        ref_scores = _mask_self(ref_scores)
    _, got = jax.lax.top_k(-scores, top_l)
    _, ref = jax.lax.top_k(-ref_scores, top_l)
    return topl_overlap(got, ref)
