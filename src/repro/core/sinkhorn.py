"""Sinkhorn distance baseline (Cuturi 2013) in pure JAX.

The paper compares LC-ACT against Cuturi's GPU Sinkhorn with entropic
regularization lambda = 20; we reproduce that baseline so the accuracy and
complexity comparisons in ``benchmarks/`` are self-contained.

Implemented in the log domain for numerical robustness at large lambda
(equivalently small epsilon = 1/lambda), with a fixed iteration count so the
whole computation jits and vmaps cleanly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("n_iters",))
def sinkhorn_cost(p: Array, q: Array, C: Array, lam: float = 20.0,
                  n_iters: int = 200) -> Array:
    """Entropic-OT transport cost  <F*, C>  with F* from Sinkhorn scaling.

    Args:
      p: (hp,) L1-normalized source histogram.
      q: (hq,) L1-normalized target histogram.
      C: (hp, hq) nonnegative cost matrix.
      lam: entropic regularization (paper uses 20).
      n_iters: fixed number of Sinkhorn iterations.
    Returns the scalar transport cost of the regularized plan (NOT a lower
    bound of EMD; it converges to EMD from above as lam -> inf).
    """
    eps = 1.0 / lam
    logp = jnp.log(jnp.maximum(p, 1e-35))
    logq = jnp.log(jnp.maximum(q, 1e-35))
    mK = -C / eps  # log kernel

    def body(_, fg):
        f, g = fg
        # f_i = eps*(logp_i - logsumexp_j (mK_ij + g_j/eps))
        f = eps * (logp - jax.scipy.special.logsumexp(mK + g[None, :] / eps, axis=1))
        g = eps * (logq - jax.scipy.special.logsumexp(mK + f[:, None] / eps, axis=0))
        return f, g

    f = jnp.zeros_like(p)
    g = jnp.zeros_like(q)
    f, g = jax.lax.fori_loop(0, n_iters, body, (f, g))
    logF = (f[:, None] + g[None, :]) / eps + mK
    F = jnp.exp(logF)
    # Mass of empty bins is ~0; renormalize the plan defensively.
    F = F * (jnp.sum(p) / jnp.maximum(jnp.sum(F), 1e-35))
    return jnp.sum(F * C)


def sinkhorn_batch(p_batch: Array, q: Array, C_batch: Array, lam: float = 20.0,
                   n_iters: int = 200) -> Array:
    """vmapped Sinkhorn: one query ``q`` against a batch of histograms."""
    fn = lambda p, C: sinkhorn_cost(p, q, C, lam=lam, n_iters=n_iters)
    return jax.vmap(fn)(p_batch, C_batch)
