"""Core EMD approximation library (the paper's contribution).

Per-pair measures: ``relaxations`` (RWMD/OMR/ICT/ACT), oracles ``emd`` and
``sinkhorn``. Batch linear-complexity engines: ``lc`` (LC-RWMD/LC-OMR/
LC-ACT). Retrieval harness: ``retrieval``.

This package is the thin compute layer. Serving callers should use the
unified facade in ``repro.api`` (``EmdIndex`` + ``EngineConfig``), which
composes these engines with the Pallas kernels and the distributed step
behind one backend-agnostic surface.
"""
from repro.core.emd import emd_exact, emd_exact_flow
from repro.core.geometry import l1_normalize, l2_normalize, pairwise_dist, pairwise_sqdist
from repro.core.lc import Corpus, lc_act_scores, lc_omr_scores, lc_rwmd_scores, lc_rwmd_scores_rev, symmetric_scores
from repro.core.relaxations import act, act_dir, ict, ict_dir, omr, omr_dir, rwmd, rwmd_dir
from repro.core.sinkhorn import sinkhorn_batch, sinkhorn_cost

__all__ = [
    "emd_exact", "emd_exact_flow",
    "l1_normalize", "l2_normalize", "pairwise_dist", "pairwise_sqdist",
    "Corpus", "lc_act_scores", "lc_omr_scores", "lc_rwmd_scores",
    "lc_rwmd_scores_rev", "symmetric_scores",
    "act", "act_dir", "ict", "ict_dir", "omr", "omr_dir", "rwmd", "rwmd_dir",
    "sinkhorn_batch", "sinkhorn_cost",
]
