"""Histogram construction utilities (paper Section 6 preprocessing).

Documents -> L1-normalized, truncated (most-frequent ``hmax`` bins) padded
histograms over a shared vocabulary; images -> dense pixel histograms whose
coordinates are pixel positions (Fig. 1).
"""
from __future__ import annotations

import numpy as np

from repro.core.lc import Corpus


def docs_to_corpus(docs: list[list[int]], coords: np.ndarray, hmax: int,
                   dtype=np.float32) -> Corpus:
    """Token-id documents -> padded Corpus (truncate to top-``hmax`` bins).

    Mirrors the paper's 20 Newsgroups preprocessing: per-document term
    frequencies, truncated to the most frequent ``hmax`` words, then
    L1-normalized.
    """
    import jax.numpy as jnp

    n = len(docs)
    ids = np.zeros((n, hmax), dtype=np.int32)
    w = np.zeros((n, hmax), dtype=dtype)
    for u, doc in enumerate(docs):
        uniq, counts = np.unique(np.asarray(doc, dtype=np.int64), return_counts=True)
        if len(uniq) > hmax:                      # keep most-frequent hmax
            keep = np.argsort(-counts, kind="stable")[:hmax]
            uniq, counts = uniq[keep], counts[keep]
        h = len(uniq)
        ids[u, :h] = uniq
        w[u, :h] = counts / counts.sum()
    return Corpus(ids=jnp.asarray(ids), w=jnp.asarray(w), coords=jnp.asarray(coords, dtype))


def images_to_corpus(images: np.ndarray, include_background: bool,
                     dtype=np.float32) -> Corpus:
    """Greyscale images (n, H, W) -> histograms with pixel-position coords.

    include_background=False drops zero pixels (sparse MNIST mode, Tab. 5);
    include_background=True keeps every pixel with a small floor weight so
    all supports fully overlap (the RWMD failure mode, Tab. 6).
    """
    import jax.numpy as jnp

    n, H, W = images.shape
    v = H * W
    yy, xx = np.meshgrid(np.arange(H), np.arange(W), indexing="ij")
    coords = np.stack([yy.ravel(), xx.ravel()], axis=1).astype(dtype)
    flat = images.reshape(n, v).astype(np.float64)
    if include_background:
        flat = flat + 1e-3 * flat.max()           # background floor -> dense
        ids = np.tile(np.arange(v, dtype=np.int32), (n, 1))
        w = (flat / flat.sum(axis=1, keepdims=True)).astype(dtype)
        return Corpus(ids=jnp.asarray(ids), w=jnp.asarray(w),
                      coords=jnp.asarray(coords))
    hmax = int((flat > 0).sum(axis=1).max())
    ids = np.zeros((n, hmax), dtype=np.int32)
    w = np.zeros((n, hmax), dtype=dtype)
    for u in range(n):
        nz = np.nonzero(flat[u])[0]
        ids[u, :len(nz)] = nz
        w[u, :len(nz)] = flat[u, nz] / flat[u, nz].sum()
    return Corpus(ids=jnp.asarray(ids), w=jnp.asarray(w), coords=jnp.asarray(coords))


def pair_from_corpus(corpus: Corpus, a: int, b: int):
    """Extract (p, q, C) for rows a, b — dense per-pair view for oracles."""
    from repro.core.geometry import pairwise_dist
    import jax.numpy as jnp

    ids_a, w_a = corpus.ids[a], corpus.w[a]
    ids_b, w_b = corpus.ids[b], corpus.w[b]
    C = pairwise_dist(corpus.coords[ids_a], corpus.coords[ids_b])
    # Invalidate padding slots: zero weight rows/cols contribute nothing,
    # but zero-cost accidental overlaps with pad id 0 must not help.
    C = jnp.where((w_a[:, None] > 0) & (w_b[None, :] > 0), C, jnp.inf)
    C = jnp.where(jnp.isinf(C), jnp.max(jnp.where(jnp.isinf(C), 0.0, C)) + 1.0, C)
    return w_a, w_b, C
