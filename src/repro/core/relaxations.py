"""The paper's relaxation measures, per histogram pair (Section 4).

All four measures relax the EMD LP in increasing tightness
(Theorem 2):    RWMD <= OMR <= ACT-k <= ICT <= EMD.

Directional convention: ``*_dir(p, q, C)`` is the cost of moving ``p`` INTO
``q`` (out-flow constraints kept; in-flow constraints removed or relaxed to
the per-edge capacity F_ij <= q_j). The symmetric measure is the max of the
two directions, exactly as in Section 2.1 / Section 6 of the paper.

Everything here is pure jnp and vectorized: the greedy pour of Algorithms
2/3 is a prefix-sum over the cost-sorted destination axis, not a Python
loop, so these functions jit/vmap and serve as readable oracles for the
linear-complexity engines in ``core/lc.py`` and the Pallas kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array

__all__ = [
    "rwmd_dir", "omr_dir", "ict_dir", "act_dir",
    "rwmd", "omr", "ict", "act",
]


def rwmd_dir(p: Array, q: Array, C: Array) -> Array:
    """Relaxed WMD, direction p -> q: every source bin ships all its mass to
    its single nearest destination (in-flow constraints dropped entirely)."""
    del q  # the relaxation ignores destination weights
    return jnp.sum(p * jnp.min(C, axis=1))


def omr_dir(p: Array, q: Array, C: Array) -> Array:
    """Overlapping Mass Reduction (Algorithm 1), direction p -> q.

    If the nearest destination overlaps (cost 0), a transfer of
    min(p_i, q_j) rides for free and the remainder pays the 2nd-nearest
    cost; otherwise everything pays the nearest cost.
    """
    neg_top2, idx2 = jax.lax.top_k(-C, 2)                 # (hp, 2)
    c1, c2 = -neg_top2[:, 0], -neg_top2[:, 1]
    q1 = q[idx2[:, 0]]
    overlap = c1 == 0.0
    moved_free = jnp.minimum(p, q1)
    rest = p - moved_free
    per_row = jnp.where(overlap, rest * c2, p * c1)
    return jnp.sum(per_row)


def _greedy_pour_rows(p: Array, cap_sorted: Array, cost_sorted: Array) -> Array:
    """Vectorized greedy pour (the while-loop of Algorithms 2/3).

    For each row i, pour ``p[i]`` into destinations l = 0,1,... with
    capacities ``cap_sorted[i, l]`` at unit costs ``cost_sorted[i, l]``.
    Transfer into slot l is  r_l = clip(p_i - prefix_cap_<l, 0, cap_l).
    Returns (per-row poured cost, per-row remaining mass).
    """
    prefix = jnp.cumsum(cap_sorted, axis=1) - cap_sorted  # exclusive prefix
    r = jnp.clip(p[:, None] - prefix, 0.0, cap_sorted)
    poured = jnp.sum(r * cost_sorted, axis=1)
    remainder = jnp.maximum(p - jnp.sum(r, axis=1), 0.0)
    return poured, remainder


def ict_dir(p: Array, q: Array, C: Array) -> Array:
    """Iterative Constrained Transfers (Algorithm 2), direction p -> q.

    Optimal for the relaxation {(1),(2),(4)}: per-edge capacity q_j, full
    sort of each cost row, greedy pour until each source bin is empty.
    """
    order = jnp.argsort(C, axis=1)                        # (hp, hq)
    cost_sorted = jnp.take_along_axis(C, order, axis=1)
    cap_sorted = q[order]
    poured, remainder = _greedy_pour_rows(p, cap_sorted, cost_sorted)
    # Histograms are L1-normalized so sum(q) >= p_i and remainder == 0;
    # keep the term for un-normalized defensive use (costs the max cost).
    return jnp.sum(poured) + jnp.sum(remainder * cost_sorted[:, -1])


@functools.partial(jax.jit, static_argnames=("iters",))
def act_dir(p: Array, q: Array, C: Array, iters: int = 1) -> Array:
    """Approximate ICT (Algorithm 3), direction p -> q.

    ``iters`` = number of Phase-2 iterations in the paper's naming
    (ACT-1 == iters=1). Performs ``iters`` capacity-constrained transfers to
    the nearest destinations, then dumps any remainder at the
    (iters+1)-th nearest cost. iters=0 degenerates to RWMD.
    """
    iters = min(iters, C.shape[1] - 1)        # k > h_q degenerates to ICT
    k = iters + 1
    neg_topk, idx = jax.lax.top_k(-C, k)                  # ascending costs
    cost_sorted = -neg_topk                               # (hp, k)
    if iters == 0:
        return jnp.sum(p * cost_sorted[:, 0])
    cap_sorted = q[idx[:, :iters]]
    poured, remainder = _greedy_pour_rows(p, cap_sorted, cost_sorted[:, :iters])
    return jnp.sum(poured) + jnp.sum(remainder * cost_sorted[:, iters])


def _symmetric(fn_dir, p, q, C, **kw):
    return jnp.maximum(fn_dir(p, q, C, **kw), fn_dir(q, p, C.T, **kw))


def rwmd(p: Array, q: Array, C: Array) -> Array:
    """Symmetric RWMD = max of the two directional lower bounds."""
    return _symmetric(rwmd_dir, p, q, C)


def omr(p: Array, q: Array, C: Array) -> Array:
    """Symmetric OMR."""
    return _symmetric(omr_dir, p, q, C)


def ict(p: Array, q: Array, C: Array) -> Array:
    """Symmetric ICT."""
    return _symmetric(ict_dir, p, q, C)


def act(p: Array, q: Array, C: Array, iters: int = 1) -> Array:
    """Symmetric ACT-``iters``."""
    return _symmetric(act_dir, p, q, C, iters=iters)
