"""Precision policies: the storage/compute/accumulate dtype triple the
batched scoring pipeline threads end to end.

At production corpus sizes the Phase-1 distance table and the handoff
ladders — not FLOPs — cap what fits per device (ROADMAP "Mixed-precision
pipeline"). A :class:`PrecisionPolicy` names the three dtype roles:

* ``storage`` — the Phase-1 handoff arrays (the (nq, v, k) Z/W ladders,
  the (nq, v) masked-min row, the (nq, v, h) reverse distance handoff)
  and the kernel block buffers that hold them. This is the axis that
  halves memory and collective bytes.
* ``compute`` — the stacked distance-matmul operands. bf16 operands on
  the MXU always accumulate into float32 (``preferred_element_type``),
  so dropping compute precision loses input bits, never sum bits.
* ``accum``  — reductions (pours, cumsum ladders, (min,+) contractions)
  and every masking/sentinel write. Always float32: the closed-form LC
  reductions tolerate low-precision STORAGE, not low-precision sums.

Three presets:

=========  =========  =========  =======
name       storage    compute    accum
=========  =========  =========  =======
f32        float32    float32    float32   (default — bitwise unchanged)
bf16       bfloat16   float32    float32
bf16_agg   bfloat16   bfloat16   float32
=========  =========  =========  =======

Sentinel representability (the PR's bugfix): the float32 sentinel
``lc.PAD_DIST`` (1e30) overflows float16 to inf and rounds in bfloat16,
so every reduced-precision path writes :func:`pad_dist_for` (dtype)
instead — finite, exactly representable in that dtype, above any real
transport cost, and guaranteed to upcast to at least the float32
sentinel wherever the dtype's range allows. All sentinel comparisons in
the pipeline are STRICT (``C < pad``), so equality after an exact
upcast round-trip still excludes the sentinel.
"""
from __future__ import annotations

import dataclasses
import functools

import jax.numpy as jnp
import numpy as np

#: ``lc.PAD_DIST`` (1e30) as float32 — it rounds UP to ~1.000000015e30,
#: so it is itself a valid round-up sentinel and the float32 pad value
#: is BITWISE the historical ``jnp.asarray(1e30, float32)``.
_PAD_F32 = float(np.float32(1e30))


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """One storage/compute/accumulate dtype triple (dtype names as
    strings — hashable, so a policy or its name rides through
    ``jax.jit`` static arguments)."""
    name: str
    storage: str
    compute: str
    accum: str


POLICIES = {
    "f32": PrecisionPolicy("f32", "float32", "float32", "float32"),
    "bf16": PrecisionPolicy("bf16", "bfloat16", "float32", "float32"),
    "bf16_agg": PrecisionPolicy("bf16_agg", "bfloat16", "bfloat16",
                                "float32"),
}


def resolve(precision) -> PrecisionPolicy:
    """Preset name (or an already-resolved policy) -> PrecisionPolicy."""
    if isinstance(precision, PrecisionPolicy):
        return precision
    if precision in POLICIES:
        return POLICIES[precision]
    raise ValueError(f"unknown precision policy {precision!r}; "
                     f"one of {sorted(POLICIES)}")


@functools.lru_cache(maxsize=None)
def _pad_dist_cached(name: str) -> float:
    dt = jnp.dtype(name)
    if dt.itemsize >= 4:
        return _PAD_F32
    fi = jnp.finfo(dt)
    # Narrow-range dtypes (float16: max 65504) cap the sentinel well
    # below the float32 one — but still orders of magnitude above any
    # real transport cost, and finite so 0-mass remainders cost 0.
    target = min(_PAD_F32, float(fi.max) / 8.0)
    x = dt.type(target)
    # Round UP to the first representable value whose upcast clears the
    # target (nearest-rounding may have landed below it).
    while float(x) < target:
        x = dt.type(float(x) * (1.0 + float(fi.eps)))
    return float(x)


def pad_dist_for(dtype) -> float:
    """The padding-distance sentinel for ``dtype``, as a Python float.

    Finite, below ``finfo(dtype).max``, above any real transport cost,
    exactly representable in ``dtype`` (so a downcast-then-upcast
    round-trip is exact), and — for every dtype whose range reaches it —
    at least the float32 sentinel on upcast, keeping strict ``< pad``
    comparisons correct across mixed-precision handoffs.
    ``pad_dist_for(float32)`` is bitwise the historical ``lc.PAD_DIST``.
    """
    return _pad_dist_cached(jnp.dtype(dtype).name)
