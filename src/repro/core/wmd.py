"""WMD baseline: exact EMD nearest-neighbor search with RWMD pruning.

This is the method the paper is 10^4x faster than (Kusner et al. 2015 +
the prefetch-and-prune trick): compute cheap RWMD lower bounds for the whole
database, exactly solve the transportation LP only for the most promising
candidates, and stop when the next lower bound exceeds the current top-l
threshold.

Host-side (scipy LP per candidate) by design — it is the accuracy/runtime
REFERENCE for benchmarks/, not a production path.
"""
from __future__ import annotations

import numpy as np

from repro.core.emd import emd_exact
from repro.core.histogram import pair_from_corpus
from repro.core.lc import Corpus, lc_rwmd_scores


def wmd_search(corpus: Corpus, q_index: int, top_l: int,
               prune_factor: int = 4) -> tuple[np.ndarray, np.ndarray]:
    """Top-l most similar rows to ``corpus[q_index]`` under exact EMD.

    prune_factor: how many RWMD-ranked candidates to solve exactly, as a
    multiple of top_l (the paper's pruning: lower bound >= current k-th
    best exact distance => candidate cannot enter the top-l).
    """
    lb = np.array(lc_rwmd_scores(corpus, corpus.ids[q_index],
                                 corpus.w[q_index]))
    lb[q_index] = np.inf                      # exclude self
    order = np.argsort(lb)
    exact: dict[int, float] = {}
    threshold = np.inf
    for rank, u in enumerate(order):
        if lb[u] >= threshold and len(exact) >= top_l:
            break                             # lower bound prunes the rest
        if rank >= prune_factor * top_l and len(exact) >= top_l:
            break
        p, q, C = pair_from_corpus(corpus, int(u), q_index)
        pn, qn, Cn = np.asarray(p), np.asarray(q), np.asarray(C)
        keep_p, keep_q = pn > 0, qn > 0
        exact[int(u)] = emd_exact(pn[keep_p], qn[keep_q],
                                  Cn[np.ix_(keep_p, keep_q)])
        if len(exact) >= top_l:
            threshold = sorted(exact.values())[top_l - 1]
    items = sorted(exact.items(), key=lambda kv: kv[1])[:top_l]
    idx = np.asarray([u for u, _ in items])
    val = np.asarray([v for _, v in items])
    return val, idx


def wmd_all_pairs_precision(corpus: Corpus, labels: np.ndarray, top_l: int,
                            n_queries: int | None = None,
                            prune_factor: int = 4) -> float:
    """precision@top-l of exact-EMD search over the corpus (or a query
    subset — the paper does the same to keep WMD benchmarks tractable)."""
    n = corpus.n if n_queries is None else min(n_queries, corpus.n)
    hits = []
    for qi in range(n):
        _, idx = wmd_search(corpus, qi, top_l, prune_factor)
        hits.append(np.mean(labels[idx] == labels[qi]))
    return float(np.mean(hits))
