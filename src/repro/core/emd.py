"""Exact EMD oracle (discrete Wasserstein / transportation LP).

Used as the ground-truth in tests and small-scale benchmarks. This is the
measure that Theorem 2's chain of lower bounds is measured against:

    RWMD <= OMR <= ACT-k <= ICT <= EMD

The solver delegates to ``scipy.optimize.linprog`` (HiGHS), which is exact for
the transportation polytope at the histogram sizes used in tests/benchmarks.
It is intentionally NOT jitted or accelerated — it is the oracle, not the
system.
"""
from __future__ import annotations

import numpy as np


def emd_exact(p, q, C) -> float:
    """Exact EMD between L1-normalized histograms ``p`` (hp,) and ``q`` (hq,)
    under nonnegative cost matrix ``C`` (hp, hq)."""
    from scipy.optimize import linprog

    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    C = np.asarray(C, dtype=np.float64)
    hp, hq = C.shape
    assert p.shape == (hp,) and q.shape == (hq,)
    # Float32 inputs normalized upstream may miss sum==1 by ~1e-7, which the
    # equality constraints would reject; renormalize exactly in float64.
    p = p / p.sum()
    q = q / q.sum()

    # Variables: F flattened row-major, F[i, j] = x[i * hq + j] >= 0.
    # Out-flow:  sum_j F[i, j] = p_i     (hp rows)
    # In-flow:   sum_i F[i, j] = q_j     (last row dropped — redundant given
    #                                     the out-flow rows and sum p = sum q)
    a_eq_rows = []
    b_eq = []
    for i in range(hp):
        row = np.zeros(hp * hq)
        row[i * hq:(i + 1) * hq] = 1.0
        a_eq_rows.append(row)
        b_eq.append(p[i])
    for j in range(hq - 1):
        row = np.zeros(hp * hq)
        row[j::hq] = 1.0
        a_eq_rows.append(row)
        b_eq.append(q[j])
    res = linprog(
        c=C.ravel(),
        A_eq=np.stack(a_eq_rows),
        b_eq=np.asarray(b_eq),
        bounds=(0, None),
        method="highs",
    )
    if not res.success:  # pragma: no cover - defensive
        raise RuntimeError(f"exact EMD LP failed: {res.message}")
    return float(res.fun)


def emd_exact_flow(p, q, C):
    """Exact EMD plus the optimal flow matrix (tests of flow-level claims)."""
    from scipy.optimize import linprog

    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    C = np.asarray(C, dtype=np.float64)
    p = p / p.sum()
    q = q / q.sum()
    hp, hq = C.shape
    a_eq = np.zeros((hp + hq - 1, hp * hq))
    b_eq = np.concatenate([p, q[:-1]])
    for i in range(hp):
        a_eq[i, i * hq:(i + 1) * hq] = 1.0
    for j in range(hq - 1):
        a_eq[hp + j, j::hq] = 1.0
    res = linprog(c=C.ravel(), A_eq=a_eq, b_eq=b_eq, bounds=(0, None), method="highs")
    if not res.success:  # pragma: no cover
        raise RuntimeError(f"exact EMD LP failed: {res.message}")
    return float(res.fun), res.x.reshape(hp, hq)
