"""Distributed EMD similarity search (the paper's workload, scaled out).

One scoring step: a batch of queries against a vocabulary-backed histogram
database, for ANY method in the ``retrieval.METHODS`` registry — the step
is derived from the registry (``MethodSpec.dist_fn`` falling back to the
method's ``batch_fn``), not hard-coded, so every method the single-host
batched engine serves also runs on the mesh. Serving callers should reach
this through ``repro.api.EmdIndex`` (``backend="distributed"``), which
builds the mesh, shardings, and jitted step from this module internally.

This module contains NO scoring math of its own: it wraps the raw sharded
arrays back into a :class:`~repro.core.lc.Corpus` and traces
``retrieval.batch_scores`` — the same batched pipeline
(``core/lc`` stage functions) that single-host callers run. The pipeline
stages carry their own ``sharding.annotate`` constraints, which are what
shape the mesh program:

Sharding (DESIGN.md section 2):
  * Phase 1 — queries over ``data``, vocabulary rows over ``model``: the
    stacked (v, nq*h) distance matmul is sharded both ways
    (``annotate.emd_stacked_dist``); the per-row top-k / masked min is
    local (``lc.streaming_smallest_k`` is built from min/where/iota so
    the SPMD partitioner shards it — ``lax.top_k`` would not partition
    and forces a full all-gather of D).
  * handoff — the query-major (nq, v, k) cost/capacity ladders (or the
    (nq, v) masked-min row) are all-gathered over ``model``
    (``annotate.emd_ladder``; v*k floats, ~2 MB at 20News scale).
    Pinning this OUTPUT layout stops XLA hoisting the resharding above
    the top-k, which would all-gather the full (v, nq, h) distance
    tensor instead — 36 GB/device at 20News scale.
  * Phase 2/3 — database rows over ``model``, queries over ``data``: the
    query-blocked pour (``lc.pour_blocked`` and friends, ``block_q``
    queries gathered per tile) is embarrassingly parallel over the
    (query, row) grid; the score matrix lands P(data, model) (per-method
    override via ``MethodSpec.dist_out``).
  * top-l — pad rows masked to ``lc.PAD_DIST`` first (zero-weight pad
    rows otherwise score 0 for the LC methods — the best possible
    score), then per-shard top-l and a single small gather.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import lc, retrieval
from repro.launch.mesh import data_axes


#: Database rows are padded to a multiple of this so the corpus shards on
#: any mesh. Overridable per call site (``repro.api.EngineConfig``
#: carries it as ``pad_multiple``).
DEFAULT_ROW_PAD_MULTIPLE = 512


def _dp(mesh):
    axes = data_axes(mesh)
    return axes if len(axes) > 1 else axes[0]


def workload_method(workload) -> str:
    """The registry method a workload scores with (``"act"`` when it
    declares none) — the single place the default lives."""
    return getattr(workload, "method", "act") or "act"


def make_scores_step(iters: int = 1, *, method: str = "act",
                     symmetric: bool = False, engine: str = "dist",
                     use_kernels: bool = False, block_q: int = 8,
                     block_v: int = 256, block_h: int = 256,
                     block_n: int = 256, rev_block: int = 256, mesh=None,
                     precision: str = "f32"):
    """Returns scores_step(corpus_ids, corpus_w, coords, q_ids, q_w)
    -> full (nq, n) score matrix for ``method``.

    The step is the registry-dispatched batched pipeline
    (``retrieval.batch_scores``): ``engine="dist"`` (default) runs each
    method's mesh-specialized scorer where one is registered and its
    plain batched scorer otherwise; ``engine="scan"`` replays the exact
    single-query graphs (verification). All the batch knobs of the
    single-host engine apply unchanged. ``mesh`` routes the kernel path
    through the ``kernels/partition`` shard_map shims (the jit_* helpers
    pass their mesh themselves).
    """
    def scores_step(corpus_ids, corpus_w, coords, q_ids, q_w):
        corpus = lc.Corpus(ids=corpus_ids, w=corpus_w, coords=coords)
        return retrieval.batch_scores(
            corpus, q_ids, q_w, method=method, symmetric=symmetric,
            engine=engine, iters=iters, use_kernels=use_kernels,
            block_v=block_v, block_h=block_h, block_n=block_n,
            rev_block=rev_block, block_q=block_q, mesh=mesh,
            precision=precision)

    return scores_step


def make_search_step(iters: int = 1, top_l: int = 16,
                     n_valid: int | None = None, **score_kw):
    """Returns search_step(corpus_ids, corpus_w, coords, q_ids, q_w)
    -> (top-l scores, top-l indices), each (nq, top_l).

    ``n_valid``: number of real (non-padding) database rows. Pad rows
    score 0 for the LC methods — the best possible score — so they must
    be masked out before top-l, not after (and for the baselines their
    scores are simply meaningless). ``None`` = no padding. Remaining
    kwargs go to :func:`make_scores_step`."""
    scores_step = make_scores_step(iters, **score_kw)

    def search_step(corpus_ids, corpus_w, coords, q_ids, q_w):
        scores = scores_step(corpus_ids, corpus_w, coords, q_ids, q_w)
        scores = lc.mask_pad_rows(scores, n_valid)
        neg, idx = jax.lax.top_k(-scores, top_l)
        return -neg, idx

    return search_step


def search_shardings(mesh, workload):
    """(in_shardings, out_shardings) for search_step on ``mesh``."""
    dp = _dp(mesh)
    ns = lambda *spec: NamedSharding(mesh, P(*spec))
    in_sh = (
        ns("model", None),        # corpus_ids (n, hmax)
        ns("model", None),        # corpus_w   (n, hmax)
        ns(None, None),           # coords     (v, m) — replicated (small*m)
        ns(dp, None),             # q_ids      (nq, hmax)
        ns(dp, None),             # q_w        (nq, hmax)
    )
    out_sh = (ns(dp, None), ns(dp, None))
    return in_sh, out_sh


def scores_shardings(mesh, workload, method: str | None = None):
    """(in_shardings, out_sharding) for scores_step on ``mesh``: the full
    (nq, n) matrix lands on the method's ``MethodSpec.dist_out`` layout —
    by default P(data, model), queries on their data shards, database
    columns on the model shards that scored them."""
    dp = _dp(mesh)
    method = workload_method(workload) if method is None else method
    spec = retrieval.METHODS[method]
    out = tuple(dp if ax == "data" else ax for ax in spec.dist_out)
    in_sh, _ = search_shardings(mesh, workload)
    return in_sh, NamedSharding(mesh, P(*out))


def search_input_specs(workload,
                       pad_multiple: int = DEFAULT_ROW_PAD_MULTIPLE) -> tuple:
    """ShapeDtypeStruct stand-ins for one scoring step of ``workload``.

    The database row count is padded to a multiple of ``pad_multiple``
    (zero-weight pad rows are masked out before top-l) so it shards on
    any mesh."""
    w = workload
    n = -(-w.n_db // pad_multiple) * pad_multiple
    return (
        jax.ShapeDtypeStruct((n, w.hmax), jnp.int32),
        jax.ShapeDtypeStruct((n, w.hmax), jnp.float32),
        jax.ShapeDtypeStruct((w.vocab, w.dim), jnp.float32),
        jax.ShapeDtypeStruct((w.queries, w.hmax), jnp.int32),
        jax.ShapeDtypeStruct((w.queries, w.hmax), jnp.float32),
    )


def case_input_specs(case, workload,
                     pad_multiple: int = DEFAULT_ROW_PAD_MULTIPLE) -> tuple:
    """ShapeDtypeStruct stand-ins for one registry :class:`StepCase`: the
    five search operands, plus — for a cascade whose spec names a
    sublinear candidate source — the source's state arrays (the trailing
    operands ``make_cascade_search_step`` expects). This is what the
    static checkers (collectives, hazards) must trace a case with; the
    plain ``search_input_specs`` is only correct for unsourced cases."""
    specs = search_input_specs(workload, pad_multiple)
    if case.kind == "cascade":
        from repro import cascade as Cx
        rspec = Cx.resolve_spec(case.cascade)
        if rspec.sourced:
            specs = specs + tuple(rspec.source.state_structs(workload.dim))
    return specs


def jit_search_step(workload, mesh, top_l: int = 16, iters: int | None = None,
                    n_valid: int | None = None, *, method: str | None = None,
                    **score_kw):
    """``n_valid`` defaults to the workload's real row count so top-l never
    returns the zero-scoring pad rows added by ``search_input_specs``;
    ``method`` defaults to the workload's (``act`` when it has none)."""
    iters = workload.iters if iters is None else iters
    n_valid = workload.n_db if n_valid is None else n_valid
    method = workload_method(workload) if method is None else method
    step = make_search_step(iters, top_l, n_valid=n_valid, method=method,
                            mesh=mesh, **score_kw)
    in_sh, out_sh = search_shardings(mesh, workload)
    return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)


def jit_scores_step(workload, mesh, iters: int | None = None, *,
                    method: str | None = None, **score_kw):
    """Jitted full-score-matrix step on ``mesh`` (``repro.api`` backend)."""
    iters = workload.iters if iters is None else iters
    method = workload_method(workload) if method is None else method
    step = make_scores_step(iters, method=method, mesh=mesh, **score_kw)
    in_sh, out_sh = scores_shardings(mesh, workload, method=method)
    return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)


# ---------------------------------------------------------------------------
# Cascaded prune-and-rescore step (``repro.cascade`` on the mesh).
#
# Stage 1 scores the full sharded corpus through the same registry-derived
# pipeline as ``make_scores_step``; its top-budget selection is SHARD-LOCAL
# (``topk_blocks`` = the model-axis size: the (nq, n) score matrix reshapes
# into per-shard column blocks, each block's lax.top_k runs on its own
# shard, and only the (nq, blocks * budget') winner ladder is merged across
# the mesh — the full score matrix is never all-gathered). Later stages
# score the small merged candidate set (replicated over "model" on the
# emd_ladder layout), so they stay cheap wherever they land.
# ---------------------------------------------------------------------------


def make_cascade_search_step(spec, top_l: int = 16,
                             n_valid: int | None = None, *,
                             topk_blocks: int = 1, engine: str = "dist",
                             use_kernels: bool = False, block_q: int = 8,
                             block_v: int = 256, block_h: int = 256,
                             block_n: int = 256, rev_block: int = 256,
                             mesh=None, precision: str = "f32"):
    """Returns cascade_step(corpus_ids, corpus_w, coords, q_ids, q_w)
    -> (top-l rescorer scores, top-l global row indices), each (nq, top_l).

    ``spec`` is a ``repro.cascade`` CascadeSpec (or preset name) whose
    rescorer must be jittable — the host-side exact ``emd`` rescorer
    cannot run inside a mesh step. ``n_valid`` masks zero-weight pad rows
    out of candidacy before the stage-1 top-budget. ``use_kernels``
    routes stage-1 AND the candidate stages/rescorer through the fused
    kernels. Compiled ``pallas_call`` has no SPMD partitioning rule of
    its own, so on the mesh the kernel launches must run inside the
    ``kernels/partition`` shard_map shims — pass ``mesh`` (the jit_*
    helpers do) and the cascade's kernel path partitions explicitly,
    compiled on TPU and interpreted on the host-mesh conformance oracle
    alike. Without ``mesh`` the kernel path is only shardable in
    interpret mode, where the kernels lower to plain HLO.
    """
    from repro import cascade as Cx

    rspec = Cx.resolve_spec(spec)
    from repro.cascade import rescore
    if not rescore.resolve(rspec.rescorer).jittable:
        raise ValueError(
            f"rescorer {rspec.rescorer!r} runs on the host and cannot be "
            "traced into the mesh step; use a jittable rescorer "
            "(act/ict/sinkhorn/...) or run the cascade through "
            "repro.cascade.cascade_search on a single host")

    def cascade_step(corpus_ids, corpus_w, coords, q_ids, q_w, *src_leaves):
        # Sourced cascades take their index state as trailing operands
        # (``case_input_specs`` / ``EmdIndex`` supply them) so the built
        # arrays ride through jit as arguments, not baked constants.
        source = rspec.source.wrap(src_leaves) if rspec.sourced else None
        corpus = lc.Corpus(ids=corpus_ids, w=corpus_w, coords=coords)
        return tuple(Cx.cascade_search(
            corpus, q_ids, q_w, rspec, top_l, n_valid=n_valid,
            topk_blocks=topk_blocks, engine=engine, use_kernels=use_kernels,
            block_v=block_v, block_h=block_h, block_n=block_n,
            rev_block=rev_block, block_q=block_q, mesh=mesh,
            precision=precision, source=source))

    return cascade_step


def jit_cascade_search_step(workload, mesh, spec, top_l: int = 16,
                            n_valid: int | None = None, **score_kw):
    """Jitted cascade step on ``mesh``: shard-local stage-wise top-budget
    (``topk_blocks`` = the mesh's model-axis size when the padded row
    count splits evenly over it), ladder-merged candidates, (nq, top_l)
    outputs on the DP shards. ``n_valid`` defaults to the workload's real
    row count so pad rows never enter candidacy."""
    from repro.launch.mesh import model_axis_size

    n_valid = workload.n_db if n_valid is None else n_valid
    pad_multiple = score_kw.pop("pad_multiple", DEFAULT_ROW_PAD_MULTIPLE)
    n_padded = -(-workload.n_db // pad_multiple) * pad_multiple
    blocks = model_axis_size(mesh)
    if n_padded % max(blocks, 1):
        blocks = 1                       # uneven split: plain global top-k
    step = make_cascade_search_step(spec, top_l, n_valid,
                                    topk_blocks=blocks, mesh=mesh,
                                    **score_kw)
    in_sh, out_sh = search_shardings(mesh, workload)
    from repro import cascade as Cx
    rspec = Cx.resolve_spec(spec)
    if rspec.sourced:
        # Source state is small (buckets/nodes, not corpus rows) and
        # every query probes arbitrary buckets: replicate it.
        n_leaves = len(rspec.source.state_structs(workload.dim))
        in_sh = in_sh + (NamedSharding(mesh, P()),) * n_leaves
    return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)


# ---------------------------------------------------------------------------
# Enumerable step registry — the surface ``repro.analysis.check`` iterates.
#
# Every servable mesh program this module can build, as data: the static
# checkers (collective-contract, jaxpr-hazard) walk these cases instead of
# hard-coding method lists, so a newly registered method or preset is
# covered by CI the moment it lands in ``retrieval.METHODS`` / ``CASCADES``.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StepCase:
    """One enumerable step program.

    kind:          ``scores`` | ``search`` | ``cascade``.
    method:        registry method (``None`` for cascade cases — the spec
                   carries its own stage methods).
    engine:        ``dist`` (the serving pipeline) or ``scan`` (the
                   per-query verification graphs).
    cascade:       CascadeSpec or preset name for ``kind="cascade"``.
    scale_guarded: True when the case promises corpus-size-independent
                   all-gather traffic (the PR-4 "score matrix never
                   crosses the mesh" contract): the checker compiles it
                   at two corpus sizes and fails on O(n) all-gather
                   growth. False for plain ``search`` (``lax.top_k``
                   does not partition, so its top-l legitimately gathers
                   scores — the cascade step exists to avoid exactly
                   that) and for fractional-budget cascades (candidate
                   counts scale with n BY DESIGN).
    use_kernels:   True routes the case through the fused Pallas kernels
                   inside the ``kernels/partition`` shard_map shims (the
                   checker passes its mesh, so the shims engage) — the
                   kernel cases extend the scaling guard to the shimmed
                   programs, pinning the "candidate gather stays outside
                   the shard_map" contract.
    precision:     mixed-precision policy preset (``repro.core.precision``)
                   the case traces under. The bf16 cases put the halved
                   Phase-1 handoff collectives under the checkers: their
                   replication all-gathers must move ~2x fewer bytes than
                   the matching f32 case, and the precision-lint pass
                   walks them for unintended f32 upcasts.
    """
    name: str
    kind: str
    method: str | None
    engine: str
    cascade: object = None
    scale_guarded: bool = False
    use_kernels: bool = False
    precision: str = "f32"


def step_cases(*, engines: tuple[str, ...] = ("dist", "scan"),
               include_search: bool = True,
               include_cascades: bool = True) -> tuple[StepCase, ...]:
    """Every (kind x method x engine) step the mesh serves, plus the
    jittable cascade presets and one absolute-budget admissible ladder
    (``cascade:pinned``) whose collective traffic must NOT scale with the
    corpus — fractional presets grow their candidate sets with n, so only
    the pinned ladder can carry the scaling guard."""
    cases = [
        StepCase(f"scores:{method}:{engine}", "scores", method, engine,
                 scale_guarded=engine == "dist")
        for method in sorted(retrieval.METHODS)
        for engine in engines
    ]
    if include_search:
        cases += [StepCase(f"search:act:{engine}", "search", "act", engine)
                  for engine in engines]
    if include_cascades:
        from repro import cascade as Cx
        from repro.cascade import rescore
        for preset in sorted(Cx.CASCADES):
            if rescore.resolve(Cx.CASCADES[preset].rescorer).jittable:
                cases.append(StepCase(f"cascade:{preset}:dist", "cascade",
                                      None, "dist", cascade=preset))
        pinned = Cx.CascadeSpec(
            stages=(Cx.CascadeStage("rwmd", 24),
                    Cx.CascadeStage("act", 8, iters=2)),
            rescorer="ict")
        cases.append(StepCase("cascade:pinned:dist", "cascade", None,
                              "dist", cascade=pinned, scale_guarded=True))
        cases.append(StepCase("cascade:pinned:dist:kernels", "cascade",
                              None, "dist", cascade=pinned,
                              scale_guarded=True, use_kernels=True))
        # Sourced ladders: stage 1 reads only the candidate source's
        # probed rows, so the mesh traffic of the WHOLE step — index
        # state in, candidate gathers through — must stay flat as the
        # corpus grows. That is the subsystem's core promise and these
        # cases put it under the scaling guard.
        from repro import candidates as candidates_mod
        sourced_lsh = Cx.CascadeSpec(
            stages=(Cx.CascadeStage("rwmd", 24),
                    Cx.CascadeStage("act", 8, iters=2)),
            rescorer="ict",
            source=candidates_mod.CentroidLSHSpec(
                n_buckets=16, probes=4, bucket_cap=8, refine=16))
        sourced_tree = Cx.CascadeSpec(
            stages=(Cx.CascadeStage("rwmd", 24),
                    Cx.CascadeStage("act", 8, iters=2)),
            rescorer="ict",
            source=candidates_mod.ClusterTreeSpec(
                branching=4, depth=2, beam=4, probes=2, leaf_cap=8))
        cases.append(StepCase("cascade:sourced:lsh:dist", "cascade", None,
                              "dist", cascade=sourced_lsh,
                              scale_guarded=True))
        cases.append(StepCase("cascade:sourced:lsh:dist:kernels", "cascade",
                              None, "dist", cascade=sourced_lsh,
                              scale_guarded=True, use_kernels=True))
        cases.append(StepCase("cascade:sourced:tree:dist", "cascade", None,
                              "dist", cascade=sourced_tree,
                              scale_guarded=True))
    if "dist" in engines:
        cases += [
            StepCase(f"scores:{method}:dist:kernels", "scores", method,
                     "dist", scale_guarded=True, use_kernels=True)
            for method in sorted(m for m, s in retrieval.METHODS.items()
                                 if s.supports_kernels)
        ]
        # bf16-policy cases: same guarded programs, half-width Phase-1
        # handoffs. One jnp-pipeline case and one kernel-shim case keep
        # both lowering paths' collective bytes and jaxprs under CI.
        cases += [
            StepCase("scores:act:dist:bf16", "scores", "act", "dist",
                     scale_guarded=True, precision="bf16"),
            StepCase("scores:act:dist:kernels:bf16", "scores", "act",
                     "dist", scale_guarded=True, use_kernels=True,
                     precision="bf16"),
        ]
    return tuple(cases)


def build_step(case: StepCase, workload, mesh=None, *, top_l: int = 4,
               pad_multiple: int = DEFAULT_ROW_PAD_MULTIPLE, **score_kw):
    """Build one registry case for ``workload``: the jitted mesh program
    when ``mesh`` is given (collective checker), the raw traceable
    callable when it is ``None`` (jaxpr hazard walker — no devices
    needed). ``score_kw`` are the usual batch knobs."""
    score_kw.setdefault("use_kernels", case.use_kernels)
    score_kw.setdefault("precision", case.precision)
    if case.kind == "scores":
        if mesh is not None:
            return jit_scores_step(workload, mesh, method=case.method,
                                   engine=case.engine, **score_kw)
        return make_scores_step(workload.iters, method=case.method,
                                engine=case.engine, **score_kw)
    if case.kind == "search":
        if mesh is not None:
            return jit_search_step(workload, mesh, top_l=top_l,
                                   method=case.method, engine=case.engine,
                                   **score_kw)
        return make_search_step(workload.iters, top_l,
                                n_valid=workload.n_db, method=case.method,
                                engine=case.engine, **score_kw)
    assert case.kind == "cascade", case.kind
    if mesh is not None:
        return jit_cascade_search_step(workload, mesh, case.cascade,
                                       top_l=top_l,
                                       pad_multiple=pad_multiple,
                                       engine=case.engine, **score_kw)
    return make_cascade_search_step(case.cascade, top_l, workload.n_db,
                                    engine=case.engine, **score_kw)
