"""Distributed LC-ACT similarity search (the paper's workload, scaled out).

One scoring step: a batch of queries against a vocabulary-backed histogram
database. Serving callers should reach this through
``repro.api.EmdIndex`` (``backend="distributed"``), which builds the mesh,
shardings, and jitted step from this module internally.

Sharding (DESIGN.md section 2):
  * Phase 1 — queries over ``data``, vocabulary rows over ``model``:
    the v x h distance matmul is TP-sharded; the per-row top-k is local.
  * handoff — the tiny (v, k) ladders are all-gathered over ``model``
    (v*k floats, ~2 MB at 20News scale).
  * Phase 2/3 — database rows over ``model``, queries over ``data``: the
    pour is embarrassingly parallel over the (query, row) grid; the final
    score matrix lands P(data, model).
  * top-l — per-shard top-l then a single small gather.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import lc
from repro.launch.mesh import data_axes


#: Database rows are padded to a multiple of this so the corpus shards on
#: any mesh. Overridable per call site (``repro.api.EngineConfig``
#: carries it as ``pad_multiple``).
DEFAULT_ROW_PAD_MULTIPLE = 512


def _dp(mesh):
    axes = data_axes(mesh)
    return axes if len(axes) > 1 else axes[0]


def make_scores_step(iters: int):
    """Returns scores_step(corpus_ids, corpus_w, coords, q_ids, q_w)
    -> full (nq, n) LC-ACT score matrix."""
    from repro.sharding import annotate
    k = iters + 1

    def scores_step(corpus_ids, corpus_w, coords, q_ids, q_w):
        def p1(qi, qw):
            return lc.phase1(coords, qi, qw, k)       # Z, W: (v, k)

        Z, W = jax.vmap(p1)(q_ids, q_w)               # (nq, v, k)
        # Pin the top-k OUTPUT layout: queries stay on their data shards,
        # the (v, k) ladders replicated. Without this, XLA hoists the
        # resharding above the top-k and all-gathers the full (nq, v, h)
        # distance tensor — 36 GB/device at 20News scale (EXPERIMENTS.md
        # section Perf, emd-20news iteration 1).
        Z = annotate.constrain(Z, ("pod", "data"), None, None)
        W = annotate.constrain(W, ("pod", "data"), None, None)

        def pour_one(Zq, Wq):
            Zg = Zq[corpus_ids]                       # (n, hmax, k)
            if iters == 0:
                return jnp.sum(corpus_w * Zg[..., 0], axis=-1)
            Wg = Wq[corpus_ids][..., :iters]
            return lc.pour(corpus_w, Zg, Wg, iters)

        return jax.vmap(pour_one)(Z, W)               # (nq, n)

    return scores_step


def make_search_step(iters: int, top_l: int, n_valid: int | None = None):
    """Returns search_step(corpus_ids, corpus_w, coords, q_ids, q_w)
    -> (top-l scores, top-l indices), each (nq, top_l).

    ``n_valid``: number of real (non-padding) database rows. Zero-weight
    pad rows score 0 — the best possible score — so they must be masked
    out before top-l, not after. ``None`` = no padding."""
    scores_step = make_scores_step(iters)

    def search_step(corpus_ids, corpus_w, coords, q_ids, q_w):
        scores = scores_step(corpus_ids, corpus_w, coords, q_ids, q_w)
        if n_valid is not None and n_valid < corpus_ids.shape[0]:
            col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
            scores = jnp.where(col < n_valid, scores,
                               jnp.asarray(lc.PAD_DIST, scores.dtype))
        neg, idx = jax.lax.top_k(-scores, top_l)
        return -neg, idx

    return search_step


def search_shardings(mesh, workload):
    """(in_shardings, out_shardings) for search_step on ``mesh``."""
    dp = _dp(mesh)
    ns = lambda *spec: NamedSharding(mesh, P(*spec))
    in_sh = (
        ns("model", None),        # corpus_ids (n, hmax)
        ns("model", None),        # corpus_w   (n, hmax)
        ns(None, None),           # coords     (v, m) — replicated (small*m)
        ns(dp, None),             # q_ids      (nq, hmax)
        ns(dp, None),             # q_w        (nq, hmax)
    )
    out_sh = (ns(dp, None), ns(dp, None))
    return in_sh, out_sh


def scores_shardings(mesh, workload):
    """(in_shardings, out_sharding) for scores_step on ``mesh``: the full
    (nq, n) matrix lands P(data, model) — queries on their data shards,
    database columns on the model shards that poured them."""
    dp = _dp(mesh)
    in_sh, _ = search_shardings(mesh, workload)
    return in_sh, NamedSharding(mesh, P(dp, "model"))


def search_input_specs(workload,
                       pad_multiple: int = DEFAULT_ROW_PAD_MULTIPLE) -> tuple:
    """ShapeDtypeStruct stand-ins for one scoring step of ``workload``.

    The database row count is padded to a multiple of ``pad_multiple``
    (zero-weight pad rows are masked out before top-l) so it shards on
    any mesh."""
    w = workload
    n = -(-w.n_db // pad_multiple) * pad_multiple
    return (
        jax.ShapeDtypeStruct((n, w.hmax), jnp.int32),
        jax.ShapeDtypeStruct((n, w.hmax), jnp.float32),
        jax.ShapeDtypeStruct((w.vocab, w.dim), jnp.float32),
        jax.ShapeDtypeStruct((w.queries, w.hmax), jnp.int32),
        jax.ShapeDtypeStruct((w.queries, w.hmax), jnp.float32),
    )


def jit_search_step(workload, mesh, top_l: int = 16, iters: int | None = None,
                    n_valid: int | None = None):
    """``n_valid`` defaults to the workload's real row count so top-l never
    returns the zero-scoring pad rows added by ``search_input_specs``."""
    iters = workload.iters if iters is None else iters
    n_valid = workload.n_db if n_valid is None else n_valid
    step = make_search_step(iters, top_l, n_valid=n_valid)
    in_sh, out_sh = search_shardings(mesh, workload)
    return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)


def jit_scores_step(workload, mesh, iters: int | None = None):
    """Jitted full-score-matrix step on ``mesh`` (``repro.api`` backend)."""
    iters = workload.iters if iters is None else iters
    step = make_scores_step(iters)
    in_sh, out_sh = scores_shardings(mesh, workload)
    return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
