"""jit-able train / prefill / decode steps with full sharding annotations.

These are the functions the dry-run lowers against the production meshes
and the train/serve drivers execute on real devices. Everything is built
from the config: input specs, parameter shardings, and the step callables.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import model as M
from repro.models.config import ModelConfig, InputShape
from repro.optim import adamw
from repro.optim.grad_utils import accumulate_grads
from repro.sharding import rules

#: KV-cache capacity padding: seq_len + 512 keeps the sequence dim divisible
#: by every mesh-axis product we shard it over (16, 256, 512).
CACHE_PAD = 512


def microbatches_for(cfg: ModelConfig, shape: InputShape) -> int:
    """Gradient-accumulation factor: keeps per-device activation memory
    bounded for the widest archs (EXPERIMENTS.md section Dry-run)."""
    tokens = shape.seq_len * shape.global_batch
    if cfg.d_model >= 16_384:
        return 8                      # nemotron-4-340b
    if cfg.d_model >= 5_000 or tokens > 2 ** 21:
        return 4
    return 1


# ----------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct stand-ins — never allocated)
# ----------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct pytree for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    tok = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)
    emb = lambda s: jax.ShapeDtypeStruct(s, jnp.bfloat16)
    if shape.kind == "train":
        batch = {"labels": tok((B, S))}
        if cfg.frontend != "none":
            batch["embeddings"] = emb((B, S, cfg.d_model))
        else:
            batch["tokens"] = tok((B, S))
        return batch
    if shape.kind == "prefill":
        batch = {}
        if cfg.frontend != "none":
            batch["embeddings"] = emb((B, S, cfg.d_model))
        else:
            batch["tokens"] = tok((B, S))
        return batch
    # decode: one new token against a cache of S past tokens
    batch = {"cache_index": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.frontend != "none":
        batch["embeddings"] = emb((B, 1, cfg.d_model))
    else:
        batch["tokens"] = tok((B, 1))
    return batch


def abstract_params(cfg: ModelConfig) -> Any:
    rng = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda: M.init(rng, cfg))


def abstract_opt_state(cfg: ModelConfig) -> Any:
    params = abstract_params(cfg)
    return jax.eval_shape(lambda: adamw.init(params, cfg.opt_state_dtype))


def abstract_cache(cfg: ModelConfig, shape: InputShape) -> Any:
    cap = shape.seq_len + CACHE_PAD
    return jax.eval_shape(lambda: M.init_decode_cache(
        cfg, shape.global_batch, cap - 1, dtype=jnp.bfloat16))


# ----------------------------------------------------------------------------
# Steps
# ----------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, shape: InputShape,
                    opt_cfg: adamw.AdamWConfig | None = None,
                    n_micro: int | None = None, mode: str = "tp"):
    from repro.sharding import annotate

    opt_cfg = opt_cfg or adamw.AdamWConfig()
    n_micro = microbatches_for(cfg, shape) if n_micro is None else n_micro

    def train_step(params, opt_state, batch):
        with annotate.mode(mode):
            loss_fn = lambda p, b: M.train_loss(p, b, cfg)
            loss, grads = accumulate_grads(loss_fn, params, batch, n_micro)
            params, opt_state, metrics = adamw.update(grads, opt_state,
                                                      params, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, cache = M.prefill(params, batch, cfg)
        return logits, cache
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, batch, cache):
        return M.decode_step(params, batch, cache, cfg)
    return decode_step


# ----------------------------------------------------------------------------
# EMD search steps (the paper's retrieval workload) — delegated to
# ``launch/search.py`` so drivers (dryrun, serve) consume ONE steps surface
# for every cell type, model or EMD. The method is workload-driven:
# ``EMDWorkload.method`` picks any ``retrieval.METHODS`` registry entry.
# ----------------------------------------------------------------------------

def make_emd_search_step(workload, top_l: int = 16, **score_kw):
    """Unjitted method-generic search step for ``workload`` (cost model /
    single-device use; ``jit_emd_search_step`` adds mesh shardings)."""
    from repro.launch import search as Sx
    return Sx.make_search_step(workload.iters, top_l,
                               method=Sx.workload_method(workload),
                               **score_kw)


def emd_search_input_specs(workload, **kw):
    from repro.launch import search as Sx
    return Sx.search_input_specs(workload, **kw)


def jit_emd_search_step(workload, mesh, **kw):
    from repro.launch import search as Sx
    return Sx.jit_search_step(workload, mesh, **kw)


def make_emd_cascade_step(workload, spec, top_l: int = 16, **score_kw):
    """Unjitted cascaded prune-and-rescore step for ``workload`` (see
    ``launch/search.make_cascade_search_step``; ``spec`` is a
    ``repro.cascade`` CascadeSpec or preset name)."""
    from repro.launch import search as Sx
    return Sx.make_cascade_search_step(spec, top_l, workload.n_db,
                                       **score_kw)


def jit_emd_cascade_step(workload, mesh, spec, **kw):
    from repro.launch import search as Sx
    return Sx.jit_cascade_search_step(workload, mesh, spec, **kw)


# ----------------------------------------------------------------------------
# jit wrapping with shardings for a given mesh
# ----------------------------------------------------------------------------

def _shardings(tree_of_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


def jit_train_step(cfg: ModelConfig, shape: InputShape, mesh, *,
                   mode: str = "tp", **kw):
    kw["mode"] = mode
    params_abs = abstract_params(cfg)
    opt_abs = abstract_opt_state(cfg)
    batch_abs = input_specs(cfg, shape)
    p_spec = rules.param_specs(params_abs, mesh, mode)
    o_spec = {"m": p_spec, "v": p_spec, "step": P()}
    b_spec = rules.batch_specs(batch_abs, mesh, mode)
    m_spec = {"loss": P(), "grad_norm": P(), "lr": P()}
    step = make_train_step(cfg, shape, **kw)
    jitted = jax.jit(
        step,
        in_shardings=(_shardings(p_spec, mesh), _shardings(o_spec, mesh),
                      _shardings(b_spec, mesh)),
        out_shardings=(_shardings(p_spec, mesh), _shardings(o_spec, mesh),
                       _shardings(m_spec, mesh)),
        donate_argnums=(0, 1),
    )
    return jitted, (params_abs, opt_abs, batch_abs)


def jit_prefill_step(cfg: ModelConfig, shape: InputShape, mesh):
    params_abs = abstract_params(cfg)
    batch_abs = input_specs(cfg, shape)
    p_spec = rules.param_specs(params_abs, mesh)
    b_spec = rules.batch_specs(batch_abs, mesh)
    cache_abs = jax.eval_shape(
        lambda p, b: make_prefill_step(cfg)(p, b)[1], params_abs, batch_abs)
    c_spec = rules.cache_specs(cache_abs, cfg, mesh)
    out_spec = (rules.logits_spec(mesh, shape.global_batch, cfg.vocab), c_spec)
    jitted = jax.jit(
        make_prefill_step(cfg),
        in_shardings=(_shardings(p_spec, mesh), _shardings(b_spec, mesh)),
        out_shardings=_shardings(out_spec, mesh),
    )
    return jitted, (params_abs, batch_abs)


def jit_decode_step(cfg: ModelConfig, shape: InputShape, mesh):
    params_abs = abstract_params(cfg)
    batch_abs = input_specs(cfg, shape)
    cache_abs = abstract_cache(cfg, shape)
    p_spec = rules.param_specs(params_abs, mesh)
    b_spec = rules.batch_specs(batch_abs, mesh)
    c_spec = rules.cache_specs(cache_abs, cfg, mesh)
    out_spec = (rules.logits_spec(mesh, shape.global_batch, cfg.vocab), c_spec)
    jitted = jax.jit(
        make_decode_step(cfg),
        in_shardings=(_shardings(p_spec, mesh), _shardings(b_spec, mesh),
                      _shardings(c_spec, mesh)),
        out_shardings=_shardings(out_spec, mesh),
        donate_argnums=(2,),
    )
    return jitted, (params_abs, batch_abs, cache_abs)
