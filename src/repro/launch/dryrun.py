import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run driver.

For every (architecture x input-shape) cell — plus the paper's own two EMD
search workloads — lower + compile the step on the production mesh(es),
print memory_analysis / cost_analysis, extract roofline terms, and append a
JSON record to the results file.

The two lines above MUST stay the first statements in this module: jax locks
the device count at first init, and only the dry-run is allowed to see 512
placeholder host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
"""
import argparse
import contextlib
import json
import sys
import time

import jax

from repro.analysis.hlo_collectives import collective_bytes
from repro.analysis.jaxpr_cost import cost_of
from repro.configs import ARCH_IDS, EMD_IDS, get_config
from repro.launch import steps as St
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES, cells_for

# --- TPU v5e hardware constants (roofline denominators) ---
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link


def model_flops(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) for train; 2*N for per-token fwd."""
    n = cfg.param_count()
    if cfg.is_moe:
        # active params: replace full expert stack by experts_per_token
        full_moe = cfg.n_layers * (3 if cfg.mlp == "swiglu" else 2) \
            * cfg.n_experts * cfg.d_model * cfg.d_ff
        active_moe = full_moe * cfg.experts_per_token / cfg.n_experts
        n = n - full_moe + active_moe
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * n * tokens


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, mode: str = "tp",
             overrides: dict | None = None) -> dict:
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.monotonic()

    with jax.set_mesh(mesh):       # ambient mesh: activation annotations
        if arch in EMD_IDS:
            jitted = St.jit_emd_search_step(cfg, mesh)
            args = St.emd_search_input_specs(cfg)
            lowered = jitted.lower(*args)
            jcost = cost_of(St.make_emd_search_step(cfg, 16), *args)
            # LC-ACT "model flops": the algorithm's own matmul term
            # (Phase-1 vhm per query) — everything else is intended overhead.
            mf = 2.0 * cfg.queries * cfg.vocab * cfg.hmax * cfg.dim
        else:
            shape = SHAPES[shape_name]
            if shape.kind == "train":
                jitted, (p, o, b) = St.jit_train_step(cfg, shape, mesh,
                                                      mode=mode)
                lowered = jitted.lower(p, o, b)
                jcost = cost_of(St.make_train_step(cfg, shape), p, o, b)
            elif shape.kind == "prefill":
                jitted, (p, b) = St.jit_prefill_step(cfg, shape, mesh)
                lowered = jitted.lower(p, b)
                jcost = cost_of(St.make_prefill_step(cfg), p, b)
            else:
                jitted, (p, b, c) = St.jit_decode_step(cfg, shape, mesh)
                lowered = jitted.lower(p, b, c)
                jcost = cost_of(St.make_decode_step(cfg), p, b, c)
            mf = model_flops(cfg, shape)

        compiled = lowered.compile()
    t_compile = time.monotonic() - t0

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo, n_dev)

    # Global terms: jaxpr counter (exact scan trip counts); XLA's numbers
    # kept for reference (they count loop bodies once — see analysis/).
    flops = float(jcost["flops"])
    bytes_acc = float(jcost["bytes"])
    coll_total = sum(coll.values())

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mode": mode,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "compile_s": round(t_compile, 1),
        "hlo_flops": flops,
        "hlo_bytes": bytes_acc,
        "xla_flops_per_dev": float(xla_cost.get("flops", 0.0)) if xla_cost else 0.0,
        "collective_bytes": coll_total,
        "collectives": coll,
        "model_flops": mf,
        # roofline terms (seconds)
        "t_compute": flops / (n_dev * PEAK_FLOPS),
        "t_memory": bytes_acc / (n_dev * HBM_BW),
        "t_collective": coll_total / (n_dev * LINK_BW),
        "memory_analysis": str(mem),
    }
    for key in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                "output_size_in_bytes", "temp_size_in_bytes"):
        val = getattr(mem, key, None)
        if val is not None:
            rec[key] = int(val)
    terms = {"compute": rec["t_compute"], "memory": rec["t_memory"],
             "collective": rec["t_collective"]}
    rec["bottleneck"] = max(terms, key=terms.get)
    rec["useful_flops_ratio"] = (mf / flops) if flops else 0.0
    if verbose:
        print(f"== {arch} x {shape_name} on {rec['mesh']} "
              f"(compile {t_compile:.1f}s) ==")
        print("memory_analysis:", mem)
        print("cost_analysis: flops=%.3e bytes=%.3e" % (flops, bytes_acc))
        print("collectives:", {k: f"{v:.3e}" for k, v in coll.items()})
        print("roofline: compute=%.4fs memory=%.4fs collective=%.4fs -> %s"
              % (rec["t_compute"], rec["t_memory"], rec["t_collective"],
                 rec["bottleneck"]))
        sys.stdout.flush()
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["no", "yes", "both"], default="no")
    ap.add_argument("--mode", choices=["tp", "fsdp", "ep"], default="tp")
    ap.add_argument("--remat-policy", choices=["full", "dots"], default=None)
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (int/float/str inferred)")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    args = ap.parse_args()
    overrides = {}
    if args.remat_policy:
        overrides["remat_policy"] = args.remat_policy
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            with contextlib.suppress(ValueError):
                v = float(v)
        overrides[k] = v
    overrides = overrides or None

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in ARCH_IDS:
            for s in cells_for(arch):
                cells.append((arch, s))
        for emd in EMD_IDS:
            cells.append((emd, "search"))
    else:
        assert args.arch, "--arch or --all required"
        if args.arch in EMD_IDS:
            cells.append((args.arch, "search"))
        else:
            shapes = [args.shape] if args.shape else cells_for(args.arch)
            cells += [(args.arch, s) for s in shapes]

    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multi_pod]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    failures = []
    for arch, shape in cells:
        for mp in pods:
            try:
                rec = run_cell(arch, shape, mp, mode=args.mode,
                               overrides=overrides)
                with open(args.out, "a") as f:
                    rec = dict(rec)
                    rec.pop("memory_analysis")
                    f.write(json.dumps(rec) + "\n")
            except Exception as e:  # noqa: BLE001 - report and continue
                failures.append((arch, shape, mp, repr(e)))
                print(f"FAILED {arch} x {shape} mp={mp}: {e!r}")
                sys.stdout.flush()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f_ in failures:
            print(" ", f_)
        sys.exit(1)
    print("\nall dry-run cells compiled OK")


if __name__ == "__main__":
    main()
