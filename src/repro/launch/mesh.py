"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
while tests and benches must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one 256-chip v5e pod) or 2x16x16 (two pods).

    Axes: ``data`` carries batch/FSDP, ``model`` carries TP/EP/SP, ``pod``
    is an outer pure-DP axis (cross-pod traffic = gradient all-reduce only).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2, *,
                   multi_pod: bool = False):
    """Small mesh over host devices for distributed unit tests."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes the global batch is sharded over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh) -> int:
    return mesh.shape.get("model", 1)
