"""Cascade rescorers: the measures that score the final survivor set.

Any ``retrieval.METHODS`` entry with a candidate-compacted scorer
(``MethodSpec.cand_fn``) can rescore — ``act`` and ``ict`` are the usual
choices. This module adds the two measures that live OUTSIDE the method
registry because they cannot serve full corpora:

* ``sinkhorn`` — Cuturi's entropic OT cost (``core/sinkhorn``), vmapped
  per (query, candidate) pair. Jittable. NOT treated as admissible-above
  the Theorem-2 stages: the fixed-iteration, mass-renormalized plan is
  not exactly feasible, so its cost can dip below true EMD — cascades
  ending here report measured recall (see ``spec._AT_LEAST_EMD``).
* ``emd``      — the exact transportation LP (``core/emd``), one HiGHS
  solve per pair on the host. The ground truth; NOT jittable, so a
  cascade ending in ``emd`` runs its pruning stages on device and
  rescoring on the host (and is rejected by the mesh step).
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lc
from repro.core.geometry import pairwise_dist
from repro.core.retrieval import METHODS
from repro.core.sinkhorn import sinkhorn_cost

Array = jax.Array

#: Sinkhorn rescoring knobs (the paper's lambda; fewer iterations than the
#: oracle default — rescoring runs per surviving pair, and 100 rounds is
#: converged at histogram sizes the cascade rescores).
SINKHORN_LAM = 20.0
SINKHORN_ITERS = 100


@dataclasses.dataclass(frozen=True)
class Rescorer:
    """One final-stage scorer. Exactly one of ``fn`` (jittable
    candidate scorer, cascade stays one jitted program) or ``host_fn``
    (numpy rescoring of device-pruned candidates) is set."""
    name: str
    fn: Callable | None = None
    host_fn: Callable | None = None

    @property
    def jittable(self) -> bool:
        return self.fn is not None


def sinkhorn_cand(corpus: lc.Corpus, Q_ids: Array, Q_w: Array,
                  cand: Array, *, block_q: int = 8, **_) -> Array:
    """Entropic-OT cost per (query, candidate) pair: (nq, b) scores.

    One stacked Phase-1-style distance matmul feeds every pair's
    (hmax, h) cost matrix. Costs stay UNMASKED (no ``lc.PAD_DIST``):
    Sinkhorn's log-domain scaling handles zero-mass padding bins by
    itself (their plan mass is ~1e-35), while a 1e30 cost would blow up
    the dual updates — ``eps * C`` must stay in float range.
    """
    nq, h = Q_ids.shape
    qc = corpus.coords[Q_ids.reshape(-1)]                # (nq*h, m)
    Dq = jnp.moveaxis(
        pairwise_dist(corpus.coords, qc).reshape(corpus.v, nq, h), 1, 0)

    def blk(Db, Wb, cb):                     # (bq, v, h), (bq, h), (bq, b)
        C = lc.gather_per_query(Db, corpus.ids[cb])
        x = corpus.w[cb]                     # (bq, b, hmax)
        pair = lambda p, q, c: sinkhorn_cost(p, q, c, lam=SINKHORN_LAM,
                                             n_iters=SINKHORN_ITERS)
        return jax.vmap(jax.vmap(pair, in_axes=(0, None, 0)))(x, Wb, C)
    return lc._map_query_blocks(blk, (Dq, Q_w, cand), Q_ids.shape[0],
                                block_q)


def emd_cand_host(corpus: lc.Corpus, Q_ids, Q_w, cand, **_) -> np.ndarray:
    """Exact EMD per (query, candidate) pair, solved on the host:
    (nq, b) float64 scores. Zero-weight (padding) bins are stripped per
    pair before the LP; an all-padding row scores 0 against everything
    (it carries no mass) — callers never rank such rows highly because
    pad rows are excluded from candidacy upstream."""
    from repro.core.emd import emd_exact
    ids = np.asarray(corpus.ids)
    w = np.asarray(corpus.w)
    Q_ids = np.asarray(Q_ids)
    Q_w = np.asarray(Q_w)
    cand = np.asarray(cand)
    nq, b = cand.shape
    out = np.zeros((nq, b))
    for u in range(nq):
        vq = Q_w[u] > 0.0
        if not vq.any():
            continue                                    # padding query
        qc = corpus.coords[np.asarray(Q_ids[u][vq])]
        D = np.asarray(pairwise_dist(corpus.coords, qc))  # (v, h_valid)
        for j in range(b):
            r = cand[u, j]
            vr = w[r] > 0.0
            if not vr.any():
                continue
            C = D[ids[r][vr]]
            out[u, j] = emd_exact(w[r][vr], Q_w[u][vq], C)
    return out


RESCORERS: dict[str, Rescorer] = {
    "sinkhorn": Rescorer("sinkhorn", fn=sinkhorn_cand),
    "emd": Rescorer("emd", host_fn=emd_cand_host),
}


def names() -> tuple[str, ...]:
    """Every valid rescorer: registry methods with a candidate scorer
    plus the cascade-only measures above."""
    return tuple(sorted([m for m, s in METHODS.items()
                         if s.cand_fn is not None] + list(RESCORERS)))


def resolve(name: str) -> Rescorer:
    """Rescorer for ``name``; registry methods wrap their ``cand_fn``."""
    if name in RESCORERS:
        return RESCORERS[name]
    spec = METHODS.get(name)
    if spec is not None and spec.cand_fn is not None:
        return Rescorer(name, fn=spec.cand_fn)
    raise ValueError(f"unknown rescorer {name!r}; one of {sorted(names())}")
