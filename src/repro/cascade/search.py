"""The cascade driver: prune with cheap bounds, rescore the survivors.

One search is a ladder of ``(method, budget)`` stages (``CascadeSpec``):
stage 1 scores the FULL corpus through the registry's batched multi-query
engine and keeps its ``budget`` best rows per query; every later stage
scores only the surviving candidate set through the method's
candidate-compacted engine (``retrieval.cand_scores`` — Phase 1 unchanged,
Phase 2/3 gather-compacted to a ``(nq, budget)`` sub-corpus); the final
rescorer ranks the last survivors and the top-l comes from ITS scores,
mapped back to global row ids.

The whole ladder jits into one program when the rescorer is jittable
(every registry method, ``sinkhorn``); the exact-``emd`` rescorer prunes
on device and rescores on the host. ``topk_blocks`` selects the
shard-blocked top-budget used by the distributed step: per-block local
top-k (each block = one model shard's columns) followed by a ladder merge
of the small winner tensors — the full (nq, n) score matrix is never
gathered across the mesh. Tie-breaking caveat: the merged selection
resolves equal scores by (block, local rank) rather than the plain
``lax.top_k`` global-lowest-index rule, so exactly-tied boundary rows may
swap between equally-valid candidate sets.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cascade import rescore
from repro.cascade.spec import CascadeSpec, resolve_spec
from repro.core import lc, retrieval
from repro.sharding import annotate

Array = jax.Array

_KNOBS = ("use_kernels", "block_v", "block_h", "block_n", "rev_block",
          "block_q", "mesh")


class CascadeResult(NamedTuple):
    """Top-l outcome of one cascaded search (ascending rescorer scores and
    the matching global database row ids, (nq, top_l) each)."""
    scores: Array
    indices: Array


def topk_smallest(scores: Array, k: int, blocks: int = 1):
    """(values, indices) of the k smallest entries per row, ascending.

    ``blocks > 1`` runs the shard-blocked schedule (the distributed
    step's ladder merge): per-block local top-k, then one merge over the
    ``blocks * min(k, n/blocks)`` winners. Exact for any block count —
    a block can hold at most min(k, n/blocks) of the true top-k — and
    falls back to plain ``lax.top_k`` when n does not split evenly.

    The per-block selection is ``lc.streaming_smallest_k``, NOT
    ``lax.top_k``: top_k lowers to a sort/TopK custom call the SPMD
    partitioner cannot shard, so on the mesh it all-gathers the whole
    (nq, blocks, n/blocks) score tensor over "model" before selecting —
    exactly the corpus-scaled traffic this schedule exists to avoid (the
    static collective checker's scaling guard caught it). The streaming
    form is built from min/where/iota, which partitions shard-locally,
    and makes the same selection (ascending, ties to the lowest column).
    """
    n = scores.shape[-1]
    if blocks > 1 and n % blocks == 0:
        per = n // blocks
        kb = min(k, per)
        s = annotate.emd_shard_topk(
            scores.reshape(scores.shape[:-1] + (blocks, per)))
        zv, li = lc.streaming_smallest_k(s, kb)      # shard-local top-k
        negv = -zv
        gi = li + (jnp.arange(blocks, dtype=jnp.int32) * per)[:, None]
        negv = annotate.emd_ladder(
            negv.reshape(scores.shape[:-1] + (blocks * kb,)))
        gi = annotate.emd_ladder(
            gi.reshape(scores.shape[:-1] + (blocks * kb,)))
        neg, pos = jax.lax.top_k(negv, k)            # ladder merge
        return -neg, jnp.take_along_axis(gi, pos, axis=-1)
    neg, idx = jax.lax.top_k(-scores, k)
    return -neg, idx


def stage_rows(spec: CascadeSpec, n: int, top_l: int) -> dict[str, int]:
    """Rows scored per query by each stage of ``spec`` on an ``n``-row
    corpus: stage 1 reads the full corpus, later stages and the rescorer
    read the previous stage's survivors (the budget ladder)."""
    budgets = spec.resolve_budgets(n, top_l)
    rows, prev = {}, n
    for i, s in enumerate(spec.stages):
        rows[f"stage{i + 1}.{s.method}"] = prev
        prev = budgets[i]
    rows[f"rescore.{spec.rescorer}"] = prev
    return rows


def _prune(corpus: lc.Corpus, Q_ids: Array, Q_w: Array, spec: CascadeSpec,
           budgets: tuple[int, ...], *, n_valid, topk_blocks, engine,
           **knobs) -> Array:
    """Run the pruning ladder; returns the (nq, budgets[-1]) global row
    ids surviving every stage (traced under jit by the callers)."""
    first = spec.stages[0]
    s = retrieval.batch_scores(corpus, Q_ids, Q_w, method=first.method,
                               iters=first.iters, engine=engine, **knobs)
    _, cand = topk_smallest(lc.mask_pad_rows(s, n_valid), budgets[0],
                            topk_blocks)
    for stage, b in zip(spec.stages[1:], budgets[1:], strict=True):
        sc = retrieval.cand_scores(corpus, Q_ids, Q_w, cand,
                                   method=stage.method, iters=stage.iters,
                                   **knobs)
        _, pos = topk_smallest(sc, b)
        cand = jnp.take_along_axis(cand, pos, axis=1)
    return cand


@functools.partial(jax.jit, static_argnames=("spec", "top_l", "n_valid",
                                             "topk_blocks", "engine")
                   + _KNOBS)
def _cascade_device(corpus: lc.Corpus, Q_ids: Array, Q_w: Array,
                    spec: CascadeSpec, top_l: int, *, n_valid=None,
                    topk_blocks: int = 1, engine: str = "batched",
                    **knobs) -> CascadeResult:
    """Whole ladder + jittable rescorer as ONE jitted program."""
    n = n_valid if n_valid is not None else corpus.n
    budgets = spec.resolve_budgets(n, top_l)
    cand = _prune(corpus, Q_ids, Q_w, spec, budgets, n_valid=n_valid,
                  topk_blocks=topk_blocks, engine=engine, **knobs)
    fn = rescore.resolve(spec.rescorer).fn
    rescored = fn(corpus, Q_ids, Q_w, cand, iters=spec.rescorer_iters,
                  **knobs)
    vals, pos = topk_smallest(rescored, top_l)
    return CascadeResult(vals, jnp.take_along_axis(cand, pos, axis=1))


@functools.partial(jax.jit, static_argnames=("spec", "top_l", "n_valid",
                                             "topk_blocks", "engine")
                   + _KNOBS)
def _prune_jit(corpus, Q_ids, Q_w, spec, top_l, *, n_valid=None,
               topk_blocks=1, engine="batched", **knobs) -> Array:
    n = n_valid if n_valid is not None else corpus.n
    budgets = spec.resolve_budgets(n, top_l)
    return _prune(corpus, Q_ids, Q_w, spec, budgets, n_valid=n_valid,
                  topk_blocks=topk_blocks, engine=engine, **knobs)


def cascade_search(corpus: lc.Corpus, Q_ids: Array, Q_w: Array,
                   spec: CascadeSpec | str, top_l: int, *,
                   n_valid: int | None = None, topk_blocks: int = 1,
                   engine: str = "batched", use_kernels: bool = False,
                   block_v: int = 256, block_h: int = 256,
                   block_n: int = 256, rev_block: int = 256,
                   block_q: int = 8, mesh=None) -> CascadeResult:
    """Cascaded top-l search of a ``(nq, h)`` query batch.

    ``spec`` is a :class:`~repro.cascade.spec.CascadeSpec` or a preset
    name from :data:`~repro.cascade.spec.CASCADES`. ``n_valid`` masks
    zero-weight pad rows beyond it out of candidacy (the distributed
    step's padded corpora); ``topk_blocks`` picks the shard-blocked
    stage-1 top-budget (the mesh step passes its model-axis size). The
    remaining knobs mirror ``retrieval.batch_scores``; ``use_kernels``
    routes the full-corpus stage-1 scoring through the Phase-1/2 kernels
    AND every candidate stage + jittable registry rescorer through the
    fused candidate kernels (``kernels/cand_pour`` — per-query gather and
    reduction in one launch, matching the reference candidate engines to
    within a few ulps, so an admissible cascade's exact-top-l guarantee is
    unchanged; ``block_n``/``block_v`` tile them). ``mesh`` (static,
    hashable) routes the kernel path of every stage through the
    ``kernels/partition`` shard_map shims when its axes divide — this is
    how the distributed step runs the kernel cascade COMPILED.
    """
    spec = resolve_spec(spec)
    knobs = dict(engine=engine, use_kernels=use_kernels, block_v=block_v,
                 block_h=block_h, block_n=block_n, rev_block=rev_block,
                 block_q=block_q, mesh=mesh)
    if rescore.resolve(spec.rescorer).jittable:
        return _cascade_device(corpus, Q_ids, Q_w, spec, top_l,
                               n_valid=n_valid, topk_blocks=topk_blocks,
                               **knobs)
    # Host rescorer (exact emd): device pruning, numpy rescoring.
    cand = np.asarray(_prune_jit(corpus, Q_ids, Q_w, spec, top_l,
                                 n_valid=n_valid, topk_blocks=topk_blocks,
                                 **knobs))
    rescored = rescore.resolve(spec.rescorer).host_fn(corpus, Q_ids, Q_w,
                                                      cand)
    pos = np.argsort(rescored, axis=1, kind="stable")[:, :top_l]
    return CascadeResult(
        jnp.asarray(np.take_along_axis(rescored, pos, axis=1),
                    jnp.float32),
        jnp.asarray(np.take_along_axis(cand, pos, axis=1), jnp.int32))


def topk_recall(indices, ref_indices) -> float:
    """Fraction of the reference top-l retrieved by ``indices``, averaged
    over queries — the cascade-vs-full agreement number reported by
    ``benchmarks/bench_cascade.py`` (1.0 for an admissible cascade with
    sufficient budgets). Delegates to :func:`retrieval.topl_overlap`."""
    return retrieval.topl_overlap(indices, ref_indices)
