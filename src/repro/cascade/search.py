"""The cascade driver: prune with cheap bounds, rescore the survivors.

One search is a ladder of ``(method, budget)`` stages (``CascadeSpec``):
stage 1 scores the FULL corpus through the registry's batched multi-query
engine — or, when the spec names a sublinear candidate source
(``repro.candidates``), only the rows the built source emits, which is
what breaks the O(n) stage-1 wall — and keeps its ``budget`` best rows
per query; every later stage
scores only the surviving candidate set through the method's
candidate-compacted engine (``retrieval.cand_scores`` — Phase 1 unchanged,
Phase 2/3 gather-compacted to a ``(nq, budget)`` sub-corpus); the final
rescorer ranks the last survivors and the top-l comes from ITS scores,
mapped back to global row ids.

The whole ladder jits into one program when the rescorer is jittable
(every registry method, ``sinkhorn``); the exact-``emd`` rescorer prunes
on device and rescores on the host. ``topk_blocks`` selects the
shard-blocked top-budget used by the distributed step: per-block local
top-k (each block = one model shard's columns) followed by a ladder merge
of the small winner tensors — the full (nq, n) score matrix is never
gathered across the mesh. Tie-breaking caveat: the merged selection
resolves equal scores by (block, local rank) rather than the plain
``lax.top_k`` global-lowest-index rule, so exactly-tied boundary rows may
swap between equally-valid candidate sets.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cascade import rescore
from repro.cascade.spec import CascadeSpec, resolve_spec
from repro.core import lc, retrieval
from repro.sharding import annotate

Array = jax.Array

_KNOBS = ("use_kernels", "block_v", "block_h", "block_n", "rev_block",
          "block_q", "mesh", "precision")


class CascadeResult(NamedTuple):
    """Top-l outcome of one cascaded search (ascending rescorer scores and
    the matching global database row ids, (nq, top_l) each)."""
    scores: Array
    indices: Array


def topk_smallest(scores: Array, k: int, blocks: int = 1):
    """(values, indices) of the k smallest entries per row, ascending.

    ``blocks > 1`` runs the shard-blocked schedule (the distributed
    step's ladder merge): per-block local top-k, then one merge over the
    ``blocks * min(k, n/blocks)`` winners. Exact for any block count —
    a block can hold at most min(k, n/blocks) of the true top-k — and
    falls back to plain ``lax.top_k`` when n does not split evenly.

    The per-block selection is ``lc.streaming_smallest_k``, NOT
    ``lax.top_k``: top_k lowers to a sort/TopK custom call the SPMD
    partitioner cannot shard, so on the mesh it all-gathers the whole
    (nq, blocks, n/blocks) score tensor over "model" before selecting —
    exactly the corpus-scaled traffic this schedule exists to avoid (the
    static collective checker's scaling guard caught it). The streaming
    form is built from min/where/iota, which partitions shard-locally,
    and makes the same selection (ascending, ties to the lowest column).
    """
    n = scores.shape[-1]
    if blocks > 1 and n % blocks == 0:
        per = n // blocks
        kb = min(k, per)
        s = annotate.emd_shard_topk(
            scores.reshape(scores.shape[:-1] + (blocks, per)))
        zv, li = lc.streaming_smallest_k(s, kb)      # shard-local top-k
        negv = -zv
        gi = li + (jnp.arange(blocks, dtype=jnp.int32) * per)[:, None]
        negv = annotate.emd_ladder(
            negv.reshape(scores.shape[:-1] + (blocks * kb,)))
        gi = annotate.emd_ladder(
            gi.reshape(scores.shape[:-1] + (blocks * kb,)))
        neg, pos = jax.lax.top_k(negv, k)            # ladder merge
        return -neg, jnp.take_along_axis(gi, pos, axis=-1)
    neg, idx = jax.lax.top_k(-scores, k)
    return -neg, idx


def _source_budgets(spec: CascadeSpec, budgets: tuple[int, ...],
                    width: int, top_l: int) -> tuple[int, ...]:
    """Clamp the resolved budget ladder to a sourced stage 1's candidate
    ``width`` — the source already pruned below any larger budget."""
    if width < top_l:
        raise ValueError(
            f"candidate source emits {width} rows per query, fewer than "
            f"top_l={top_l} ({spec.describe()})")
    return tuple(min(b, width) for b in budgets)


def stage_rows(spec: CascadeSpec, n: int, top_l: int) -> dict[str, int]:
    """Rows scored per query by each stage of ``spec`` on an ``n``-row
    corpus: stage 1 reads the full corpus — or, sourced, only the
    source's candidate width — later stages and the rescorer read the
    previous stage's survivors (the budget ladder)."""
    budgets = spec.resolve_budgets(n, top_l)
    prev = n
    if spec.sourced:
        width = spec.source.width
        if width is not None:
            prev = min(width, n)
            budgets = _source_budgets(spec, budgets, prev, top_l)
    rows = {}
    for i, s in enumerate(spec.stages):
        rows[f"stage{i + 1}.{s.method}"] = prev
        prev = budgets[i]
    rows[f"rescore.{spec.rescorer}"] = prev
    return rows


def _prune(corpus: lc.Corpus, Q_ids: Array, Q_w: Array, spec: CascadeSpec,
           budgets: tuple[int, ...], *, n_valid, topk_blocks, engine,
           source=None, **knobs):
    """Run the pruning ladder; returns ``(cand, cmask)``: the
    (nq, budgets[-1]) global row ids surviving every stage, plus their
    validity mask when stage 1 was fed by a sublinear source (``None``
    on the full-scan path, where every survivor is real). Traced under
    jit by the callers.

    Full scan keeps the original path BITWISE: full-corpus
    ``batch_scores`` + (shard-blocked) top-budget. A sourced stage 1
    instead scores only the source's candidate rows through the
    method's candidate-compacted engine, with the source's invalid
    slots (under-full buckets) pushed to ``lc.PAD_DIST`` so they rank
    last; the mask rides along the ladder because a later gather can
    still select one when a query's probed buckets hold fewer real rows
    than the final budget.
    """
    first = spec.stages[0]
    if source is None or source.spec.full_scan:
        s = retrieval.batch_scores(corpus, Q_ids, Q_w, method=first.method,
                                   iters=first.iters, engine=engine,
                                   **knobs)
        _, cand = topk_smallest(lc.mask_pad_rows(s, n_valid), budgets[0],
                                topk_blocks)
        cmask = None
    else:
        cand, cmask = source.candidates(corpus, Q_ids, Q_w)
        sc = retrieval.cand_scores(corpus, Q_ids, Q_w, cand,
                                   method=first.method, iters=first.iters,
                                   **knobs)
        sc = jnp.where(cmask, sc, lc.PAD_DIST)
        _, pos = topk_smallest(sc, budgets[0])
        cand = jnp.take_along_axis(cand, pos, axis=1)
        cmask = jnp.take_along_axis(cmask, pos, axis=1)
    for stage, b in zip(spec.stages[1:], budgets[1:], strict=True):
        sc = retrieval.cand_scores(corpus, Q_ids, Q_w, cand,
                                   method=stage.method, iters=stage.iters,
                                   **knobs)
        if cmask is not None:
            sc = jnp.where(cmask, sc, lc.PAD_DIST)
        _, pos = topk_smallest(sc, b)
        cand = jnp.take_along_axis(cand, pos, axis=1)
        if cmask is not None:
            cmask = jnp.take_along_axis(cmask, pos, axis=1)
    return cand, cmask


def _resolved_budgets(spec: CascadeSpec, source, n: int,
                      top_l: int) -> tuple[int, ...]:
    """Budget ladder for one search: fraction resolution + sourced
    clamping to the built source's (static) candidate width."""
    budgets = spec.resolve_budgets(n, top_l)
    if source is not None and not source.spec.full_scan:
        budgets = _source_budgets(spec, budgets, source.width, top_l)
    return budgets


@functools.partial(jax.jit, static_argnames=("spec", "top_l", "n_valid",
                                             "topk_blocks", "engine")
                   + _KNOBS)
def _cascade_device(corpus: lc.Corpus, Q_ids: Array, Q_w: Array,
                    spec: CascadeSpec, top_l: int, *, n_valid=None,
                    topk_blocks: int = 1, engine: str = "batched",
                    source=None, **knobs) -> CascadeResult:
    """Whole ladder + jittable rescorer as ONE jitted program. ``source``
    is a built candidate source (a pytree argument — its spec rides in
    the treedef, so distinct indexes of the same spec share a compile)."""
    n = n_valid if n_valid is not None else corpus.n
    budgets = _resolved_budgets(spec, source, n, top_l)
    cand, cmask = _prune(corpus, Q_ids, Q_w, spec, budgets,
                         n_valid=n_valid, topk_blocks=topk_blocks,
                         engine=engine, source=source, **knobs)
    fn = rescore.resolve(spec.rescorer).fn
    rescored = fn(corpus, Q_ids, Q_w, cand, iters=spec.rescorer_iters,
                  **knobs)
    if cmask is not None:
        rescored = jnp.where(cmask, rescored, lc.PAD_DIST)
    vals, pos = topk_smallest(rescored, top_l)
    return CascadeResult(vals, jnp.take_along_axis(cand, pos, axis=1))


@functools.partial(jax.jit, static_argnames=("spec", "top_l", "n_valid",
                                             "topk_blocks", "engine")
                   + _KNOBS)
def _prune_jit(corpus, Q_ids, Q_w, spec, top_l, *, n_valid=None,
               topk_blocks=1, engine="batched", source=None, **knobs):
    n = n_valid if n_valid is not None else corpus.n
    budgets = _resolved_budgets(spec, source, n, top_l)
    return _prune(corpus, Q_ids, Q_w, spec, budgets, n_valid=n_valid,
                  topk_blocks=topk_blocks, engine=engine, source=source,
                  **knobs)


def cascade_search(corpus: lc.Corpus, Q_ids: Array, Q_w: Array,
                   spec: CascadeSpec | str, top_l: int, *,
                   n_valid: int | None = None, topk_blocks: int = 1,
                   engine: str = "batched", use_kernels: bool = False,
                   block_v: int = 256, block_h: int = 256,
                   block_n: int = 256, rev_block: int = 256,
                   block_q: int = 8, mesh=None, precision: str = "f32",
                   source=None) -> CascadeResult:
    """Cascaded top-l search of a ``(nq, h)`` query batch.

    ``spec`` is a :class:`~repro.cascade.spec.CascadeSpec` or a preset
    name from :data:`~repro.cascade.spec.CASCADES`. ``n_valid`` masks
    zero-weight pad rows beyond it out of candidacy (the distributed
    step's padded corpora); ``topk_blocks`` picks the shard-blocked
    stage-1 top-budget (the mesh step passes its model-axis size). The
    remaining knobs mirror ``retrieval.batch_scores``; ``use_kernels``
    routes the full-corpus stage-1 scoring through the Phase-1/2 kernels
    AND every candidate stage + jittable registry rescorer through the
    fused candidate kernels (``kernels/cand_pour`` — per-query gather and
    reduction in one launch, matching the reference candidate engines to
    within a few ulps, so an admissible cascade's exact-top-l guarantee is
    unchanged; ``block_n``/``block_v`` tile them). ``mesh`` (static,
    hashable) routes the kernel path of every stage through the
    ``kernels/partition`` shard_map shims when its axes divide — this is
    how the distributed step runs the kernel cascade COMPILED.

    ``source`` is a BUILT candidate source (``spec.source.build(corpus)``
    or the one ``EmdIndex.build`` stores) and is required when
    ``spec.sourced``: stage 1 then scores only the sourced candidates,
    breaking the O(n) stage-1 wall — at the price of measured recall.
    """
    spec = resolve_spec(spec)
    if spec.sourced:
        if source is None:
            raise ValueError(
                f"cascade {spec.describe()} is sourced but no built "
                "candidate source was passed; build one with "
                "spec.source.build(corpus) (EmdIndex does this for you)")
        if source.spec != spec.source:
            raise ValueError(
                f"built source {source.spec.describe()} does not match "
                f"the cascade's source spec {spec.source.describe()}")
    elif source is not None and not source.spec.full_scan:
        raise ValueError(
            f"a {source.spec.describe()} source was passed but cascade "
            f"{spec.describe()} does not declare one (set "
            "CascadeSpec.source so admissibility accounting sees it)")
    knobs = dict(engine=engine, use_kernels=use_kernels, block_v=block_v,
                 block_h=block_h, block_n=block_n, rev_block=rev_block,
                 block_q=block_q, mesh=mesh, precision=precision)
    if rescore.resolve(spec.rescorer).jittable:
        return _cascade_device(corpus, Q_ids, Q_w, spec, top_l,
                               n_valid=n_valid, topk_blocks=topk_blocks,
                               source=source, **knobs)
    # Host rescorer (exact emd): device pruning, numpy rescoring.
    cand, cmask = _prune_jit(corpus, Q_ids, Q_w, spec, top_l,
                             n_valid=n_valid, topk_blocks=topk_blocks,
                             source=source, **knobs)
    cand = np.asarray(cand)
    rescored = rescore.resolve(spec.rescorer).host_fn(corpus, Q_ids, Q_w,
                                                      cand)
    if cmask is not None:
        rescored = np.where(np.asarray(cmask), rescored, lc.PAD_DIST)
    pos = np.argsort(rescored, axis=1, kind="stable")[:, :top_l]
    return CascadeResult(
        jnp.asarray(np.take_along_axis(rescored, pos, axis=1),
                    jnp.float32),
        jnp.asarray(np.take_along_axis(cand, pos, axis=1), jnp.int32))


def topk_recall(indices, ref_indices) -> float:
    """Fraction of the reference top-l retrieved by ``indices``, averaged
    over queries — the cascade-vs-full agreement number reported by
    ``benchmarks/bench_cascade.py`` (1.0 for an admissible cascade with
    sufficient budgets). Delegates to :func:`retrieval.topl_overlap`."""
    return retrieval.topl_overlap(indices, ref_indices)
