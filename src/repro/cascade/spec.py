"""Cascade specifications: typed stage ladders with admissibility checking.

A cascade is a prune-and-rescore pipeline: stage 1 scores the full corpus
with a cheap measure and keeps its ``budget`` best candidates per query;
every later stage scores ONLY the survivors of the previous stage
(gather-compacted, see ``core/lc``'s candidate engines); the final
``rescorer`` scores the last survivor set and the top-l is taken from its
scores. This is the serving pattern Theorem 2's bound hierarchy
(RWMD <= OMR <= ACT-k <= ICT <= EMD) exists to enable.

Admissibility is validated STATICALLY against the bound table below: a
cascade is *admissible* when every stage is a provable lower bound of the
final rescorer. An admissible cascade preserves the exact top-l of
full-corpus rescoring whenever the stage budgets exceed the stage-score
rank of every true top-l neighbor (each true neighbor then survives every
prune); a non-admissible cascade — e.g. the fast ``wcd`` prefetch, whose
bound only holds against exact EMD — is still servable, but its agreement
with full scoring is an empirical recall number, which the API surfaces
(``EmdIndex.recall_at_l``, ``benchmarks/bench_cascade.py``).
"""
from __future__ import annotations

import dataclasses

from repro.candidates import SourceSpec, resolve_source
from repro.core.retrieval import METHODS

#: The paper's directional bound chain, loosest to tightest (Theorem 2:
#: RWMD <= OMR <= ACT-k <= ICT <= EMD). Public: the static registry lint
#: (``repro.analysis.registry_lint``) proves :func:`is_lower_bound` is a
#: partial order consistent with exactly this chain.
BOUND_CHAIN = ("rwmd", "omr", "act", "ict")

#: Chain position of each directional measure in Theorem 2's hierarchy.
#: Tightness keys are (position, iters): a stage lower-bounds a rescorer
#: iff its key is <= the rescorer's. ``act`` with iters=0 degenerates to
#: RWMD (position 0); iters only discriminates act-vs-act.
_CHAIN_POS = {m: i for i, m in enumerate(BOUND_CHAIN)}

#: Final measures every EMD lower bound PROVABLY sits below: exact EMD
#: only. The Sinkhorn rescorer is deliberately absent — a converged
#: entropic plan's cost upper-bounds EMD, but the fixed-iteration,
#: mass-renormalized plan ``rescore.sinkhorn_cand`` computes is not
#: exactly feasible and can dip below the optimum, so cascades rescored
#: by it report measured recall rather than claiming exactness.
_AT_LEAST_EMD = ("emd",)

#: Methods that provably lower-bound exact EMD without being comparable
#: inside the directional chain: ``wcd`` (Jensen: the centroid distance
#: under a Euclidean ground metric is below any transport cost) and
#: ``rwmd_rev`` (the chain's opposite direction). Public for the same
#: reason as :data:`BOUND_CHAIN`.
EMD_ONLY_BOUNDS = ("wcd", "rwmd_rev")
_EMD_ONLY_BOUNDS = EMD_ONLY_BOUNDS


def _tightness(method: str, iters: int) -> tuple[int, int] | None:
    """(chain position, iters) tightness key, or None outside the chain."""
    if method not in _CHAIN_POS:
        return None
    if method == "act":
        return (0, 0) if iters == 0 else (_CHAIN_POS["act"], iters)
    return (_CHAIN_POS[method], 0)


def is_lower_bound(method: str, iters: int, rescorer: str,
                   rescorer_iters: int) -> bool:
    """True when ``method`` is a PROVABLE lower bound of ``rescorer``
    (the per-stage admissibility predicate)."""
    if method == rescorer and (method != "act" or iters <= rescorer_iters):
        return True                         # any measure bounds itself
    if rescorer in _AT_LEAST_EMD:
        return method in _CHAIN_POS or method in _EMD_ONLY_BOUNDS
    a = _tightness(method, iters)
    b = _tightness(rescorer, rescorer_iters)
    if a is None or b is None:
        return False
    if a[0] != b[0]:
        return a[0] < b[0]
    return a[1] <= b[1]                     # act-vs-act: fewer rounds


@dataclasses.dataclass(frozen=True)
class CascadeStage:
    """One pruning stage: score the surviving candidates with ``method``
    and keep the ``budget`` best per query.

    budget: int = absolute rows kept; float in (0, 1] = fraction of the
            corpus, resolved at search time (and clamped to [top_l, n]).
    iters:  LC-ACT Phase-2 rounds (ignored by other methods).
    """
    method: str
    budget: int | float
    iters: int = 1

    def __post_init__(self) -> None:
        if self.method not in METHODS:
            raise ValueError(f"unknown cascade stage method {self.method!r};"
                             f" registered: {sorted(METHODS)}")
        b = self.budget
        if isinstance(b, bool) or b <= 0 or \
                (isinstance(b, float) and b > 1.0):
            raise ValueError(
                f"stage budget must be a positive row count or a fraction "
                f"in (0, 1], got {b!r}")
        if self.iters < 0:
            raise ValueError(f"stage iters must be >= 0, got {self.iters}")


@dataclasses.dataclass(frozen=True)
class CascadeSpec:
    """Frozen description of a prune-and-rescore cascade.

    stages:         pruning ladder, cheapest first; stage 1 scores the
                    full corpus, later stages the previous survivors.
                    Stage methods (after the first) need a registered
                    candidate scorer (``MethodSpec.cand_fn``).
    rescorer:       final measure scoring the last survivor set — any
                    method with a ``cand_fn`` (``act``, ``ict``, ...) or
                    one of the cascade-only rescorers in
                    ``repro.cascade.rescore`` (``sinkhorn``, exact
                    ``emd``; the latter runs host-side).
    rescorer_iters: LC-ACT rounds when the rescorer is ``act``.
    source:         where stage 1's candidates come from: ``None`` or a
                    full-scan source = the whole corpus (the original
                    O(n) path, bitwise unchanged); a sublinear
                    ``SourceSpec`` (``repro.candidates``; registered
                    names like ``"centroid_lsh"`` resolve with their
                    defaults) = stage 1 scores only the rows the built
                    index emits, which forces measured-recall reporting.

    Hashable, so it keys jit caches and rides inside
    ``repro.api.EngineConfig`` unchanged.
    """
    stages: tuple[CascadeStage, ...]
    rescorer: str = "act"
    rescorer_iters: int = 1
    source: SourceSpec | str | None = None

    def __post_init__(self) -> None:
        from repro.cascade import rescore      # late: avoids import cycle
        if self.source is not None:
            object.__setattr__(self, "source", resolve_source(self.source))
        if not self.stages:
            raise ValueError("a cascade needs at least one pruning stage")
        # Stage 1 scores the full corpus through batch_scores; only the
        # later stages run candidate-compacted — unless a sublinear
        # source feeds stage 1, which then compacts too.
        sourced = self.sourced
        for s in self.stages[1:] if not sourced else self.stages:
            if METHODS[s.method].cand_fn is None:
                raise ValueError(
                    f"stage method {s.method!r} has no candidate-compacted "
                    "scorer (MethodSpec.cand_fn); it cannot prune "
                    + ("sourced candidates (a sublinear source makes "
                       "EVERY stage candidate-compacted)" if sourced else
                       "survivors (only the first stage scores "
                       "full-corpus)"))
        rescore.resolve(self.rescorer)         # raises on unknown rescorer
        if self.rescorer_iters < 0:
            raise ValueError("rescorer_iters must be >= 0, "
                             f"got {self.rescorer_iters}")
        fracs = [s.budget for s in self.stages
                 if isinstance(s.budget, float)]
        ints = [s.budget for s in self.stages if isinstance(s.budget, int)]
        for seq in (fracs, ints):
            if any(b > a for a, b in zip(seq, seq[1:], strict=False)):
                raise ValueError(
                    "stage budgets must be non-increasing (each stage "
                    f"prunes), got {[s.budget for s in self.stages]}")

    @property
    def sourced(self) -> bool:
        """True when stage 1 consumes a sublinear candidate source
        instead of scanning the corpus."""
        return self.source is not None and not self.source.full_scan

    @property
    def admissible(self) -> bool:
        """True when EVERY stage provably lower-bounds the rescorer —
        the precondition for the exact-top-l guarantee (budgets
        permitting); False means recall must be measured, not assumed.
        A sublinear source can drop a true neighbor before any stage
        scores it, so only full-scan (or unsourced) cascades can be
        admissible."""
        if self.source is not None and not self.source.admissible:
            return False
        return all(is_lower_bound(s.method, s.iters, self.rescorer,
                                  self.rescorer_iters)
                   for s in self.stages)

    def resolve_budgets(self, n: int, top_l: int) -> tuple[int, ...]:
        """Concrete per-stage survivor counts for a corpus of ``n`` real
        rows: fractions scale by n and everything clamps into
        [top_l, n]. A resolved budget larger than its predecessor's (only
        possible when mixing absolute and fractional budgets — same-kind
        ladders are validated at construction) is an error, not a silent
        clamp: the spec does not actually prune on this corpus."""
        if top_l > n:
            raise ValueError(f"top_l={top_l} exceeds corpus size {n}")
        out = []
        prev = n
        for s in self.stages:
            b = int(round(s.budget * n)) if isinstance(s.budget, float) \
                else int(s.budget)
            b = min(b, n)
            if b > prev:
                raise ValueError(
                    f"stage budgets resolve non-monotonically on n={n}: "
                    f"{s.budget!r} -> {b} rows after a {prev}-row stage "
                    f"({self.describe()})")
            b = max(b, top_l)
            out.append(b)
            prev = b
        return tuple(out)

    def check_servable(self, n: int, top_l: int, *,
                       require_jittable: bool = False) -> None:
        """Raise ``ValueError`` unless this spec can serve an index of
        ``n`` rows at ``top_l`` neighbors — the per-rung validation the
        online serving runtime (``repro.serving``) runs over its whole
        degradation ladder BEFORE taking traffic, so a fallback rung can
        never fail at the moment it is needed.

        Checks: the budgets resolve monotonically on this corpus size
        (``resolve_budgets`` raises otherwise), and — with
        ``require_jittable`` (the distributed backend, whose cascade step
        compiles the rescorer into the mesh program) — that the rescorer
        is device-side, not host-side exact EMD.
        """
        if require_jittable:
            from repro.cascade import rescore    # late: avoids import cycle
            if not rescore.resolve(self.rescorer).jittable:
                raise ValueError(
                    f"cascade rescorer {self.rescorer!r} runs on the host; "
                    "this serving configuration needs a jittable rescorer "
                    f"({self.describe()})")
        self.resolve_budgets(n, top_l)

    def describe(self) -> str:
        """``wcd(20%) -> rwmd(5%) -> act-3`` style one-liner; sourced
        cascades prefix the source, e.g. ``centroid_lsh[...] ~> ...``."""
        def fmt(b):
            return f"{100 * b:g}%" if isinstance(b, float) else str(b)
        parts = [f"{s.method}({fmt(s.budget)})" for s in self.stages]
        final = self.rescorer + (f"-{self.rescorer_iters}"
                                 if self.rescorer == "act" else "")
        chain = " -> ".join(parts + [final])
        if self.sourced:
            return f"{self.source.describe()} ~> {chain}"
        return chain


#: Named cascade presets (``EngineConfig.cascade`` accepts these keys).
CASCADES: dict[str, CascadeSpec] = {
    # The serving default: cheap centroid prefetch, RWMD prune, ACT
    # rescore. NOT admissible (wcd only bounds exact EMD), so its recall
    # vs full ACT is measured — benchmarks/bench_cascade.py tracks it
    # (>= 0.95 recall@16 at these budgets on the text-like workload; the
    # 8x wcd headroom is what the centroid heuristic needs).
    "fast": CascadeSpec(stages=(CascadeStage("wcd", 0.4),
                                CascadeStage("rwmd", 0.05)),
                        rescorer="act", rescorer_iters=3),
    # Admissible ladder inside the Theorem-2 chain: exact top-l whenever
    # budgets cover the true neighbors' stage ranks.
    "chain": CascadeSpec(stages=(CascadeStage("rwmd", 0.2),
                                 CascadeStage("omr", 0.05)),
                         rescorer="act", rescorer_iters=3),
    # Tightest linear-complexity answer: ACT prune, full-ladder ICT
    # rescore (admissible).
    "tight": CascadeSpec(stages=(CascadeStage("rwmd", 0.2),
                                 CascadeStage("act", 0.05, iters=3)),
                         rescorer="ict"),
    # Ground truth at the top: every stage is a provable EMD lower bound
    # (admissible); the exact LP runs host-side on the final survivors.
    "exact": CascadeSpec(stages=(CascadeStage("wcd", 0.2),
                                 CascadeStage("rwmd", 0.1),
                                 CascadeStage("act", 0.02, iters=3)),
                         rescorer="emd"),
}


#: Declared admissibility of every preset — the documentation claim each
#: preset's comment makes, as data. The registry lint recomputes
#: ``CASCADES[name].admissible`` and fails if code and claim diverge
#: (e.g. an edit to the bound table silently flipping a preset's
#: exactness guarantee).
PRESET_ADMISSIBLE: dict[str, bool] = {
    "fast": False,      # wcd bounds exact EMD only, not the act rescorer
    "chain": True,
    "tight": True,
    "exact": True,
}


def resolve_spec(spec: CascadeSpec | str) -> CascadeSpec:
    """A CascadeSpec passes through; a string resolves in :data:`CASCADES`."""
    if isinstance(spec, CascadeSpec):
        return spec
    if spec in CASCADES:
        return CASCADES[spec]
    raise ValueError(f"unknown cascade preset {spec!r}; "
                     f"one of {sorted(CASCADES)}")
