"""Cascaded prune-and-rescore search (the Theorem-2 serving pattern).

The paper's bound hierarchy RWMD <= OMR <= ACT-k <= ICT <= EMD exists so
cheap lower bounds can prune candidates before expensive measures run.
This package makes that a first-class subsystem:

* :class:`CascadeSpec` / :class:`CascadeStage` — typed ``(method,
  budget)`` ladders with STATIC admissibility validation (every stage a
  provable lower bound of the rescorer => exact top-l when budgets cover
  the true neighbors' stage ranks; otherwise recall is measured).
* :func:`cascade_search` — the driver: full-corpus stage 1 through the
  batched registry engines, gather-compacted later stages
  (``retrieval.cand_scores``), rescoring by any registry method or the
  cascade-only ``sinkhorn`` / exact ``emd`` rescorers.
* ``CASCADES`` — named presets (``EngineConfig.cascade`` accepts these).

Serving callers reach this through ``repro.api.EmdIndex``
(``EngineConfig(cascade=...)`` or ``index.search(..., cascade=...)``);
the distributed step in ``launch/search.py`` runs the same driver with a
shard-blocked top-budget.
"""
from repro.cascade.rescore import RESCORERS, Rescorer
from repro.cascade.search import (CascadeResult, cascade_search, stage_rows,
                                  topk_recall, topk_smallest)
from repro.cascade.spec import (CASCADES, CascadeSpec, CascadeStage,
                                is_lower_bound, resolve_spec)

__all__ = [
    "CASCADES", "CascadeResult", "CascadeSpec", "CascadeStage",
    "RESCORERS", "Rescorer", "cascade_search", "is_lower_bound",
    "resolve_spec", "stage_rows", "topk_recall", "topk_smallest",
]
