"""Fault tolerance: checkpointed step loop with failure recovery and
straggler tracking.

``FaultTolerantRunner`` wraps any (state, batch) -> state step function:
  * checkpoints every ``ckpt_every`` steps (atomic, see checkpoint/store);
  * on a step failure (node loss, preemption — surfaced as an exception
    from the runtime), rolls back to the last checkpoint and replays; the
    deterministic data pipeline (data/tokens.py) guarantees replayed
    microbatches are bit-identical;
  * tracks per-step wall time; steps slower than ``straggler_factor`` x the
    running median are recorded so the controller can exclude the offending
    hosts at the next elastic event (runtime/elastic.py). Failed and
    REPLAYED steps are excluded from the timing stats: a replayed step runs
    against warm caches (and a failed one measured the failure, not the
    work), so re-recording either would bias the median the flagging
    threshold compares against.

On a real multi-host cluster the exception source is jax's distributed
runtime (missing heartbeat -> coordinator error); here failures are
injected by tests (and the serving chaos harness, serving/chaos.py), which
exercises the identical recovery path.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from collections import deque
from collections.abc import Callable
from typing import Any

from repro.checkpoint import store

#: Sliding window of per-step wall times kept for the straggler median.
#: Also the memory bound: ``StragglerStats.times`` is a deque capped here,
#: so a long-running service never grows it past 64 floats.
TIME_WINDOW = 64


@dataclasses.dataclass
class StragglerStats:
    times: deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=TIME_WINDOW))
    flagged_steps: list[int] = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float, factor: float) -> bool:
        self.times.append(dt)
        if len(self.times) >= 8:
            med = statistics.median(self.times)
            if dt > factor * med:
                self.flagged_steps.append(step)
                return True
        return False


class FaultTolerantRunner:
    def __init__(self, step_fn: Callable[[Any, Any], Any],
                 batch_fn: Callable[[int], Any], ckpt_dir: str,
                 ckpt_every: int = 10, max_restarts: int = 16,
                 straggler_factor: float = 3.0):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.straggler = StragglerStats()
        self.straggler_factor = straggler_factor
        self.restarts = 0
        # High-water mark of steps whose timing was recorded: steps at or
        # below it are rollback replays and must not re-enter the stats.
        self._timed_through = 0

    def _save(self, state: Any, step: int) -> None:
        store.save(self.ckpt_dir, step, state, extra={"wall": time.time()})

    def _resume_point(self, state: Any) -> tuple[Any, int]:
        last = store.latest_step(self.ckpt_dir)
        if last is None:
            return state, 0
        return store.restore(self.ckpt_dir, last, state), last

    def run(self, state: Any, n_steps: int,
            on_step: Callable[[int, Any], None] | None = None) -> Any:
        """Run to ``n_steps`` total, resuming/replaying through failures."""
        state, step = self._resume_point(state)
        if step == 0:
            self._save(state, 0)
        while step < n_steps:
            try:
                t0 = time.monotonic()
                batch = self.batch_fn(step)
                state = self.step_fn(state, batch)
                dt = time.monotonic() - t0
                step += 1
                if step > self._timed_through:       # first attempt only
                    self.straggler.record(step, dt, self.straggler_factor)
                    self._timed_through = step
                if on_step is not None:
                    on_step(step, state)
                if step % self.ckpt_every == 0:
                    self._save(state, step)
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                state, step = self._resume_point(state)
        self._save(state, step)
        return state
