"""Elastic scaling: re-shard a checkpointed state onto a different mesh.

Scale-up/down = restore on the new mesh: ``reshard_plan`` computes the
target NamedShardings from the same rule table used at train time, so the
plan is always consistent with what the (re)compiled step expects. Nothing
about the checkpoint format depends on the mesh it was written from (leaves
are stored unsharded), which is what makes 8 -> 4 -> 8 device moves a pure
restore (tests/test_distributed.py).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding

from repro.checkpoint import store
from repro.sharding import rules


def reshard_plan(params_like: Any, new_mesh: Mesh) -> Any:
    """Target shardings for ``params_like`` on ``new_mesh``."""
    return rules.param_shardings(params_like, new_mesh)


def restore_on_mesh(ckpt_dir: str, step: int, params_like: Any,
                    new_mesh: Mesh) -> Any:
    """Checkpoint -> params resharded for ``new_mesh`` (the elastic event)."""
    return store.restore(ckpt_dir, step, params_like,
                         shardings=reshard_plan(params_like, new_mesh))


def reshard_live(tree: Any, new_mesh: Mesh, shardings: Any = None) -> Any:
    """In-memory reshard (survivor-only recovery, no checkpoint round-trip).

    ``shardings``: explicit target NamedSharding tree matching ``tree`` —
    for state whose placement is NOT covered by the parameter rule table,
    e.g. a built EmdIndex's Phase-1 tables, whose target is the search
    step's input shardings on the surviving mesh. Defaults to
    :func:`reshard_plan` (the training-parameter rules)."""
    target = reshard_plan(tree, new_mesh) if shardings is None else shardings
    return jax.tree.map(jax.device_put, tree, target)
