"""Unified EMD serving API (the stable surface scaling work lands behind).

One entry point — :class:`EmdIndex` — over the three engines that
previously had four disjoint call conventions:

* ``backend="reference"``  — pjit-able jnp engines in ``core.lc``,
* ``backend="pallas"``     — fused TPU kernels in ``kernels/``,
* ``backend="distributed"``— the mesh-sharded multi-query step in
  ``launch/search.py``.

Configured by the frozen :class:`EngineConfig`; methods are typed
:class:`~repro.core.retrieval.MethodSpec` registry entries.
"""
from repro.api.config import BACKENDS, DISTRIBUTABLE_METHODS, EngineConfig
from repro.api.index import EmdIndex
from repro.cascade import CASCADES, CascadeSpec, CascadeStage
from repro.core.retrieval import METHODS, MethodSpec

__all__ = ["BACKENDS", "CASCADES", "CascadeSpec", "CascadeStage",
           "DISTRIBUTABLE_METHODS", "EngineConfig", "EmdIndex",
           "METHODS", "MethodSpec"]
