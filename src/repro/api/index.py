"""``EmdIndex``: one serving entry point over every EMD engine.

Build once, query many times — the nearest-neighbor index shape
(build/query phases) the paper's batch algorithms imply. ``build``
precomputes and owns everything reusable across queries: the device-placed
corpus, the method spec, and (for ``backend="distributed"``) the mesh,
shardings, row padding, and jitted multi-query step. Callers then write
identical code whether the engine underneath is the pjit-able jnp
reference, the fused Pallas kernels, or a mesh-sharded multi-host step.

    index = EmdIndex.build(corpus, EngineConfig(method="act", iters=3))
    scores = index.scores(q_ids, q_w)          # (h,) -> (n,)
    scores = index.scores(Q_ids, Q_w)          # (nq, h) -> (nq, n)
    top, idx = index.search(q_ids, q_w)        # top-l neighbors
    S = index.all_pairs()                      # n x n symmetric matrix
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import EngineConfig
from repro.core import lc, retrieval
from repro.core.lc import Corpus

Array = jax.Array


def _pad_rows(x: Array, n_padded: int) -> Array:
    return jnp.pad(x, ((0, n_padded - x.shape[0]), (0, 0)))


def _mesh_context(mesh):
    """Ambient-mesh context for sharding annotations. ``jax.set_mesh``
    landed after 0.4.x; without it the in_shardings on the jitted step
    still place data correctly and ``annotate.constrain`` no-ops."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh else contextlib.nullcontext()


@dataclasses.dataclass(frozen=True, repr=False)
class EmdIndex:
    """Immutable handle over a built index. Construct via :meth:`build`."""
    corpus: Corpus
    config: EngineConfig
    _mesh: Any = None
    _scores_step: Any = None
    _padded_corpus: Corpus | None = None

    def __repr__(self) -> str:
        mesh = "" if self._mesh is None else f", mesh={dict(self._mesh.shape)}"
        return (f"EmdIndex(n={self.corpus.n}, hmax={self.corpus.hmax}, "
                f"v={self.corpus.v}, m={self.corpus.m}, "
                f"method={self.config.method!r}, "
                f"backend={self.config.backend!r}{mesh})")

    # ------------------------------------------------------------- build
    @classmethod
    def build(cls, corpus: Corpus, config: EngineConfig | None = None, *,
              mesh=None) -> "EmdIndex":
        """Precompute everything reusable across queries of ``corpus``.

        ``mesh``: distributed backend only — the device mesh to shard
        over; defaults to a single-device (1, 1) data x model mesh so
        single-host callers and multi-host launchers run the same code.
        """
        config = EngineConfig() if config is None else config
        if config.backend != "distributed":
            return cls(corpus=jax.device_put(corpus), config=config)

        from repro.configs.emd_20news import EMDWorkload
        from repro.launch import mesh as mesh_mod
        from repro.launch import search as dsearch

        mesh = mesh_mod.make_test_mesh(1, 1) if mesh is None else mesh
        n_pad = -(-corpus.n // config.pad_multiple) * config.pad_multiple
        padded = Corpus(ids=_pad_rows(corpus.ids, n_pad),
                        w=_pad_rows(corpus.w, n_pad), coords=corpus.coords)
        workload = EMDWorkload(name="emd-index", n_db=corpus.n,
                               vocab=corpus.v, dim=corpus.m,
                               hmax=corpus.hmax,
                               iters=config.effective_iters, queries=0,
                               method=config.method)
        step = dsearch.jit_scores_step(workload, mesh,
                                       **config.dist_step_kwargs())
        in_sh, _ = dsearch.scores_shardings(mesh, workload,
                                            method=config.method)
        padded = Corpus(ids=jax.device_put(padded.ids, in_sh[0]),
                        w=jax.device_put(padded.w, in_sh[1]),
                        coords=jax.device_put(padded.coords, in_sh[2]))
        return cls(corpus=corpus, config=config, _mesh=mesh,
                   _scores_step=step, _padded_corpus=padded)

    # --------------------------------------------------------- properties
    @property
    def n(self) -> int:
        """Number of database histograms."""
        return self.corpus.n

    @property
    def backend(self) -> str:
        return self.config.backend

    @property
    def mesh(self):
        """The device mesh (distributed backend), else ``None``."""
        return self._mesh

    # ------------------------------------------------------------ scoring
    def scores(self, q_ids: Array, q_w: Array) -> Array:
        """Directional bound of every database row vs the query/queries.

        Accepts a single query ``(h,)`` -> ``(n,)`` or a batch
        ``(nq, h)`` -> ``(nq, n)``, uniformly across backends. Lower =
        more similar.
        """
        q_ids = jnp.asarray(q_ids)
        q_w = jnp.asarray(q_w)
        if q_ids.ndim not in (1, 2) or q_ids.shape != q_w.shape:
            raise ValueError(
                f"expected matching (h,) or (nq, h) queries, got "
                f"ids {q_ids.shape} / w {q_w.shape}")
        single = q_ids.ndim == 1
        if self.config.backend == "distributed":
            qi = q_ids[None] if single else q_ids
            qw = q_w[None] if single else q_w
            nq = qi.shape[0]
            # Pad the query batch to the data-axis size so any nq shards.
            from repro.launch.mesh import data_axes
            dp = int(np.prod([self._mesh.shape[a]
                              for a in data_axes(self._mesh)]))
            qi = _pad_rows(qi, -(-nq // dp) * dp)
            qw = _pad_rows(qw, -(-nq // dp) * dp)
            p = self._padded_corpus
            with _mesh_context(self._mesh):
                s = self._scores_step(p.ids, p.w, p.coords, qi, qw)
            s = s[:nq, :self.n]            # drop pad queries and pad rows
            return s[0] if single else s
        kw = self.config.score_kwargs()
        if single:
            return retrieval.query_scores(self.corpus, q_ids, q_w,
                                          symmetric=self.config.symmetric,
                                          **kw)
        return retrieval.batch_scores(self.corpus, q_ids, q_w,
                                      symmetric=self.config.symmetric,
                                      engine=self.config.batch_engine, **kw)

    def search(self, q_ids: Array, q_w: Array,
               top_l: int | None = None) -> tuple[Array, Array]:
        """(scores, indices) of the top-l most similar database rows,
        ascending; ``(top_l,)`` each for a single query, ``(nq, top_l)``
        for a batch. ``top_l`` defaults to ``config.top_l``."""
        top_l = self.config.top_l if top_l is None else top_l
        s = self.scores(q_ids, q_w)
        neg, idx = jax.lax.top_k(-s, top_l)
        return -neg, idx

    def all_pairs(self) -> Array:
        """n x n symmetric score matrix over the corpus (the paper's
        evaluation mode; feed to ``retrieval.precision_at_l``)."""
        if self.config.backend == "distributed":
            # NOTE: with config.symmetric the baked-in step already maxes
            # both directions per pair, so the transpose-max below merely
            # re-symmetrizes float noise — directional scoring would halve
            # the Phase-2 work but needs a second jitted step; all_pairs
            # is the (cold) evaluation path, so compile cost wins.
            asym = self.scores(self.corpus.ids, self.corpus.w)
            if self.config.spec.symmetric:
                return asym
            return lc.symmetric_scores(asym)
        return retrieval.all_pairs_scores(self.corpus,
                                          engine=self.config.batch_engine,
                                          **self.config.score_kwargs())

    # ---------------------------------------------------------- plumbing
    def precision_at_l(self, labels, top_l: int | None = None) -> float:
        """Corpus-as-queries precision@top-l (paper Section 6)."""
        top_l = self.config.top_l if top_l is None else top_l
        return retrieval.precision_at_l(self.all_pairs(),
                                        jnp.asarray(np.asarray(labels)),
                                        top_l)

    def with_config(self, **changes) -> "EmdIndex":
        """Rebuild this index with ``dataclasses.replace``d config."""
        return EmdIndex.build(self.corpus,
                              dataclasses.replace(self.config, **changes),
                              mesh=self._mesh)
