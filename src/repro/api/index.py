"""``EmdIndex``: one serving entry point over every EMD engine.

Build once, query many times — the nearest-neighbor index shape
(build/query phases) the paper's batch algorithms imply. ``build``
precomputes and owns everything reusable across queries: the device-placed
corpus, the method spec, and (for ``backend="distributed"``) the mesh,
shardings, row padding, and jitted multi-query step. Callers then write
identical code whether the engine underneath is the pjit-able jnp
reference, the fused Pallas kernels, or a mesh-sharded multi-host step.

    index = EmdIndex.build(corpus, EngineConfig(method="act", iters=3))
    scores = index.scores(q_ids, q_w)          # (h,) -> (n,)
    scores = index.scores(Q_ids, Q_w)          # (nq, h) -> (nq, n)
    top, idx = index.search(q_ids, q_w)        # top-l neighbors
    S = index.all_pairs()                      # n x n symmetric matrix
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import EngineConfig
from repro.core import lc, retrieval
from repro.core.lc import Corpus

Array = jax.Array


def _pad_rows(x: Array, n_padded: int) -> Array:
    return jnp.pad(x, ((0, n_padded - x.shape[0]), (0, 0)))


def _mesh_context(mesh):
    """Ambient-mesh context for sharding annotations. ``jax.set_mesh``
    landed after 0.4.x; without it the in_shardings on the jitted step
    still place data correctly and ``annotate.constrain`` no-ops."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh else contextlib.nullcontext()


@dataclasses.dataclass(frozen=True, repr=False)
class EmdIndex:
    """Immutable handle over a built index. Construct via :meth:`build`."""
    corpus: Corpus
    config: EngineConfig
    _mesh: Any = None
    _scores_step: Any = None
    _padded_corpus: Corpus | None = None
    _cascade_step: Any = None
    _tuned: Any = None
    _source: Any = None

    def __repr__(self) -> str:
        mesh = "" if self._mesh is None else f", mesh={dict(self._mesh.shape)}"
        return (f"EmdIndex(n={self.corpus.n}, hmax={self.corpus.hmax}, "
                f"v={self.corpus.v}, m={self.corpus.m}, "
                f"method={self.config.method!r}, "
                f"backend={self.config.backend!r}{mesh})")

    # ------------------------------------------------------------- build
    @classmethod
    def build(cls, corpus: Corpus, config: EngineConfig | None = None, *,
              mesh=None, source=None) -> "EmdIndex":
        """Precompute everything reusable across queries of ``corpus``.

        ``mesh``: distributed backend only — the device mesh to shard
        over; defaults to a single-device (1, 1) data x model mesh so
        single-host callers and multi-host launchers run the same code.

        When the config's cascade names a sublinear candidate source
        (``repro.candidates``), its index is built here too — the
        host-side quantization/tree fit runs once per build, and
        ``search`` consumes the built arrays afterwards. ``source``
        injects an already-built source instead (checkpoint restore;
        must match ``config.source_spec``).

        With ``config.autotune != "off"`` the kernel tile knobs are
        resolved here, once, through ``repro.kernels.autotune`` (cached
        winners under ``"cached"``, a timed sweep of VMEM-admissible
        configs under ``"force"``); the applied picks are recorded on
        :attr:`tuned_blocks` and the jitted steps below compile with
        them baked in.
        """
        config = EngineConfig() if config is None else config
        tuned: dict = {}
        if config.autotune != "off":
            from repro.kernels import autotune
            config, tuned = autotune.resolve_config(corpus, config)
        src_spec = config.source_spec
        if src_spec is not None and not src_spec.full_scan:
            if source is None:
                source = src_spec.build(corpus)
            elif source.spec != src_spec:
                raise ValueError(
                    f"injected source {source.spec.describe()} does not "
                    f"match config's {src_spec.describe()}")
        else:
            source = None
        if config.backend != "distributed":
            if source is not None:
                source = jax.device_put(source)
            return cls(corpus=jax.device_put(corpus), config=config,
                       _tuned=tuned, _source=source)

        from repro.configs.emd_20news import EMDWorkload
        from repro.launch import mesh as mesh_mod
        from repro.launch import search as dsearch

        mesh = mesh_mod.make_test_mesh(1, 1) if mesh is None else mesh
        n_pad = -(-corpus.n // config.pad_multiple) * config.pad_multiple
        padded = Corpus(ids=_pad_rows(corpus.ids, n_pad),
                        w=_pad_rows(corpus.w, n_pad), coords=corpus.coords)
        workload = EMDWorkload(name="emd-index", n_db=corpus.n,
                               vocab=corpus.v, dim=corpus.m,
                               hmax=corpus.hmax,
                               iters=config.effective_iters, queries=0,
                               method=config.method)
        step = dsearch.jit_scores_step(workload, mesh,
                                       **config.dist_step_kwargs())
        cascade_step = None
        if config.cascade is not None:
            cascade_step = dsearch.jit_cascade_search_step(
                workload, mesh, config.cascade_spec, top_l=config.top_l,
                **config.cascade_step_kwargs())
        in_sh, _ = dsearch.scores_shardings(mesh, workload,
                                            method=config.method)
        padded = Corpus(ids=jax.device_put(padded.ids, in_sh[0]),
                        w=jax.device_put(padded.w, in_sh[1]),
                        coords=jax.device_put(padded.coords, in_sh[2]))
        if source is not None:
            # Small index state, probed at arbitrary buckets: replicated
            # (matches the step's trailing in_shardings).
            from jax.sharding import NamedSharding, PartitionSpec
            source = jax.device_put(source,
                                    NamedSharding(mesh, PartitionSpec()))
        return cls(corpus=corpus, config=config, _mesh=mesh,
                   _scores_step=step, _padded_corpus=padded,
                   _cascade_step=cascade_step, _tuned=tuned,
                   _source=source)

    # --------------------------------------------------------- properties
    @property
    def n(self) -> int:
        """Number of database histograms."""
        return self.corpus.n

    @property
    def backend(self) -> str:
        return self.config.backend

    @property
    def mesh(self):
        """The device mesh (distributed backend), else ``None``."""
        return self._mesh

    @property
    def source(self):
        """The built candidate source feeding cascade stage 1 (``None``
        when the config's cascade is unsourced or full-scan)."""
        return self._source

    @property
    def tuned_blocks(self) -> dict:
        """Autotuned tile picks applied at build: {kernel family ->
        {block knob: tile}}. Empty when ``config.autotune="off"`` or
        nothing was eligible (benches record this next to their
        timings)."""
        return dict(self._tuned or {})

    # ------------------------------------------------------------ scoring
    @staticmethod
    def _check_queries(q_ids: Array, q_w: Array) -> tuple[Array, Array,
                                                          bool]:
        """Validate and normalize query input to a ``(nq, h)`` batch;
        returns (ids, w, was_single)."""
        q_ids = jnp.asarray(q_ids)
        q_w = jnp.asarray(q_w)
        if q_ids.ndim not in (1, 2) or q_ids.shape != q_w.shape:
            raise ValueError(
                f"expected matching (h,) or (nq, h) queries, got "
                f"ids {q_ids.shape} / w {q_w.shape}")
        single = q_ids.ndim == 1
        return ((q_ids[None], q_w[None], True) if single
                else (q_ids, q_w, False))

    def _run_dist_step(self, step, qi: Array, qw: Array, *extra):
        """Run a jitted mesh step on a query batch padded to the data-axis
        size (so any nq shards); returns the outputs with pad-query rows
        still attached — callers slice ``[:nq]``. ``extra`` operands
        (e.g. candidate-source state leaves) append after the queries."""
        from repro.launch.mesh import data_axes
        nq = qi.shape[0]
        dp = int(np.prod([self._mesh.shape[a]
                          for a in data_axes(self._mesh)]))
        qi = _pad_rows(qi, -(-nq // dp) * dp)
        qw = _pad_rows(qw, -(-nq // dp) * dp)
        p = self._padded_corpus
        with _mesh_context(self._mesh):
            return step(p.ids, p.w, p.coords, qi, qw, *extra)

    def scores(self, q_ids: Array, q_w: Array) -> Array:
        """Directional bound of every database row vs the query/queries.

        Accepts a single query ``(h,)`` -> ``(n,)`` or a batch
        ``(nq, h)`` -> ``(nq, n)``, uniformly across backends. Lower =
        more similar.
        """
        qi, qw, single = self._check_queries(q_ids, q_w)
        if self.config.backend == "distributed":
            s = self._run_dist_step(self._scores_step, qi, qw)
            s = s[:qi.shape[0], :self.n]   # drop pad queries and pad rows
            return s[0] if single else s
        q_ids, q_w = (qi[0], qw[0]) if single else (qi, qw)
        kw = self.config.score_kwargs()
        if single:
            return retrieval.query_scores(self.corpus, q_ids, q_w,
                                          symmetric=self.config.symmetric,
                                          **kw)
        return retrieval.batch_scores(self.corpus, q_ids, q_w,
                                      symmetric=self.config.symmetric,
                                      engine=self.config.batch_engine, **kw)

    def search(self, q_ids: Array, q_w: Array, top_l: int | None = None, *,
               cascade=None) -> tuple[Array, Array]:
        """(scores, indices) of the top-l most similar database rows,
        ascending; ``(top_l,)`` each for a single query, ``(nq, top_l)``
        for a batch. ``top_l`` defaults to ``config.top_l``.

        ``cascade`` (a ``repro.cascade`` CascadeSpec or preset name,
        defaulting to ``config.cascade``) routes the search through the
        prune-and-rescore ladder instead of full-corpus scoring: scores
        come from the cascade's rescorer, candidates only from rows that
        survived every pruning stage. On ``backend="distributed"`` the
        mesh cascade step is baked at build time from the config, so the
        spec and ``top_l`` cannot be changed per call there.
        """
        top_l = self.config.top_l if top_l is None else top_l
        cascade = self.config.cascade if cascade is None else cascade
        if cascade is None:
            s = self.scores(q_ids, q_w)
            neg, idx = jax.lax.top_k(-s, top_l)
            return -neg, idx
        return self._cascade(q_ids, q_w, top_l, cascade)

    def _cascade(self, q_ids: Array, q_w: Array, top_l: int,
                 cascade) -> tuple[Array, Array]:
        from repro import cascade as cascade_mod

        if self.config.symmetric:
            raise ValueError(
                "cascade search scores directionally; this index is "
                "configured symmetric=True (same rule EngineConfig "
                "enforces for cascade-in-config)")
        spec = cascade_mod.resolve_spec(cascade)
        qi, qw, single = self._check_queries(q_ids, q_w)
        if self.config.backend == "distributed":
            if spec != self.config.cascade_spec:
                raise ValueError(
                    "the distributed cascade step is baked at build time; "
                    "rebuild with EngineConfig(cascade=...) to change the "
                    "spec")
            if top_l != self.config.top_l:
                raise ValueError(
                    "the distributed cascade step is jitted for "
                    f"top_l={self.config.top_l}; rebuild with "
                    "EngineConfig(top_l=...) to change it")
            nq = qi.shape[0]
            leaves = (jax.tree_util.tree_leaves(self._source)
                      if self._source is not None else ())
            scores, idx = self._run_dist_step(self._cascade_step, qi, qw,
                                              *leaves)
            scores, idx = scores[:nq], idx[:nq]
        else:
            res = cascade_mod.cascade_search(
                self.corpus, qi, qw, spec, top_l,
                engine=self.config.batch_engine,
                source=self._source if spec.sourced else None,
                **self.config.cascade_knobs())
            scores, idx = res.scores, res.indices
        return (scores[0], idx[0]) if single else (scores, idx)

    def all_pairs(self) -> Array:
        """n x n symmetric score matrix over the corpus (the paper's
        evaluation mode; feed to ``retrieval.precision_at_l``)."""
        if self.config.backend == "distributed":
            # NOTE: with config.symmetric the baked-in step already maxes
            # both directions per pair, so the transpose-max below merely
            # re-symmetrizes float noise — directional scoring would halve
            # the Phase-2 work but needs a second jitted step; all_pairs
            # is the (cold) evaluation path, so compile cost wins.
            asym = self.scores(self.corpus.ids, self.corpus.w)
            if self.config.spec.symmetric:
                return asym
            return lc.symmetric_scores(asym)
        return retrieval.all_pairs_scores(self.corpus,
                                          engine=self.config.batch_engine,
                                          **self.config.score_kwargs())

    # ---------------------------------------------------------- plumbing
    def precision_at_l(self, labels, top_l: int | None = None, *,
                       scores: Array | None = None) -> float:
        """Corpus-as-queries precision@top-l (paper Section 6).

        ``scores``: precomputed n x n score matrix (e.g. a cached
        ``all_pairs()`` shared across several top-l evaluations, or an
        externally-computed exact matrix); defaults to scoring the corpus
        with this index's configuration.
        """
        top_l = self.config.top_l if top_l is None else top_l
        scores = self.all_pairs() if scores is None else jnp.asarray(scores)
        return retrieval.precision_at_l(scores,
                                        jnp.asarray(np.asarray(labels)),
                                        top_l)

    def recall_at_l(self, other_scores: Array,
                    top_l: int | None = None, *,
                    scores: Array | None = None) -> float:
        """Agreement with a reference ranking: the fraction of
        ``other_scores``' top-l neighbors (per corpus row, self excluded)
        that this index's scoring also retrieves — e.g. cascade-vs-exact
        or LC-bound-vs-EMD agreement, measurable straight from the API.

        ``other_scores``: the reference n x n matrix (exact EMD, a full
        ACT run, ...). ``scores``: this index's precomputed matrix;
        defaults to ``all_pairs()``.
        """
        top_l = self.config.top_l if top_l is None else top_l
        scores = self.all_pairs() if scores is None else jnp.asarray(scores)
        return retrieval.recall_at_l(scores, jnp.asarray(other_scores),
                                     top_l, exclude_self=True)

    def with_config(self, **changes) -> "EmdIndex":
        """Rebuild this index with ``dataclasses.replace``d config. An
        already-built candidate source is reused when the new config
        keeps the same source spec (the expensive host-side fit does not
        rerun for an unrelated knob change)."""
        config = dataclasses.replace(self.config, **changes)
        reuse = (self._source if self._source is not None
                 and config.source_spec == self._source.spec else None)
        return EmdIndex.build(self.corpus, config, mesh=self._mesh,
                              source=reuse)
