"""Engine configuration for the unified serving API.

``EngineConfig`` is the single typed knob surface that replaces the old
string-keyed ``retrieval.METHODS`` lookups, the loose ``use_kernels`` flag,
and ``jit_search_step``'s positional kwargs. It is frozen and hashable so
it can key jit caches and be shipped around a cluster verbatim.
"""
from __future__ import annotations

import dataclasses

from repro.cascade.spec import CascadeSpec, resolve_spec
from repro.core.precision import POLICIES
from repro.core.retrieval import METHODS

#: Execution engines EmdIndex can place a method on.
BACKENDS = ("reference", "pallas", "distributed")

#: Methods servable on ``backend="distributed"`` — since the mesh step is
#: derived from the registry (every method's batched pipeline runs on the
#: mesh), this is ALL of them. Kept as a public name for callers that
#: feature-gate on it.
DISTRIBUTABLE_METHODS = tuple(sorted(METHODS))


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Frozen description of how an :class:`~repro.api.EmdIndex` scores.

    method:       one of ``rwmd | rwmd_rev | omr | act | bow | wcd``
                  (the typed ``retrieval.METHODS`` registry keys).
    iters:        LC-ACT Phase-2 rounds (ignored by other methods).
    backend:      ``reference`` (pjit-able jnp), ``pallas`` (fused TPU
                  kernels; methods without kernel support fall back to
                  reference compute), or ``distributed`` (mesh-sharded
                  method-generic multi-query step from
                  ``launch/search.py`` — every registered method and all
                  batch knobs apply there too; kernel-capable methods run
                  the fused kernels inside the ``kernels/partition``
                  shard_map shims when ``batch_engine="batched"``, so the
                  kernel path compiles on the mesh).
    symmetric:    score queries with the paper's symmetric measure
                  (max of the two directional bounds; needs a method with
                  a registered reverse, i.e. rwmd). Valid on every
                  backend, including distributed.
    top_l:        default neighbor count for ``EmdIndex.search``.
    batch_engine: multi-query dispatch for ``EmdIndex.scores`` batches:
                  ``batched`` (default) amortizes Phase 1 across the
                  query batch (on ``backend="distributed"`` this is the
                  mesh pipeline, ``engine="dist"``); ``scan`` replays the
                  exact single-query graph per query via ``lax.map`` —
                  bit-for-bit equal to a loop of single-query calls, for
                  verification.
    block_v/block_h/block_n: Pallas kernel tile sizes (vocabulary rows,
                  histogram slots, database rows). Explicit values always
                  win over autotuned picks.
    precision:    mixed-precision policy preset (``repro.core.precision``
                  ``POLICIES``): ``"f32"`` (default — bitwise the
                  historical pipeline), ``"bf16"`` (bf16 Phase-1 storage
                  + handoffs, f32 matmul operands and accumulators —
                  halves table bytes and mesh handoff collectives), or
                  ``"bf16_agg"`` (additionally bf16 matmul operands; the
                  MXU still accumulates f32). Applies to every batched
                  scoring path on every backend; reductions and sentinel
                  writes always stay in the f32 accumulator.
    autotune:     tile-size policy applied at ``EmdIndex.build``
                  (``repro.kernels.autotune``): ``off`` (default — the
                  knobs above are used verbatim), ``cached`` (apply the
                  ``tune_cache`` winner for each kernel launch shape;
                  cache misses keep the defaults, so builds stay
                  deterministic and never time anything), or ``force``
                  (time the VMEM-admissible configs now with the paired
                  bench harness and overwrite the cache). Only knobs
                  still at their dataclass defaults are replaced.
    tune_cache:   path of the ``TuneCache`` JSON file backing
                  ``autotune`` (``None`` = in-memory only: ``cached``
                  sees an empty cache, ``force`` does not persist).
    block_q:      query-block size of the batched engine's Phase-2
                  schedule (queries gathered/poured per tile).
    rev_block:    row-block size of the streamed reverse-RWMD scorer.
    pad_multiple: distributed backend pads database rows to a multiple of
                  this so the corpus shards on any mesh (was a magic 512).
    cascade:      prune-and-rescore ladder for ``EmdIndex.search``: a
                  ``repro.cascade.CascadeSpec`` or a preset name from
                  ``repro.cascade.CASCADES`` (``"fast"``, ``"chain"``,
                  ``"tight"``, ``"exact"``). ``None`` (default) searches
                  by full-corpus scoring with ``method``. With a cascade,
                  ``method``/``iters`` still drive ``scores``/
                  ``all_pairs``; ``search`` runs the ladder (on the
                  distributed backend the mesh cascade step is built at
                  ``EmdIndex.build``, so the rescorer must be jittable —
                  no host-side exact ``emd`` there).
    """
    method: str = "act"
    iters: int = 1
    backend: str = "reference"
    symmetric: bool = False
    top_l: int = 16
    batch_engine: str = "batched"
    block_v: int = 256
    block_h: int = 256
    block_n: int = 256
    block_q: int = 8
    rev_block: int = 256
    pad_multiple: int = 512
    cascade: CascadeSpec | str | None = None
    autotune: str = "off"
    tune_cache: str | None = None
    precision: str = "f32"

    def __post_init__(self) -> None:
        if self.method not in METHODS:
            raise ValueError(f"unknown method {self.method!r}; "
                             f"registered: {sorted(METHODS)}")
        if self.precision not in POLICIES:
            raise ValueError(f"unknown precision policy {self.precision!r}; "
                             f"one of {sorted(POLICIES)}")
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"one of {BACKENDS}")
        if self.iters < 0:
            raise ValueError(f"iters must be >= 0, got {self.iters}")
        if self.top_l < 1:
            raise ValueError(f"top_l must be >= 1, got {self.top_l}")
        if self.batch_engine not in ("batched", "scan"):
            raise ValueError(f"unknown batch_engine {self.batch_engine!r}; "
                             "one of ('batched', 'scan')")
        if self.autotune not in ("off", "cached", "force"):
            raise ValueError(f"unknown autotune mode {self.autotune!r}; "
                             "one of ('off', 'cached', 'force')")
        if min(self.block_v, self.block_h, self.block_n, self.block_q,
               self.rev_block, self.pad_multiple) < 1:
            raise ValueError("block sizes and pad_multiple must be >= 1")
        spec = METHODS[self.method]
        if self.symmetric and not spec.symmetric and spec.reverse is None:
            raise ValueError(
                f"method {self.method!r} has no reverse direction; "
                "symmetric=True needs one (use method='rwmd')")
        if self.cascade is not None:
            if self.symmetric:
                raise ValueError(
                    "cascade search scores directionally; symmetric=True "
                    "is not supported with a cascade")
            cspec = resolve_spec(self.cascade)   # raises on unknown preset
            if self.backend == "distributed":
                from repro.cascade import rescore
                if not rescore.resolve(cspec.rescorer).jittable:
                    raise ValueError(
                        f"cascade rescorer {cspec.rescorer!r} runs on the "
                        "host; the distributed backend needs a jittable "
                        "rescorer (act/ict/sinkhorn/...)")
                if cspec.sourced and cspec.source.width is None:
                    raise ValueError(
                        "the distributed cascade step needs a candidate "
                        "source with an explicit capacity (bucket_cap/"
                        "leaf_cap) so its state shapes are static; "
                        f"{cspec.source.describe()} sizes to the data")

    @property
    def spec(self):
        """The typed :class:`~repro.core.retrieval.MethodSpec` entry."""
        return METHODS[self.method]

    @property
    def cascade_spec(self) -> CascadeSpec | None:
        """The resolved :class:`~repro.cascade.CascadeSpec` (preset names
        looked up in ``repro.cascade.CASCADES``), or ``None``."""
        return None if self.cascade is None else resolve_spec(self.cascade)

    @property
    def source_spec(self):
        """The cascade's candidate-source spec (``repro.candidates``),
        or ``None`` when unsourced / no cascade — the build parameters
        ``EmdIndex.build`` constructs the stage-1 index from."""
        cspec = self.cascade_spec
        return None if cspec is None else cspec.source

    @property
    def effective_iters(self) -> int:
        """Phase-2 rounds actually run (0 for non-ACT methods)."""
        return self.iters if self.spec.uses_iters else 0

    def _kernel_backend(self) -> bool:
        """True when this config's backend runs the fused kernels: the
        single-host pallas backend, or the distributed backend's batched
        pipeline — there the launches run inside the
        ``kernels/partition`` shard_map shims, which is what makes
        compiled ``pallas_call`` legal on the mesh (the scan engine
        replays per-query graphs and keeps kernels off)."""
        return (self.backend == "pallas"
                or (self.backend == "distributed"
                    and self.batch_engine == "batched"))

    def score_kwargs(self) -> dict:
        """Static kwargs for the uniform ``retrieval`` scorer signature."""
        return dict(
            method=self.method,
            iters=self.effective_iters,
            use_kernels=self._kernel_backend() and self.spec.supports_kernels,
            block_v=self.block_v, block_h=self.block_h,
            block_n=self.block_n, rev_block=self.rev_block,
            block_q=self.block_q, precision=self.precision,
        )

    def dist_step_kwargs(self) -> dict:
        """Static kwargs for ``launch.search.jit_scores_step`` — the same
        method + batch knobs as the single-host engines, plus the
        symmetric flag and the mesh engine selector (``batch_engine``
        "batched" traces the mesh pipeline, "scan" the per-query
        verification graphs)."""
        return dict(
            self.score_kwargs(),
            symmetric=self.symmetric,
            engine=("dist" if self.batch_engine == "batched" else "scan"),
        )

    def cascade_knobs(self) -> dict:
        """The batch knobs a cascade accepts: ``score_kwargs`` minus the
        method selection (the cascade spec carries its own stage methods
        and iters). Single place the cascade kwarg contract lives.
        ``use_kernels`` is keyed off the backend alone — NOT off
        ``config.method``'s kernel support, which the cascade never
        runs; methods without kernels simply ignore the flag. On
        ``backend="pallas"`` and the distributed backend's batched
        pipeline it reaches every layer of the ladder: the Phase-1/2
        kernels for stage-1 scoring and the fused candidate kernels
        (``kernels/cand_pour``) for the compacted stages and jittable
        rescorers."""
        kw = self.score_kwargs()
        kw.pop("method")
        kw.pop("iters")
        kw["use_kernels"] = self._kernel_backend()
        return kw

    def cascade_step_kwargs(self) -> dict:
        """Static kwargs for ``launch.search.jit_cascade_search_step``."""
        return dict(
            self.cascade_knobs(),
            engine=("dist" if self.batch_engine == "batched" else "scan"),
            pad_multiple=self.pad_multiple,
        )
