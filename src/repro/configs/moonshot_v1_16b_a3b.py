"""moonshot-v1-16b-a3b — Moonlight-16B-A3B MoE (64 experts, top-6).
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163_840, head_dim=128,
    n_experts=64, experts_per_token=6,
    mlp="swiglu",
)
