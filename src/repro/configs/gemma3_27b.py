"""gemma3-27b — 5:1 local:global attention (window 1024), 262k vocab.
[hf:google/gemma-3 family]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
    d_ff=21_504, vocab=262_144, head_dim=128,
    sliding_window=1024, local_global_ratio=5,
    mlp="swiglu", tie_embeddings=True,
)
