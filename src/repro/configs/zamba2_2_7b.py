"""zamba2-2.7b — Mamba2 backbone + ONE weight-tied shared attention+MLP
block applied every 6 layers. [arXiv:2411.15242]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10_240, vocab=32_000, head_dim=80,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    hybrid_attn_every=6,
    mlp="swiglu",
)
