"""qwen2-vl-7b — M-RoPE; the vision tower is a STUB: inputs are precomputed
patch embeddings. [arXiv:2409.12191; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18_944, vocab=152_064, head_dim=128,
    mlp="swiglu", mrope=True, frontend="vision_patches",
)
