"""musicgen-large — decoder-only over EnCodec tokens; the EnCodec frontend
is a STUB: inputs are precomputed frame embeddings. [arXiv:2306.05284; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048, head_dim=64,
    mlp="gelu", frontend="audio_frames",
)
