"""The paper's own workload: LC-ACT image similarity, MNIST-scale.
n=60,000 images, v=784 pixel coords (717 used), m=2, dense histograms."""
from repro.configs.emd_20news import EMDWorkload

CONFIG = EMDWorkload(name="emd-mnist", n_db=60_000, vocab=784,
                     dim=2, hmax=784, iters=7, queries=1024)
