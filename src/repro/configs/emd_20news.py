"""The paper's own workload: LC-ACT text similarity search, 20News-scale.
n=18,828 docs, h=500 (truncated), v=69,682 words, m=300 (word2vec)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class EMDWorkload:
    name: str
    n_db: int            # database histograms
    vocab: int           # vocabulary size v
    dim: int             # embedding dimension m
    hmax: int            # padded histogram size
    iters: int           # ACT Phase-2 iterations
    queries: int         # query batch scored together
    method: str = "act"  # retrieval.METHODS registry key scored on the mesh


CONFIG = EMDWorkload(name="emd-20news", n_db=18_828, vocab=69_682,
                     dim=300, hmax=500, iters=7, queries=256)
