"""olmo-1b — non-parametric LayerNorm, tied embeddings. [arXiv:2402.00838]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=50_304, head_dim=128,
    mlp="swiglu", norm="nonparametric", tie_embeddings=True,
)
