"""nemotron-4-340b — GQA + squared-ReLU MLP. [arXiv:2402.16819]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18_432, n_heads=96, n_kv_heads=8,
    d_ff=73_728, vocab=256_000, head_dim=192,
    mlp="relu2",
    opt_state_dtype="bfloat16",   # 341B params: fp32 m/v won't fit one pod
)
