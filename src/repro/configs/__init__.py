"""Config registry: ``--arch <id>`` resolution + reduced smoke variants.

``get_config(name)`` returns the full published configuration (exercised
only abstractly, via the dry-run). ``smoke_config(name)`` returns a reduced
same-family variant small enough for a real CPU forward/train step.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCH_IDS = [
    "moonshot-v1-16b-a3b",
    "mixtral-8x22b",
    "mamba2-2.7b",
    "gemma3-27b",
    "nemotron-4-340b",
    "olmo-1b",
    "nemotron-4-15b",
    "musicgen-large",
    "qwen2-vl-7b",
    "zamba2-2.7b",
]

EMD_IDS = ["emd-20news", "emd-mnist"]


def _module_for(name: str) -> str:
    return "repro.configs." + name.replace("-", "_").replace(".", "_")


def get_config(name: str):
    if name not in ARCH_IDS + EMD_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS + EMD_IDS}")
    return importlib.import_module(_module_for(name)).CONFIG


def smoke_config(name: str) -> ModelConfig:
    """Reduced config of the same family: few layers, narrow, tiny vocab."""
    full = get_config(name)
    updates = dict(
        n_layers=4 if full.family != "hybrid" else 4,
        d_model=64,
        d_ff=128 if full.d_ff else 0,
        vocab=256,
        head_dim=16,
        param_dtype="float32",
        opt_state_dtype="float32",
        remat=False,
    )
    if full.n_heads:
        updates["n_heads"] = 4
        updates["n_kv_heads"] = min(full.n_kv_heads, 2) if full.n_kv_heads < full.n_heads else 4
    if full.is_moe:
        updates["n_experts"] = 4
        updates["experts_per_token"] = 2
    if full.ssm_state:
        updates["ssm_state"] = 16
        updates["ssm_head_dim"] = 16
        updates["ssm_chunk"] = 8
    if full.hybrid_attn_every:
        updates["hybrid_attn_every"] = 2
    if full.sliding_window:
        updates["sliding_window"] = 8
    return dataclasses.replace(full, **updates)
