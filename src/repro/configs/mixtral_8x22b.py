"""mixtral-8x22b — 8 experts, top-2 routing. [arXiv:2401.04088; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16_384, vocab=32_768, head_dim=128,
    n_experts=8, experts_per_token=2,
    mlp="swiglu",
    opt_state_dtype="bfloat16",   # 141B params: fp32 m/v won't fit one pod
)
