"""Render markdown roofline tables from the dry-run's JSONL results.

The records come from ``launch/dryrun.py`` (default output
``results/dryrun.jsonl`` — the dry-run must have been run first; this
module only formats). Prints one markdown table per mesh; keeps the LAST
record per (arch, shape, mesh) so re-runs supersede earlier rows.

Usage: PYTHONPATH=src python -m repro.analysis.report [results/dryrun.jsonl]
"""
from __future__ import annotations

import json
import os
import sys


def load(path: str) -> dict:
    if not os.path.exists(path):
        raise SystemExit(
            f"no dry-run results at {path!r} — generate them first with "
            "`PYTHONPATH=src python -m repro.launch.dryrun --all` (or pass "
            "the JSONL path as the first argument)")
    recs = {}
    with open(path) as f:
        for line in f:
            if not line.strip():
                continue
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x*1e6:.1f}us"
    if x < 0.1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.3f}s"


def fmt_e(x: float) -> str:
    return f"{x:.2e}"


def table(recs: dict, mesh: str) -> str:
    rows = [r for (_a, _s, m), r in sorted(recs.items()) if m == mesh]
    out = ["| arch | shape | t_compute | t_memory | t_collective | "
           "bottleneck | HLO FLOPs | model FLOPs | useful | "
           "roofline frac |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        dom = max(r["t_compute"], r["t_memory"], r["t_collective"])
        frac = r["t_compute"] / dom if dom else 0.0
        useful = r.get("useful_flops_ratio", 0.0)
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['t_compute'])} "
            f"| {fmt_s(r['t_memory'])} | {fmt_s(r['t_collective'])} "
            f"| {r['bottleneck']} | {fmt_e(r['hlo_flops'])} "
            f"| {fmt_e(r['model_flops'])} | {useful:.2f} | {frac:.3f} |")
    return "\n".join(out)


def summary(recs: dict, mesh: str) -> str:
    rows = [r for (_a, _s, m), r in sorted(recs.items()) if m == mesh]
    worst = min(rows, key=lambda r: (
        r["t_compute"] / max(r["t_compute"], r["t_memory"],
                             r["t_collective"], 1e-30)))
    most_coll = max(rows, key=lambda r: r["t_collective"]
                    / max(r["t_compute"] + r["t_memory"], 1e-30))
    return (f"worst roofline fraction: {worst['arch']} x {worst['shape']}; "
            f"most collective-bound: {most_coll['arch']} x "
            f"{most_coll['shape']}")


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.jsonl"
    recs = load(path)
    meshes = sorted({m for (_, _, m) in recs})
    for mesh in meshes:
        n = sum(1 for k in recs if k[2] == mesh)
        print(f"\n### Mesh {mesh} ({n} cells)\n")
        print(table(recs, mesh))
        print("\n" + summary(recs, mesh))


if __name__ == "__main__":
    main()
