"""The shared finding type of the static-check suite.

Every pass in ``repro.analysis.check`` returns a flat list of
:class:`Violation` records; the CLI renders them and exits non-zero when
any survive. Kept in its own stdlib-only module so pass modules and the
CLI can share it without import cycles (the CLI must stay importable
before jax initializes — it sets ``XLA_FLAGS`` first).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Violation:
    """One static-contract failure.

    passname: which checker found it (``registry`` / ``hazards`` /
              ``vmem`` / ``collectives`` / ``bench``).
    subject:  the thing checked — a step-case name, kernel family,
              registry entry, or file.
    message:  human-readable description of the broken invariant.
    """
    passname: str
    subject: str
    message: str

    def __str__(self) -> str:
        return f"[{self.passname}] {self.subject}: {self.message}"


def render(violations: list[Violation], *, checked: int,
           passname: str) -> str:
    """One pass's summary line for the CLI report."""
    if not violations:
        return f"PASS {passname}: {checked} subject(s) clean"
    lines = [f"FAIL {passname}: {len(violations)} violation(s) "
             f"across {checked} subject(s)"]
    lines += [f"  - {v}" for v in violations]
    return "\n".join(lines)
