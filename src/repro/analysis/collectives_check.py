"""Collective-contract checker: compile every registry step on the
8-device host mesh and hold its collective traffic to a manifest.

For each :func:`repro.launch.search.step_cases` entry the pass lowers the
jitted step with the real input shardings, compiles it, and extracts
per-kind collective wire bytes from the partitioned HLO
(``analysis.hlo_collectives.collective_bytes`` — trip-count aware, ring
wire model). Two contracts:

* **manifest pin** — the byte profile must equal the checked-in golden
  manifest (``manifests/collectives.json``) exactly. Any partitioner
  regression — a stage constraint dropped, XLA hoisting a reshard above
  the shard-local top-k — shows up as a byte diff long before a profile
  run would catch it. The manifest records the jax version that produced
  it; on a different jax the pin degrades to a warning (partitioner
  output legitimately changes across releases) while the scaling guard
  below still runs. ``--update-manifests`` regenerates.
* **scaling guard** — every case with ``scale_guarded=True`` (the dist
  scores pipelines and the absolute-budget ``cascade:pinned`` ladder) is
  compiled again at double the corpus rows; its all-gather bytes must
  not grow. This machine-checks the PR-4 guarantee that the (nq, n)
  score matrix never crosses the mesh: a corpus-scaled all-gather is
  exactly what a broken ``emd_ladder`` constraint produces. Plain
  ``search`` is exempt (``lax.top_k`` does not partition — its top-l
  legitimately gathers scores; the cascade step exists to avoid that),
  as are fractional-budget cascades (candidate counts scale by design).

Requires 8 host devices: the CLI sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before jax
initializes.
"""
from __future__ import annotations

import json
import os

import jax

from repro.analysis.hlo_collectives import collective_bytes
from repro.analysis.violations import Violation
from repro.configs.emd_20news import EMDWorkload
from repro.launch import search as S
from repro.launch.mesh import make_test_mesh

#: Mesh the contract is pinned on: 2 data x 4 model host devices.
N_DATA, N_MODEL = 2, 4
N_DEVICES = N_DATA * N_MODEL

#: The tiny tracing workload (compiles in ~1 s/step on the host mesh)
#: and its row padding. Dims are multiples of the mesh axes.
CHECK_PAD_MULTIPLE = 8
_BASE = dict(vocab=96, dim=8, hmax=16, iters=2, queries=16)

#: Corpus rows for the manifest compile and the scaling probe. The probe
#: pair starts at 128, not the manifest's 64: the pinned cascade's
#: shard-local ladder is ``blocks * min(budget, n/blocks)`` wide, so its
#: traffic legitimately grows until every shard holds at least the stage
#: budget (n >= blocks * max_budget = 96 here) and is exactly flat after.
CHECK_N_DB = 64
SCALE_N_DBS = (128, 256)

#: All-gather growth tolerated between the two probe sizes before a
#: guarded case fails (absolute bytes; legitimate steps grow by exactly
#: zero — the slack only absorbs control-flow bookkeeping).
GROWTH_TOLERANCE_BYTES = 2048

MANIFEST_PATH = os.path.join(os.path.dirname(__file__), "manifests",
                             "collectives.json")


def check_workload(n_db: int = CHECK_N_DB) -> EMDWorkload:
    return EMDWorkload(name="chk", n_db=n_db, **_BASE)


def step_collectives(case: S.StepCase, workload, mesh, *,
                     step_fn=None) -> dict[str, float]:
    """Compile one case on ``mesh`` and return its per-kind collective
    wire bytes. ``step_fn`` overrides the registry-built jitted step —
    the seeded-violation tests inject through it. Input specs are
    per-case (``S.case_input_specs``): sourced cascades take their
    candidate-index state as trailing operands."""
    specs = S.case_input_specs(case, workload,
                               pad_multiple=CHECK_PAD_MULTIPLE)
    fn = S.build_step(case, workload, mesh,
                      pad_multiple=CHECK_PAD_MULTIPLE) \
        if step_fn is None else step_fn
    hlo = fn.lower(*specs).compile().as_text()
    return {k: float(v)
            for k, v in sorted(collective_bytes(hlo, N_DEVICES).items())}


def make_mesh():
    if len(jax.devices()) < N_DEVICES:
        raise SystemExit(
            f"the collective checker needs {N_DEVICES} host devices; run "
            "under XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{N_DEVICES} (the repro.analysis.check CLI sets this itself "
            "when it starts before jax does)")
    return make_test_mesh(N_DATA, N_MODEL)


def load_manifest(path: str = MANIFEST_PATH) -> dict | None:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def build_manifest(mesh=None) -> dict:
    """Compile every case and record its byte profile."""
    mesh = make_mesh() if mesh is None else mesh
    w = check_workload()
    steps = {c.name: step_collectives(c, w, mesh) for c in S.step_cases()}
    return {
        "jax": jax.__version__,
        "n_devices": N_DEVICES,
        "mesh": [N_DATA, N_MODEL],
        "workload": dict(n_db=CHECK_N_DB, **_BASE),
        "pad_multiple": CHECK_PAD_MULTIPLE,
        "steps": steps,
    }


def write_manifest(manifest: dict, path: str = MANIFEST_PATH) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
        f.write("\n")


def check_scaling(case: S.StepCase, mesh, *,
                  small_fn=None, big_fn=None) -> list[Violation]:
    """All-gather bytes must not grow with the corpus for a guarded case.

    ``small_fn``/``big_fn`` override the two jitted steps (seeded tests).
    """
    n0, n1 = SCALE_N_DBS
    small = step_collectives(case, check_workload(n0), mesh,
                             step_fn=small_fn)
    big = step_collectives(case, check_workload(n1), mesh,
                           step_fn=big_fn)
    ag0 = small.get("all-gather", 0.0)
    ag1 = big.get("all-gather", 0.0)
    if ag1 > ag0 + GROWTH_TOLERANCE_BYTES:
        return [Violation(
            "collectives", case.name,
            f"all-gather bytes scale with the corpus: {ag0:.0f} at "
            f"n={n0} -> {ag1:.0f} at n={n1} — an array "
            "sized by the database rows is crossing the mesh (the "
            "shard-local top-budget / emd_ladder contract is broken)")]
    return []


def run(*, update_manifests: bool = False,
        manifest_path: str = MANIFEST_PATH,
        ) -> tuple[list[Violation], int]:
    """Manifest pin + scaling guard over every registry case."""
    mesh = make_mesh()
    out: list[Violation] = []
    cases = S.step_cases()

    if update_manifests:
        write_manifest(build_manifest(mesh), manifest_path)

    manifest = load_manifest(manifest_path)
    if manifest is None:
        out.append(Violation(
            "collectives", "manifest",
            f"no golden manifest at {manifest_path}; run the CLI with "
            "--update-manifests and commit the result"))
        pinned = {}
        pin_enforced = False
    else:
        pinned = manifest.get("steps", {})
        pin_enforced = manifest.get("jax") == jax.__version__
        if not pin_enforced:
            print(f"collectives: manifest was built on jax "
                  f"{manifest.get('jax')!r}, running {jax.__version__} — "
                  "byte pins reported as warnings only; scaling guard "
                  "still enforced")

    w = check_workload()
    for case in cases:
        got = step_collectives(case, w, mesh)
        want = pinned.get(case.name)
        if want is None:
            if manifest is not None:
                out.append(Violation(
                    "collectives", case.name,
                    "step missing from the golden manifest — rerun with "
                    "--update-manifests and review the new profile"))
        elif got != want:
            msg = (f"collective profile drifted from the manifest: "
                   f"got {got}, pinned {want}")
            if pin_enforced:
                out.append(Violation("collectives", case.name, msg))
            else:
                print(f"collectives: WARN {case.name}: {msg}")
        if case.scale_guarded:
            out += check_scaling(case, mesh)

    stale = sorted(set(pinned) - {c.name for c in cases})
    for name in stale:
        out.append(Violation(
            "collectives", name,
            "manifest pins a step the registry no longer enumerates — "
            "rerun with --update-manifests"))
    return out, len(cases)
