"""Exact jaxpr-level FLOP/byte accounting.

XLA's ``compiled.cost_analysis()`` counts every while/scan body ONCE
(trip counts are invisible to HloCostAnalysis), which under-reports any
scanned-layer model by ~the layer count. This counter walks the closed
jaxpr instead, multiplying scan bodies by their static length, so the
roofline terms the dry-run records (``launch/dryrun.py`` ->
``results/dryrun.jsonl``) are exact for the matmul-dominated workloads
this framework runs.

FLOPs: 2*M*N*K per dot_general (batched dims included), conv as implicit
dot. Bytes: a structural HBM-traffic model — operands+outputs of
dot/conv (weights and activations stream through VMEM once under perfect
fusion), gather/scatter, and big reduction operands. Pure element-wise ops
are assumed fused (not counted); the number is therefore a lower-ish bound
on real traffic and is labelled as such wherever reported.
"""
from __future__ import annotations

import math

import jax
import numpy as np

try:
    # The supported introspection surface (jax >= 0.4.16 ships
    # jax.extend.core; ClosedJaxpr joined it later).
    from jax.extend import core as jcore

    _ = jcore.ClosedJaxpr
except (ImportError, AttributeError):  # pragma: no cover - old-jax shim
    # Fallback for jax builds whose extend surface predates ClosedJaxpr.
    # Private import, kept ONLY as the shim: it breaks silently on jax
    # upgrades, which is why the supported path above is tried first.
    from jax._src import core as jcore

_RECURSE_PARAM_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr")
#: Body-carrying params of the control-flow primitives ``_count`` handles
#: explicitly (with trip-count multiplication); ``iter_eqns`` descends into
#: these too so generic walkers see EVERY equation.
_BODY_PARAM_KEYS = ("body_jaxpr",)


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:  # noqa: BLE001 - abstract tokens etc.
        return 0


def _io_bytes(eqn) -> int:
    n = 0
    for v in list(eqn.invars) + list(eqn.outvars):
        aval = getattr(v, "aval", None)
        if aval is not None:
            n += _aval_bytes(aval)
    return n


def _dot_flops(eqn) -> int:
    ((lc, _rc), (lb, _rb)) = eqn.params["dimension_numbers"]
    a = eqn.invars[0].aval
    out = eqn.outvars[0].aval
    contract = math.prod(a.shape[i] for i in lc) if lc else 1
    return 2 * int(np.prod(out.shape)) * int(contract)


def _conv_flops(eqn) -> int:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    groups = eqn.params.get("feature_group_count", 1)
    # rhs: spatial... x in_feat/groups x out_feat (depends on dim numbers);
    # per output element: 2 * prod(rhs.shape) / out_feat.
    dn = eqn.params["dimension_numbers"]
    out_feat = rhs.shape[dn.rhs_spec[0]]
    per_out = 2 * int(np.prod(rhs.shape)) // max(out_feat, 1)
    del groups
    return int(np.prod(out.shape)) * per_out


def _sub_jaxprs(eqn):
    for key in _RECURSE_PARAM_KEYS:
        if key in eqn.params:
            j = eqn.params[key]
            yield j.jaxpr if isinstance(j, jcore.ClosedJaxpr) else j
    if "branches" in eqn.params:                      # cond
        for b in eqn.params["branches"]:
            yield b.jaxpr if isinstance(b, jcore.ClosedJaxpr) else b


def iter_eqns(jaxpr):
    """Yield every equation of ``jaxpr`` and all nested sub-jaxprs
    (scan/while/cond/pjit/remat/pallas_call bodies), each visited once.

    The generic single-visit walk for structural analyses
    (``analysis/hazards`` builds on it); unlike :func:`_count` it applies
    no trip-count weighting — an equation inside a scanned body is
    yielded once however many times the loop runs.
    """
    for eqn in jaxpr.eqns:
        yield eqn
        subs = list(_sub_jaxprs(eqn))
        for key in _BODY_PARAM_KEYS:
            if key in eqn.params:
                j = eqn.params[key]
                subs.append(j.jaxpr if isinstance(j, jcore.ClosedJaxpr)
                            else j)
        for sub in subs:
            yield from iter_eqns(sub)


def _count(jaxpr) -> dict[str, float]:
    flops = 0.0
    nbytes = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            flops += _dot_flops(eqn)
            nbytes += _io_bytes(eqn)
        elif name == "conv_general_dilated":
            flops += _conv_flops(eqn)
            nbytes += _io_bytes(eqn)
        elif name in ("gather", "scatter", "scatter-add", "scatter_add",
                      "dynamic_slice", "dynamic_update_slice"):
            nbytes += _io_bytes(eqn)
        elif name in ("reduce_sum", "reduce_max", "reduce_min", "argmax",
                      "argmin", "reduce_and", "reduce_or", "sort", "top_k",
                      "cumsum", "reduce_prod"):
            nbytes += _io_bytes(eqn)
        elif name == "scan":
            inner = _count(eqn.params["jaxpr"].jaxpr)
            n = eqn.params["length"]
            flops += n * inner["flops"]
            nbytes += n * inner["bytes"]
            continue
        elif name == "while":
            inner = _count(eqn.params["body_jaxpr"].jaxpr)
            flops += inner["flops"]                   # trip count unknown
            nbytes += inner["bytes"]
            continue
        elif name == "cond":
            subs = [_count(b.jaxpr if isinstance(b, jcore.ClosedJaxpr) else b)
                    for b in eqn.params["branches"]]
            flops += max(s["flops"] for s in subs)
            nbytes += max(s["bytes"] for s in subs)
            continue
        elif name == "shard_map":
            # Body avals are PER-SHARD; every device runs the body once, so
            # global cost = body cost x mesh size.
            body = eqn.params.get("jaxpr")
            mesh = eqn.params.get("mesh")
            n_shards = 1
            if mesh is not None:
                try:
                    n_shards = int(np.prod(list(dict(mesh.shape).values())))
                except Exception:  # noqa: BLE001
                    n_shards = 1
            inner = _count(body.jaxpr if isinstance(body, jcore.ClosedJaxpr)
                           else body)
            flops += n_shards * inner["flops"]
            nbytes += n_shards * inner["bytes"]
            continue
        # generic recursion (pjit, remat/checkpoint, custom_vjp, ...)
        for sub in _sub_jaxprs(eqn):
            inner = _count(sub)
            flops += inner["flops"]
            nbytes += inner["bytes"]
    return {"flops": flops, "bytes": nbytes}


def cost_of(fn, *abstract_args, **kw) -> dict[str, float]:
    """Global (unpartitioned) FLOPs and structural HBM bytes of ``fn``."""
    closed = jax.make_jaxpr(fn, **kw)(*abstract_args)
    return _count(closed.jaxpr)
