"""Collective-traffic extraction from SPMD-partitioned HLO text — with
while-loop trip-count multiplication and a ring wire-byte model.

``compiled.as_text()`` shows per-device result types on each op; operands
are bare references, so sizes are derived from the RESULT type plus the
replica-group size g:

  all-gather       wire = R (g-1) / g x g participants  = R (g-1) x groups
  reduce-scatter   operand O = R g  ->  wire = O (g-1) x groups
  all-reduce       RS + AG            wire = 2 R (g-1) x groups
  all-to-all       wire = R (g-1) x groups
  collective-perm  wire = R x participants

Collectives inside a scanned layer stack live in a while-loop body; XLA
lowers lax.scan to a while whose condition compares the induction variable
to a constant, which we recover and multiply by.

Reduced-precision emulation: the CPU host-mesh oracle cannot run bf16
collectives natively, so XLA widens them — ``convert(bf16 -> f32)`` ->
f32 all-gather -> ``convert`` back — and the textual wire dtype lies
about the program's semantic traffic (a TPU runs the same collective
natively at bf16 width). When a collective's operand is produced by a
convert (or a fusion containing one) from a narrower float into the
collective dtype, bytes are charged at the NARROW width.
"""
from __future__ import annotations

import re
from collections import defaultdict

_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
          "collective-permute")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s+(?P<result>.+?)\s+(?P<kind>all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start|-done)?\(")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_WHILE_RE = re.compile(r"while\(")
_WHILE_ATTR = re.compile(r"(?:condition|body)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(
    r"\(\s*(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?\s+)?%([\w\.\-]+)")
#: ``<wide> convert(<narrow>[...`` — the CPU collective-type widener's
#: producer-side upcast (narrow float -> the collective's wire dtype).
_NARROW_CONVERT_RE = re.compile(
    r"=\s*(?P<wide>f32|f64)\[[0-9,]*\](?:\{[^}]*\})?\s+"
    r"convert\(\s*(?P<narrow>bf16|f16|f8e4m3fn|f8e5m2)\[")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        size = _DTYPE_BYTES[dt]
        for d in dims.split(","):
            if d:
                size *= int(d)
        total += size
    return total


def _group_info(line: str, n_devices: int) -> tuple[int, int]:
    """(group size g, num groups)."""
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2)), int(m.group(1))
    m = _GROUPS_LIST.search(line)
    if m:
        g = len(m.group(1).split(","))
        return g, max(n_devices // max(g, 1), 1)
    return n_devices, 1


def _wire_bytes(kind: str, result_bytes: int, g: int, groups: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) * groups
    if kind == "reduce-scatter":
        return float(result_bytes * g) * (g - 1) * groups
    if kind == "collective-permute":
        return float(result_bytes) * g * groups
    # all-gather (result already gathered), all-to-all
    return float(result_bytes) * (g - 1) * groups


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    depth = 0
    for line in hlo.splitlines():
        s = line.strip()
        if cur is None:
            if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
                name = s.split()[0].lstrip("%")
                if name == "ENTRY":
                    name = s.split()[1].lstrip("%")
                comps[name] = []
                cur = name
                depth = 1
            continue
        depth += line.count("{") - line.count("}")
        if depth <= 0:
            cur = None
            continue
        comps[cur].append(line)
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def _semantic_scale(line: str, kind: str, comps, comp_lines) -> float:
    """1.0, or narrow/wide itemsize ratio when this collective's operand
    is a widening convert (or a fusion containing one) from a narrower
    float — the CPU oracle's bf16-collective emulation (module docstring).
    """
    rm = _SHAPE_RE.search(line)
    if rm is None or rm.group(1) not in ("f32", "f64"):
        return 1.0          # already narrow (or integer-fenced) wire
    wire = rm.group(1)
    om = _OPERAND_RE.search(line, line.index(kind))
    if not om:
        return 1.0
    opname = om.group(1)
    prod = next((ln for ln in comp_lines
                 if ln.strip().startswith(f"%{opname} ")
                 or f" %{opname} = " in ln), None)
    if prod is None:
        return 1.0
    cands = [prod]
    cm = _CALL_RE.search(prod)
    if cm and "fusion" in prod:
        cands += comps.get(cm.group(1), [])
    for ln in cands:
        nm = _NARROW_CONVERT_RE.search(ln)
        if nm and nm.group("wide") == wire:
            return (_DTYPE_BYTES[nm.group("narrow")]
                    / _DTYPE_BYTES[nm.group("wide")])
    return 1.0


def collective_bytes(hlo: str, n_devices: int) -> dict[str, float]:
    """Per-kind GLOBAL collective wire bytes, trip-count aware."""
    comps = _split_computations(hlo)

    def comp_cost(name: str, seen: tuple = ()) -> dict[str, float]:
        out: dict[str, float] = defaultdict(float)
        if name not in comps or name in seen:
            return out
        for line in comps[name]:
            s = line.strip()
            if s.startswith("//"):
                continue
            m = _OP_RE.search(s)
            if m:
                kind = m.group("kind")
                rb = _shape_bytes(m.group("result"))
                g, groups = _group_info(s, n_devices)
                scale = _semantic_scale(s, kind, comps, comps[name])
                out[kind] += _wire_bytes(kind, int(rb * scale), g, groups)
                continue
            if _WHILE_RE.search(s):
                cm_cond = re.search(r"condition=%?([\w\.\-]+)", s)
                cm_body = re.search(r"body=%?([\w\.\-]+)", s)
                if cm_cond and cm_body:
                    n = _trip_count(comps.get(cm_cond.group(1), []))
                    for k, v in comp_cost(cm_body.group(1),
                                          seen + (name,)).items():
                        out[k] += v * n
                continue
            cm = _CALL_RE.search(s)
            if cm and "fusion" not in s:
                for k, v in comp_cost(cm.group(1), seen + (name,)).items():
                    out[k] += v
        return out

    entry = None
    mm = re.search(r"ENTRY %?([\w\.\-]+)", hlo)
    if mm:
        entry = mm.group(1)
    elif comps:
        entry = next(iter(comps))
    return dict(comp_cost(entry or ""))
