"""Benchmark-artifact sanity pass (stdlib-only, no jax).

The CI smoke job used to hold its BENCH_*.json assertions in inline
``python -c`` strings inside the workflow — unreviewable and
untestable. This module is those checks as code: the smoke job now runs
``python -m repro.analysis.check --passes bench`` after the benchmark
smokes, and the same validations are unit-tested against seeded-bad
artifacts.

Validated:

* ``BENCH_batch.json`` — non-empty ``entries``, at least one entry from
  the distributed engine, every entry carrying the throughput fields;
  provenance fields (``device_kind`` plus an ``autotune`` record with
  the mode and the tuned tile picks) so a perf number is never divorced
  from the hardware and tile configuration that produced it.
  Both batch and cascade artifacts must also carry a
  ``precision_sweep``: every policy in ``PRECISION_POLICIES`` with its
  recall delta vs f32 and handoff bytes, bf16 bytes exactly half of
  f32's, and the bf16 recall delta inside the acceptance band
  (``PRECISION_MAX_RECALL_DELTA``) — the measured frontier behind
  ``EngineConfig(precision=...)``.
* ``BENCH_cascade.json`` — non-empty ``entries`` each with
  ``recall_at_l`` / ``queries_per_sec`` / ``use_kernels``; BOTH kernel
  settings present (the kernel path must not silently drop out of the
  bench matrix); a ``distributed_step`` record with recall + qps; all
  recalls inside [0, 1]; the same provenance fields as BENCH_batch.
  The corpus-size ``sweep`` (candidate sources): every rung pairs the
  full-scan reference with at least one sublinear source, recalls and
  throughputs are well-formed, and — full (non-smoke) runs only — at
  the largest corpus some sublinear source beats the full scan's qps
  at recall@l >= 0.9 (the subsystem's acceptance bar).
* ``BENCH_serve.json`` — non-empty per-load ``entries`` each carrying
  latency percentiles (``p50_ms <= p99_ms``), a served-tier mix, and
  100% request completion (served + shed == offered — the runtime never
  hangs a request); a ``chaos`` record whose seeded fault replay
  completed every request AND reproduced deterministically.
"""
from __future__ import annotations

import json
import os

from repro.analysis.violations import Violation

BATCH_PATH = "BENCH_batch.json"
CASCADE_PATH = "BENCH_cascade.json"
SERVE_PATH = "BENCH_serve.json"


def _load(path: str) -> tuple[dict | None, list[Violation]]:
    if not os.path.exists(path):
        return None, [Violation(
            "bench", path,
            "artifact missing — run the benchmark smoke first "
            "(BENCH_SMOKE=1 PYTHONPATH=src python -m benchmarks.run)")]
    try:
        with open(path) as f:
            return json.load(f), []
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        return None, [Violation("bench", path, f"unparseable JSON: {e}")]


def _check_provenance(r: dict, path: str) -> list[Violation]:
    """Hardware/tile provenance every perf artifact must carry."""
    out = []
    if not isinstance(r.get("device_kind"), str) or not r["device_kind"]:
        out.append(Violation(
            "bench", path,
            "no device_kind — perf numbers must name their hardware"))
    tune = r.get("autotune")
    if not isinstance(tune, dict):
        out.append(Violation("bench", path, "no autotune record"))
        return out
    if tune.get("mode") not in ("off", "cached", "force"):
        out.append(Violation(
            "bench", path,
            f"autotune mode {tune.get('mode')!r} not one of "
            "('off', 'cached', 'force')"))
    if not isinstance(tune.get("tuned_blocks"), dict):
        out.append(Violation(
            "bench", path,
            "autotune record has no tuned_blocks mapping"))
    return out


#: Policies the precision sweep must cover (mirrors
#: ``repro.core.precision.POLICIES`` — literal here so this pass stays
#: stdlib-only).
PRECISION_POLICIES = ("f32", "bf16", "bf16_agg")

#: Acceptance band for the bf16 policy's recall@l drop vs f32 (the
#: "within 0.01 of f32" bar of the mixed-precision frontier).
PRECISION_MAX_RECALL_DELTA = 0.01


def _check_precision(r: dict, path: str) -> list[Violation]:
    """The per-policy precision sweep every scoring artifact carries."""
    ps = r.get("precision_sweep")
    if not isinstance(ps, dict) or not ps.get("entries"):
        return [Violation(
            "bench", path,
            "no precision_sweep — the mixed-precision frontier fell "
            "out of the bench matrix")]
    out = []
    entries = {e.get("policy"): e for e in ps["entries"]}
    missing = [p for p in PRECISION_POLICIES if p not in entries]
    if missing:
        out.append(Violation(
            "bench", path,
            f"precision_sweep missing policies {missing} — every "
            f"policy in {list(PRECISION_POLICIES)} must be measured"))
    for name, e in sorted(entries.items()):
        for key in ("recall_delta_vs_f32", "handoff_bytes_per_row",
                    "queries_per_sec"):
            if key not in e:
                out.append(Violation(
                    "bench", path,
                    f"precision_sweep entry {name!r} missing {key!r}"))
        delta = e.get("recall_delta_vs_f32")
        if isinstance(delta, (int, float)) and not 0.0 <= delta <= 1.0:
            out.append(Violation(
                "bench", path,
                f"precision_sweep {name!r} recall_delta_vs_f32={delta} "
                "outside [0, 1]"))
    f32b = entries.get("f32", {}).get("handoff_bytes_per_row")
    bf16b = entries.get("bf16", {}).get("handoff_bytes_per_row")
    if isinstance(f32b, int) and isinstance(bf16b, int) \
            and bf16b * 2 != f32b:
        out.append(Violation(
            "bench", path,
            f"bf16 handoff bytes {bf16b} are not half of f32's {f32b} "
            "— the storage dtype stopped driving the byte model"))
    delta = entries.get("bf16", {}).get("recall_delta_vs_f32")
    if isinstance(delta, (int, float)) \
            and delta > PRECISION_MAX_RECALL_DELTA:
        out.append(Violation(
            "bench", path,
            f"bf16 recall delta {delta} vs f32 exceeds the "
            f"{PRECISION_MAX_RECALL_DELTA} acceptance band"))
    return out


def check_batch(path: str = BATCH_PATH) -> list[Violation]:
    r, out = _load(path)
    if r is None:
        return out
    out += _check_provenance(r, path)
    entries = r.get("entries") or []
    if not entries:
        out.append(Violation("bench", path, "no benchmark entries"))
        return out
    if not any(e.get("engine") == "distributed" for e in entries):
        out.append(Violation(
            "bench", path,
            "no distributed-engine entry — the mesh path fell out of "
            "the bench matrix"))
    for i, e in enumerate(entries):
        if "queries_per_sec" not in e and "qps" not in e:
            out.append(Violation(
                "bench", path, f"entry #{i} has no throughput field"))
    out += _check_precision(r, path)
    return out


def check_cascade(path: str = CASCADE_PATH) -> list[Violation]:
    r, out = _load(path)
    if r is None:
        return out
    out += _check_provenance(r, path)
    entries = r.get("entries") or []
    if not entries:
        out.append(Violation("bench", path, "no benchmark entries"))
        return out
    for i, e in enumerate(entries):
        for key in ("recall_at_l", "queries_per_sec", "use_kernels"):
            if key not in e:
                out.append(Violation(
                    "bench", path, f"entry #{i} missing {key!r}"))
        rec = e.get("recall_at_l")
        if isinstance(rec, (int, float)) and not 0.0 <= rec <= 1.0:
            out.append(Violation(
                "bench", path,
                f"entry #{i} recall_at_l={rec} outside [0, 1]"))
    kernel_settings = {e.get("use_kernels") for e in entries
                      if "use_kernels" in e}
    if kernel_settings and kernel_settings != {False, True}:
        out.append(Violation(
            "bench", path,
            f"kernel settings covered: {sorted(kernel_settings)} — the "
            "bench matrix must run use_kernels both ways"))
    dist = r.get("distributed_step")
    if not isinstance(dist, dict):
        out.append(Violation(
            "bench", path, "no distributed_step record"))
    else:
        for key in ("recall_at_l", "queries_per_sec"):
            if key not in dist:
                out.append(Violation(
                    "bench", path, f"distributed_step missing {key!r}"))
    out += _check_precision(r, path)
    out += _check_sweep(r, path)
    return out


#: Full (non-smoke) sweep acceptance: at the largest corpus, some
#: sublinear source must beat the full scan's qps at this recall@l.
SWEEP_MIN_RECALL = 0.9


def _check_sweep(r: dict, path: str) -> list[Violation]:
    """The corpus-size sweep of the candidate-source subsystem."""
    out = []
    sweep = r.get("sweep")
    if not isinstance(sweep, list) or not sweep:
        return [Violation(
            "bench", path,
            "no corpus-size sweep — the candidate-source rungs fell out "
            "of the bench matrix")]
    for rung in sweep:
        n = rung.get("n")
        tag = f"sweep rung n={n}"
        entries = rung.get("entries") or []
        kinds = [e.get("source") for e in entries]
        if "full_scan" not in kinds:
            out.append(Violation(
                "bench", path,
                f"{tag} has no full_scan reference entry"))
        if not any(k not in (None, "full_scan") for k in kinds):
            out.append(Violation(
                "bench", path, f"{tag} has no sublinear source entry"))
        for e in entries:
            rec, qps = e.get("recall_at_l"), e.get("queries_per_sec")
            if not isinstance(rec, (int, float)) or not 0.0 <= rec <= 1.0:
                out.append(Violation(
                    "bench", path,
                    f"{tag} {e.get('source')} recall_at_l={rec!r} "
                    "outside [0, 1]"))
            if not isinstance(qps, (int, float)) or qps <= 0:
                out.append(Violation(
                    "bench", path,
                    f"{tag} {e.get('source')} queries_per_sec={qps!r} "
                    "not a positive number"))
    if not r.get("smoke"):
        largest = max(sweep, key=lambda rung: rung.get("n") or 0)
        entries = largest.get("entries") or []
        full_qps = max((e.get("queries_per_sec", 0.0) for e in entries
                        if e.get("source") == "full_scan"), default=None)
        ok = full_qps is not None and any(
            e.get("source") not in (None, "full_scan")
            and e.get("recall_at_l", 0.0) >= SWEEP_MIN_RECALL
            and e.get("queries_per_sec", 0.0) > full_qps
            for e in entries)
        if not ok:
            out.append(Violation(
                "bench", path,
                f"sweep largest rung (n={largest.get('n')}): no "
                f"sublinear source with recall@l >= {SWEEP_MIN_RECALL} "
                "AND queries_per_sec above the full scan — the "
                "subsystem's acceptance bar"))
    return out


def check_serve(path: str = SERVE_PATH) -> list[Violation]:
    r, out = _load(path)
    if r is None:
        return out
    entries = r.get("entries") or []
    if not entries:
        out.append(Violation("bench", path, "no load entries"))
    for i, e in enumerate(entries):
        for key in ("p50_ms", "p99_ms", "tier_mix", "offered_qps"):
            if key not in e:
                out.append(Violation(
                    "bench", path, f"entry #{i} missing {key!r}"))
        p50, p99 = e.get("p50_ms"), e.get("p99_ms")
        if isinstance(p50, (int, float)) and isinstance(p99, (int, float)) \
                and p50 > p99:
            out.append(Violation(
                "bench", path,
                f"entry #{i} p50_ms={p50} > p99_ms={p99}"))
        n, done = e.get("n_requests"), e.get("completed")
        if isinstance(n, int) and isinstance(done, int) and done != n:
            out.append(Violation(
                "bench", path,
                f"entry #{i} completed {done}/{n} requests — the "
                "runtime hung or dropped traffic"))
        mix = e.get("tier_mix")
        if isinstance(mix, dict) and isinstance(e.get("served"), int) \
                and sum(mix.values()) != e["served"]:
            out.append(Violation(
                "bench", path,
                f"entry #{i} tier_mix totals {sum(mix.values())} != "
                f"served {e['served']}"))
    chaos = r.get("chaos")
    if not isinstance(chaos, dict):
        out.append(Violation("bench", path, "no chaos record"))
        return out
    if chaos.get("completed") != chaos.get("n_requests"):
        out.append(Violation(
            "bench", path,
            f"chaos run completed {chaos.get('completed')}/"
            f"{chaos.get('n_requests')} requests under injected faults"))
    if chaos.get("deterministic") is not True:
        out.append(Violation(
            "bench", path,
            "chaos replay was not deterministic under the fixed seed"))
    return out


def run(*, batch_path: str = BATCH_PATH, cascade_path: str = CASCADE_PATH,
        serve_path: str = SERVE_PATH) -> tuple[list[Violation], int]:
    return (check_batch(batch_path) + check_cascade(cascade_path)
            + check_serve(serve_path), 3)
