"""Jaxpr hazard detector — walk every step program's closed jaxpr and
flag constructs that would stall or silently bloat the mesh step.

Three hazard classes, found by the single-visit equation walk
``analysis.jaxpr_cost.iter_eqns`` (scan/while/cond/pjit bodies
included):

* **host round-trips** — ``pure_callback`` / ``io_callback`` /
  ``debug_callback`` (any primitive whose name contains ``callback``),
  infeed/outfeed: each one forces a device->host sync inside what must
  be a single dispatched program. The host-only exact-EMD rescorer is
  exactly the thing this catches if someone traces it into a mesh step.
* **float64 promotions** — each step is traced UNDER x64 mode
  (``jax.experimental.enable_x64``) with its real float32/int32 input
  avals; any equation then producing f64/c128 reveals a latent promotion
  (a Python float folded at trace time, an np.float64 constant) that
  doubles memory and collective bytes the moment a caller enables x64.
  All current engines trace clean, so any flag is a regression.
* **oversized captured constants** — closed-over arrays above
  ``max_const_bytes`` (default 1 MiB) get baked into the program and
  replicated to every device instead of arriving as sharded operands.

Pure tracing — no devices, no mesh, no compilation — so this pass runs
in milliseconds per step and needs no ``XLA_FLAGS``.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.analysis.jaxpr_cost import iter_eqns
from repro.analysis.violations import Violation

#: Primitives that force a host round-trip inside a jitted step even
#: though their names do not contain "callback".
_HOST_SYNC_PRIMS = frozenset({"infeed", "outfeed"})

#: dtypes whose appearance under an x64 trace marks a promotion hazard.
#: (int64 is excluded: x64 mode makes every Python-int literal an s64
#: weak type, which is benign and would flag every program.)
_WIDE_FLOATS = frozenset({"float64", "complex128"})

DEFAULT_MAX_CONST_BYTES = 1 << 20


def _is_host_callback(prim_name: str) -> bool:
    return "callback" in prim_name or prim_name in _HOST_SYNC_PRIMS


def check_jaxpr(name: str, closed, *,
                max_const_bytes: int = DEFAULT_MAX_CONST_BYTES,
                ) -> list[Violation]:
    """Hazard-scan one already-traced ClosedJaxpr."""
    out: list[Violation] = []
    callbacks: set[str] = set()
    wide: set[str] = set()
    for eqn in iter_eqns(closed.jaxpr):
        pname = eqn.primitive.name
        if _is_host_callback(pname):
            callbacks.add(pname)
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            dt = getattr(getattr(aval, "dtype", None), "name", None)
            if dt in _WIDE_FLOATS:
                wide.add(f"{pname}->{dt}")
    for pname in sorted(callbacks):
        out.append(Violation(
            "hazards", name,
            f"host callback primitive {pname!r} inside a jitted step "
            "(forces a device->host sync per dispatch)"))
    for tag in sorted(wide):
        out.append(Violation(
            "hazards", name,
            f"wide-float promotion under x64 tracing: {tag} (a trace-time "
            "constant or np scalar is not pinned to float32)"))
    for i, const in enumerate(getattr(closed, "consts", ()) or ()):
        try:
            nbytes = int(np.asarray(const).nbytes)
        except Exception:  # noqa: BLE001 - opaque closures (fn refs etc.)
            continue
        if nbytes > max_const_bytes:
            out.append(Violation(
                "hazards", name,
                f"captured constant #{i} is {nbytes} bytes "
                f"(> {max_const_bytes}): it will be baked into the "
                "program and replicated to every device rather than "
                "arriving as a sharded operand"))
    return out


def check_fn(name: str, fn, specs, *,
             max_const_bytes: int = DEFAULT_MAX_CONST_BYTES,
             ) -> list[Violation]:
    """Trace ``fn`` on ``specs`` under x64 mode and hazard-scan it.

    The input avals keep their declared f32/i32 dtypes — x64 mode only
    changes how TRACE-TIME literals promote, which is exactly the latent
    hazard being probed.
    """
    try:
        with jax.experimental.enable_x64():
            closed = jax.make_jaxpr(fn)(*specs)
    except Exception as e:  # noqa: BLE001 - surface, don't crash the suite
        return [Violation("hazards", name,
                          f"step failed to trace under x64 mode: {e}")]
    return check_jaxpr(name, closed, max_const_bytes=max_const_bytes)


def run(*, workload=None, pad_multiple: int = 8,
        max_const_bytes: int = DEFAULT_MAX_CONST_BYTES,
        extra_fns: dict | None = None) -> tuple[list[Violation], int]:
    """Hazard-scan every registry step case (plus ``extra_fns``, a
    {name: callable} dict traced on the same input specs — the
    seeded-violation tests inject through it)."""
    from repro.analysis.collectives_check import check_workload
    from repro.launch import search as S

    workload = check_workload() if workload is None else workload
    base_specs = S.search_input_specs(workload, pad_multiple=pad_multiple)
    out: list[Violation] = []
    checked = 0
    for case in S.step_cases():
        fn = S.build_step(case, workload)
        # Per-case specs: sourced cascades append their candidate-index
        # state operands (which ALSO puts the big-constant scan on that
        # state — it must arrive as an argument, never baked in).
        specs = S.case_input_specs(case, workload,
                                   pad_multiple=pad_multiple)
        out += check_fn(case.name, fn, specs,
                        max_const_bytes=max_const_bytes)
        checked += 1
    for name, fn in (extra_fns or {}).items():
        out += check_fn(name, fn, base_specs,
                        max_const_bytes=max_const_bytes)
        checked += 1
    return out, checked
