"""Pallas kernel VMEM static analyzer.

Every kernel family in ``repro.kernels.ops`` publishes its per-grid-cell
block layout as data (``ops.KERNEL_FAMILIES`` / ``ops.block_layout`` —
the same clamp/pad arithmetic the wrappers apply, evaluated without
tracing). This pass turns those layouts into a per-core VMEM footprint:
pipelined in/out blocks count twice (Pallas double-buffers the
HBM<->VMEM streams), scratch once, and the total must clear a
configurable budget below the hardware's ~16 MB/core (see
``/opt/skills/guides`` Pallas notes). It also validates the launch
geometry — non-empty grids, padded dims divisible by their blocks.

Two checked profiles:

* ``bench`` — the tile sizes and shapes the test/bench suites actually
  launch; these must fit with the default knobs.
* ``paper`` — 20News scale (n=18.8k, v=69.7k, h=500) with the tuned-down
  candidate tiles that fit. The profile is the static half of the tile
  autotuner (``repro.kernels.autotune``): :func:`footprint` is the model
  it sweeps, and ``autotune.admissible_configs`` enumerates only tile
  choices :func:`check_launch` admits. ``cand_dist`` is guarded here at
  paper scale since its blocked-vocab rework: the grid streams the
  query's (v, h) distance handoff one ``block_v`` slab at a time into a
  persistent gather accumulator, so its per-cell residency is
  tile-sized, not corpus-sized.
"""
from __future__ import annotations

from repro.analysis.violations import Violation
from repro.kernels import ops

#: ~16 MB/core of VMEM on current TPUs; the default budget is the full
#: amount — callers wanting Mosaic-register headroom pass a lower one
#: (the CI job checks at the default).
DEFAULT_VMEM_BUDGET_BYTES = 16 * 2**20


def footprint(family: str, **dims) -> tuple[ops.KernelBlocks, int]:
    """(layout, per-core VMEM bytes) of one kernel launch — the static
    cost model the tile autotuner sweeps."""
    layout = ops.block_layout(family, **dims)
    return layout, layout.vmem_bytes()


def check_configs() -> list[tuple[str, str, dict]]:
    """(profile:family label, family, dims) for every checked launch."""
    from repro.configs.emd_20news import CONFIG as PAPER

    bench = dict(v=2048, h=64, m=32, k=8, n=4096, b=256, iters=3, qh=64)
    out: list[tuple[str, str, dict]] = [
        ("bench:dist_topk", "dist_topk",
         dict(nq=8, v=bench["v"], h=bench["h"], m=bench["m"], k=bench["k"])),
        ("bench:act_phase2", "act_phase2",
         dict(nq=8, n=bench["n"], h=bench["h"], iters=bench["iters"])),
        ("bench:act_phase2_cand", "act_phase2_cand",
         dict(nq=8, n=bench["b"], h=bench["h"], iters=bench["iters"])),
    ]
    for mode in ("pour", "omr"):
        out.append((f"bench:cand_pour:{mode}", "cand_pour",
                    dict(nq=8, b=bench["b"], h=bench["h"], v=bench["v"],
                         k=bench["k"], iters=bench["iters"], mode=mode,
                         block_n=64)))
    for mode in ("rev_min", "ict"):
        out.append((f"bench:cand_dist:{mode}", "cand_dist",
                    dict(nq=8, b=bench["b"], h=bench["h"], v=bench["v"],
                         qh=bench["h"], mode=mode, block_n=64)))
    # Paper scale: Phase-1/2 tiles are h/n-blocked so the defaults hold;
    # the candidate pour needs block_n=8 (the onehot gather scratch is
    # r = block_n * h rows and h is 500 here).
    k = PAPER.iters + 1
    out += [
        ("paper:dist_topk", "dist_topk",
         dict(nq=8, v=PAPER.vocab, h=PAPER.hmax, m=PAPER.dim, k=k)),
        ("paper:act_phase2", "act_phase2",
         dict(nq=8, n=PAPER.n_db, h=PAPER.hmax, iters=PAPER.iters)),
        ("paper:cand_pour", "cand_pour",
         dict(nq=8, b=512, h=PAPER.hmax, v=PAPER.vocab, k=k,
              iters=PAPER.iters, block_n=8)),
    ]
    # cand_dist at paper scale: the blocked-vocab rework streams the
    # (v, h) handoff in block_v slabs, but the (block_n*h, h) gather
    # accumulator + reduce temporaries still force block_n down to 2 at
    # h = qh = 500 (ict's ladder scratch is the binding constraint).
    for mode in ("rev_min", "ict"):
        out.append((f"paper:cand_dist:{mode}", "cand_dist",
                    dict(nq=8, b=512, h=PAPER.hmax, v=PAPER.vocab,
                         qh=PAPER.hmax, mode=mode, block_n=2)))
    # bf16 storage profile: the same paper-scale launches under the
    # "bf16" precision policy. The table/handoff slabs halve, which is
    # exactly what grows the autotuner's admissible tile space — checked
    # here so a layout change that silently stops honoring ``dtype``
    # fails CI (the footprints must fit with DOUBLED candidate tiles).
    out += [
        ("paper:dist_topk:bf16", "dist_topk",
         dict(nq=8, v=PAPER.vocab, h=PAPER.hmax, m=PAPER.dim, k=k,
              dtype="bfloat16")),
        ("paper:cand_pour:bf16", "cand_pour",
         dict(nq=8, b=512, h=PAPER.hmax, v=PAPER.vocab, k=k,
              iters=PAPER.iters, block_n=16, dtype="bfloat16")),
        ("paper:cand_dist:rev_min:bf16", "cand_dist",
         dict(nq=8, b=512, h=PAPER.hmax, v=PAPER.vocab, qh=PAPER.hmax,
              mode="rev_min", block_n=4, dtype="bfloat16")),
    ]
    return out


def check_launch(label: str, family: str, dims: dict, *,
                 budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES,
                 ) -> list[Violation]:
    """Validate one launch config: layout builds, grid well-formed,
    footprint under budget."""
    try:
        layout, nbytes = footprint(family, **dims)
    except (ValueError, AssertionError) as e:
        return [Violation("vmem", label, f"invalid launch config: {e}")]
    out: list[Violation] = []
    if not layout.grid or any(g < 1 for g in layout.grid):
        out.append(Violation("vmem", label,
                             f"degenerate grid {layout.grid}"))
    for buf in layout.buffers:
        if any(d < 1 for d in buf.shape) and 0 not in buf.shape:
            out.append(Violation(
                "vmem", label,
                f"buffer {buf.name!r} has a negative dim: {buf.shape}"))
    if nbytes > budget_bytes:
        out.append(Violation(
            "vmem", label,
            f"per-core VMEM footprint {nbytes / 2**20:.2f} MiB exceeds "
            f"the {budget_bytes / 2**20:.0f} MiB budget "
            f"(grid {layout.grid}; shrink block_n/block_v/block_h)"))
    return out


def run(*, budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES,
        configs=None) -> tuple[list[Violation], int]:
    """Check every profiled launch; returns (violations, launches)."""
    configs = check_configs() if configs is None else configs
    out: list[Violation] = []
    for label, family, dims in configs:
        out += check_launch(label, family, dims, budget_bytes=budget_bytes)
    return out, len(configs)
