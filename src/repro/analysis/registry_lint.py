"""Registry/spec consistency lint — the bound table and method registry
as checkable mathematical objects.

Three families of invariants, all pure Python (no tracing, no devices):

* **Bound-table order** — ``cascade.spec.is_lower_bound`` must be a
  partial order on (method, iters) pairs consistent with Theorem 2's
  chain RWMD <= OMR <= ACT-k <= ICT <= EMD: reflexive, transitive,
  antisymmetric up to the known degeneracy (ACT with 0 Phase-2 rounds IS
  RWMD), with every chain member and every EMD-only bound below exact
  EMD, and the EMD-only bounds (wcd, rwmd_rev) below NOTHING else in the
  chain. A bad edit to the tightness table silently breaks cascade
  admissibility — this pass turns that into a CI failure.
* **MethodSpec coherence** — reverse links symmetric, ``dist_fn`` never
  dead code (``batch_scores.pick`` only consults it when a ``batch_fn``
  exists), kernel support only on methods with a batched engine,
  ``dist_out`` layouts well-formed, symmetric measures reverse-free.
* **Cascade presets** — every ``CASCADES`` entry constructs, resolves
  budgets on a reference corpus, and its COMPUTED admissibility matches
  the DECLARED ``PRESET_ADMISSIBLE`` claim; ``DISTRIBUTABLE_METHODS``
  tracks the registry; ``EngineConfig`` constructs for every
  (method x backend).

The bound-table relation is injectable (``rel=``) so the seeded-violation
test can prove the checker actually rejects an inconsistent table.
"""
from __future__ import annotations

import itertools
from collections.abc import Callable

from repro.analysis.violations import Violation
from repro.cascade import spec as cspec
from repro.cascade import rescore
from repro.core.retrieval import METHODS

#: iters values the order proof quantifies over — 0 exercises the
#: ACT->RWMD degeneracy, 3 is the serving default, the rest the gaps.
_ITERS_DOMAIN = (0, 1, 2, 3)

#: The single legitimate antisymmetry degeneracy: ACT with zero Phase-2
#: rounds computes exactly the RWMD relaxation, so the two compare equal
#: in both directions without being the same registry entry.
_DEGENERATE = frozenset({frozenset({("act", 0), ("rwmd", 0)})})


def _order_domain() -> list[tuple[str, int]]:
    chain = [(m, i) for m in cspec.BOUND_CHAIN for i in _ITERS_DOMAIN
             if m == "act" or i == 0]
    extras = [(m, 0) for m in cspec.EMD_ONLY_BOUNDS] + [("emd", 0)]
    return chain + extras


def check_bound_table(rel: Callable[[str, int, str, int], bool] | None = None,
                      ) -> list[Violation]:
    """Prove the admissibility relation is the partial order the paper
    claims. ``rel(method, iters, rescorer, rescorer_iters)`` defaults to
    the real :func:`repro.cascade.spec.is_lower_bound`."""
    rel = cspec.is_lower_bound if rel is None else rel
    out: list[Violation] = []
    dom = _order_domain()

    def R(a, b):
        return bool(rel(a[0], a[1], b[0], b[1]))

    for x in dom:
        if not R(x, x):
            out.append(Violation("registry", f"{x[0]}-{x[1]}",
                                 "bound relation is not reflexive"))
    for x, y, z in itertools.product(dom, repeat=3):
        if R(x, y) and R(y, z) and not R(x, z):
            out.append(Violation(
                "registry", f"{x}<={y}<={z}",
                "bound relation is not transitive"))
    for x, y in itertools.combinations(dom, 2):
        if R(x, y) and R(y, x) and frozenset({x, y}) not in _DEGENERATE:
            out.append(Violation(
                "registry", f"{x}~{y}",
                "bound relation is not antisymmetric (mutual bounds on "
                "distinct measures outside the ACT-0 == RWMD degeneracy)"))
    # Chain consistency: each chain member bounds its successor and EMD.
    chain = cspec.BOUND_CHAIN
    for lo, hi in zip(chain, chain[1:], strict=False):
        if not R((lo, 1 if lo == "act" else 0), (hi, 1 if hi == "act" else 0)):
            out.append(Violation(
                "registry", f"{lo}<={hi}",
                "Theorem-2 chain edge missing from the bound table"))
    for m in (*chain, *cspec.EMD_ONLY_BOUNDS):
        if not R((m, 1), ("emd", 0)):
            out.append(Violation(
                "registry", f"{m}<=emd",
                "every registered lower bound must sit below exact EMD"))
    # EMD-only bounds must NOT claim chain membership (wcd's Jensen bound
    # holds against EMD alone — admitting it under an act rescorer would
    # wrongly mark the 'fast' preset exact).
    for m in cspec.EMD_ONLY_BOUNDS:
        for hi in chain:
            if m != hi and R((m, 0), (hi, 3)):
                out.append(Violation(
                    "registry", f"{m}<={hi}",
                    "EMD-only bound admitted inside the directional "
                    "chain"))
    return out


def check_method_specs(methods=None) -> list[Violation]:
    """Structural coherence of every :class:`MethodSpec`."""
    methods = METHODS if methods is None else methods
    out: list[Violation] = []
    for name, spec in sorted(methods.items()):
        if spec.name != name:
            out.append(Violation("registry", name,
                                 f"registry key != spec.name {spec.name!r}"))
        if spec.reverse is not None:
            rev = methods.get(spec.reverse)
            if rev is None:
                out.append(Violation(
                    "registry", name,
                    f"reverse {spec.reverse!r} is not registered"))
            elif rev.reverse != name:
                out.append(Violation(
                    "registry", name,
                    f"reverse link not symmetric: {spec.reverse} points "
                    f"back to {rev.reverse!r}"))
        if spec.symmetric and spec.reverse is not None:
            out.append(Violation(
                "registry", name,
                "a symmetric measure needs no reverse direction"))
        if spec.dist_fn is not None and spec.batch_fn is None:
            out.append(Violation(
                "registry", name,
                "dist_fn without batch_fn is dead code: batch_scores "
                "only consults dist_fn when a batched engine exists"))
        if spec.symmetric_batch_fn is not None and spec.reverse is None \
                and not spec.symmetric:
            out.append(Violation(
                "registry", name,
                "symmetric_batch_fn on a directional method with no "
                "reverse is unreachable"))
        if spec.supports_kernels and spec.batch_fn is None:
            out.append(Violation(
                "registry", name,
                "supports_kernels on a method without a batched engine "
                "(the kernel paths live in the batch pipelines)"))
        bad_axes = [ax for ax in spec.dist_out
                    if ax not in ("data", "model", None)]
        if bad_axes:
            out.append(Violation(
                "registry", name, f"dist_out has unknown axes {bad_axes}"))
        if spec.uses_iters and spec.cand_fn is None:
            out.append(Violation(
                "registry", name,
                "iterated methods must be cascade-rescorable (cand_fn)"))
    return out


def check_cascade_presets(cascades=None, declared=None) -> list[Violation]:
    """Every preset constructs, resolves, and matches its declared
    admissibility; the rescorer registry covers it."""
    cascades = cspec.CASCADES if cascades is None else cascades
    declared = cspec.PRESET_ADMISSIBLE if declared is None else declared
    out: list[Violation] = []
    if set(cascades) != set(declared):
        out.append(Violation(
            "registry", "CASCADES",
            f"PRESET_ADMISSIBLE keys {sorted(declared)} out of sync with "
            f"presets {sorted(cascades)}"))
    for name, spec in sorted(cascades.items()):
        try:
            rescore.resolve(spec.rescorer)
            spec.resolve_budgets(n=4096, top_l=16)
        except (ValueError, KeyError) as e:
            out.append(Violation("registry", f"cascade:{name}", str(e)))
            continue
        if name in declared and spec.admissible != declared[name]:
            out.append(Violation(
                "registry", f"cascade:{name}",
                f"computed admissible={spec.admissible} contradicts the "
                f"declared claim {declared[name]} — the bound table and "
                "the preset's documentation have diverged"))
    return out


def check_api_config() -> list[Violation]:
    """``DISTRIBUTABLE_METHODS`` tracks the registry; ``EngineConfig``
    constructs for every (method x backend)."""
    from repro.api import config as api_config
    out: list[Violation] = []
    if api_config.DISTRIBUTABLE_METHODS != tuple(sorted(METHODS)):
        out.append(Violation(
            "registry", "DISTRIBUTABLE_METHODS",
            f"{api_config.DISTRIBUTABLE_METHODS} != registry "
            f"{tuple(sorted(METHODS))}"))
    for method in sorted(METHODS):
        for backend in api_config.BACKENDS:
            try:
                api_config.EngineConfig(method=method, backend=backend)
            except ValueError as e:
                out.append(Violation(
                    "registry", f"EngineConfig({method}, {backend})",
                    str(e)))
    return out


def run(rel=None) -> tuple[list[Violation], int]:
    """All registry-lint checks; returns (violations, subjects checked)."""
    out = (check_bound_table(rel) + check_method_specs()
           + check_cascade_presets() + check_api_config())
    checked = (len(_order_domain()) + len(METHODS) + len(cspec.CASCADES)
               + 1)
    return out, checked
