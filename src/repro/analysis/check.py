"""Static contract checker — one CLI over every pre-run invariant.

    PYTHONPATH=src python -m repro.analysis.check [--passes ...]

Five default passes (plus the opt-in bench-artifact pass), each a module
in this package returning :class:`~repro.analysis.violations.Violation`
records; the CLI renders a per-pass report and exits non-zero if any
violation survives:

* ``registry``    — bound-table partial order, MethodSpec coherence,
                    cascade-preset admissibility claims
                    (``registry_lint``). Pure Python.
* ``hazards``     — host callbacks / f64 promotions / oversized baked
                    constants in every registry step's jaxpr
                    (``hazards``). Tracing only, no devices.
* ``precision``   — bf16-policy step cases whose Phase-1 handoffs
                    silently stayed float32, or whose precision kwarg
                    was dropped entirely (``precision_lint``). Tracing
                    only, no devices.
* ``vmem``        — Pallas per-core VMEM footprints from the kernels'
                    static block layouts (``vmem``). Pure arithmetic.
* ``collectives`` — partitioned-HLO collective bytes of every step on
                    the 8-device host mesh vs the golden manifest, plus
                    the corpus-scaling all-gather guard
                    (``collectives_check``). Needs the forced host
                    devices — this module sets ``XLA_FLAGS`` itself,
                    which is why its imports stay stdlib-only until
                    after argument parsing.
* ``bench``       — BENCH_*.json artifact sanity (``bench_check``);
                    opt-in (``--passes bench``) since the artifacts only
                    exist after a benchmark run.

``--update-manifests`` regenerates the collective manifest in place
(then still verifies against it — committing the diff is the review).
"""
from __future__ import annotations

import argparse
import os
import sys

#: Pass name -> (module name, included by default).
PASSES = {
    "registry": ("repro.analysis.registry_lint", True),
    "hazards": ("repro.analysis.hazards", True),
    "precision": ("repro.analysis.precision_lint", True),
    "vmem": ("repro.analysis.vmem", True),
    "collectives": ("repro.analysis.collectives_check", True),
    "bench": ("repro.analysis.bench_check", False),
}

_FORCED_DEVICES = 8


def _parse(argv):
    p = argparse.ArgumentParser(
        prog="repro.analysis.check",
        description="static sharding/collective/VMEM/admissibility checks")
    p.add_argument("--passes", default=None,
                   help="comma-separated subset of "
                        f"{','.join(PASSES)} or 'all' "
                        "(default: every pass except bench)")
    p.add_argument("--update-manifests", action="store_true",
                   help="regenerate the golden collective manifest "
                        "before checking against it")
    p.add_argument("--vmem-budget-mb", type=float, default=16.0,
                   help="per-core VMEM budget the kernel layouts must "
                        "clear (default: 16)")
    return p.parse_args(argv)


def _selected(arg: str | None) -> list[str]:
    if arg is None:
        return [n for n, (_, default) in PASSES.items() if default]
    if arg.strip() == "all":
        return list(PASSES)
    names = [s.strip() for s in arg.split(",") if s.strip()]
    bad = [n for n in names if n not in PASSES]
    if bad:
        raise SystemExit(f"unknown pass(es) {bad}; one of {list(PASSES)}")
    return names


def main(argv=None) -> int:
    args = _parse(sys.argv[1:] if argv is None else argv)
    selected = _selected(args.passes)

    if "collectives" in selected and "XLA_FLAGS" not in os.environ:
        # Must happen before anything imports jax: the collective pass
        # compiles on an 8-device host mesh.
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={_FORCED_DEVICES}")

    import importlib

    from repro.analysis.violations import render

    failures = 0
    for name in selected:
        mod = importlib.import_module(PASSES[name][0])
        kwargs = {}
        if name == "vmem":
            kwargs["budget_bytes"] = int(args.vmem_budget_mb * 2**20)
        if name == "collectives":
            kwargs["update_manifests"] = args.update_manifests
        violations, checked = mod.run(**kwargs)
        print(render(violations, checked=checked, passname=name))
        failures += len(violations)

    print(f"\n{'FAIL' if failures else 'OK'}: {len(selected)} pass(es), "
          f"{failures} violation(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
