"""Precision-policy lint — catch bf16 policies that silently run f32.

A mixed-precision policy (``repro.core.precision``) earns its keep at
exactly one place: the Phase-1 handoff tensors — the (nq, v, k) cost /
capacity ladders, the (nq, v) min-handoff row, and the (nq, v, h)
reverse distance table — which are the arrays the mesh step all-gathers
over "model" and the serving path keeps resident. If a refactor drops
the storage-dtype downcast, nothing breaks: the program still traces,
scores still match (better, even), and the only symptom is that every
collective and table silently doubles back to f32 width. This pass makes
that regression loud.

For every registry step case that declares a reduced-precision policy
(``StepCase.precision != "f32"``), the raw step callable is traced (no
devices, like ``analysis.hazards``) and its equation outputs walked:

* **policy ignored** — a bf16-policy trace containing no bfloat16 avals
  at all means the precision kwarg fell off somewhere in the stack.
* **handoff stayed f32** — a float32 aval with a handoff shape and NO
  bfloat16 aval of the same shape anywhere in the trace. The healthy
  trace contains BOTH (the f32 value feeding the downcast and its bf16
  result); only-f32 means the ``astype(policy.storage)`` was dropped.
  Keying on the bf16 twin is what keeps the f32 accumulators and the
  pre-downcast top-k outputs — which are f32 BY DESIGN — out of the
  report.
"""
from __future__ import annotations

import jax

from repro.analysis.jaxpr_cost import iter_eqns
from repro.analysis.violations import Violation

#: Ladder depths probed for the (nq, v, k) handoff shapes. Real ladders
#: are ``iters + 1`` deep (single digits); the cap keeps the (nq, v, h)
#: compute intermediates of h-sized last axes out of the ladder set.
MAX_LADDER_K = 8


def handoff_shapes(nq: int, v: int, h: int) -> frozenset[tuple[int, ...]]:
    """Every Phase-1 handoff shape a policy's storage dtype must cover:
    the top-k ladders, the min-handoff row, and the reverse distance
    table (query-major, as ``sharding.annotate`` pins them)."""
    shapes = {(nq, v), (nq, v, h)}
    shapes.update((nq, v, kk) for kk in range(1, MAX_LADDER_K + 1))
    return frozenset(shapes)


def _aval_shapes(closed) -> dict[str, set[tuple[int, ...]]]:
    out: dict[str, set[tuple[int, ...]]] = {}
    for eqn in iter_eqns(closed.jaxpr):
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            dt = getattr(getattr(aval, "dtype", None), "name", None)
            if dt is not None:
                out.setdefault(dt, set()).add(tuple(aval.shape))
    return out


def check_jaxpr(name: str, closed, *, nq: int, v: int, h: int,
                storage: str = "bfloat16") -> list[Violation]:
    """Lint one already-traced ClosedJaxpr of a reduced-precision step."""
    shapes = _aval_shapes(closed)
    stored = shapes.get(storage, set())
    if not stored:
        return [Violation(
            "precision", name,
            f"policy declares {storage} storage but the trace contains "
            f"no {storage} avals at all — the precision kwarg was "
            "dropped somewhere between the step and the lc engines")]
    out: list[Violation] = []
    for shape in sorted(handoff_shapes(nq, v, h) & shapes.get("float32",
                                                              set())):
        if shape not in stored:
            out.append(Violation(
                "precision", name,
                f"Phase-1 handoff {shape} appears in float32 with no "
                f"{storage} counterpart — the storage-dtype downcast "
                "was dropped, doubling its table bytes and mesh "
                "all-gather width"))
    return out


def check_fn(name: str, fn, specs, *, nq: int, v: int, h: int,
             storage: str = "bfloat16") -> list[Violation]:
    """Trace ``fn`` on ``specs`` and lint it."""
    try:
        closed = jax.make_jaxpr(fn)(*specs)
    except Exception as e:  # noqa: BLE001 - surface, don't crash the suite
        return [Violation("precision", name,
                          f"step failed to trace: {e}")]
    return check_jaxpr(name, closed, nq=nq, v=v, h=h, storage=storage)


def run(*, workload=None, pad_multiple: int = 8,
        extra_fns: dict | None = None) -> tuple[list[Violation], int]:
    """Lint every registry step case with a reduced-precision policy
    (plus ``extra_fns``, {name: callable} traced as bf16-policy steps —
    the seeded-violation tests inject through it)."""
    from repro.analysis.collectives_check import check_workload
    from repro.core.precision import resolve
    from repro.launch import search as S

    workload = check_workload() if workload is None else workload
    nq, v, h = workload.queries, workload.vocab, workload.hmax
    specs = S.search_input_specs(workload, pad_multiple=pad_multiple)
    out: list[Violation] = []
    checked = 0
    for case in S.step_cases():
        if case.precision == "f32":
            continue
        fn = S.build_step(case, workload)
        case_specs = S.case_input_specs(case, workload,
                                        pad_multiple=pad_multiple)
        storage = resolve(case.precision).storage
        out += check_fn(case.name, fn, case_specs, nq=nq, v=v, h=h,
                        storage=storage)
        checked += 1
    for name, fn in (extra_fns or {}).items():
        out += check_fn(name, fn, specs, nq=nq, v=v, h=h)
        checked += 1
    return out, checked
