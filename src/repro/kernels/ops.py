"""Jitted public wrappers around the Pallas kernels.

Handle padding to block multiples, backend selection (interpret=True on
CPU — the container has no TPU; the kernels are written for TPU BlockSpec
tiling and validated in interpret mode), and shape restoration.

``JAX_PALLAS_INTERPRET=1`` forces interpret mode on every backend — the
CI kernel-conformance job sets it so the suite pins the interpreted
semantics explicitly rather than relying on backend detection.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import os

import jax
import jax.numpy as jnp

from repro.kernels.act_phase2 import act_phase2_cand_pallas, act_phase2_pallas
from repro.kernels.cand_pour import cand_dist_pallas, cand_pour_pallas
from repro.kernels.dist_topk import dist_topk_pallas


#: Read once at import: the flag participates in no jit cache key, so a
#: mid-process change could not take effect anyway (the first trace's
#: choice would be reused) — pinning it at import makes that explicit.
_FORCE_INTERPRET = os.environ.get("JAX_PALLAS_INTERPRET", "") not in ("",
                                                                      "0")


def _interpret_default() -> bool:
    return _FORCE_INTERPRET or jax.default_backend() != "tpu"


def _round_up(x: int, b: int) -> int:
    return -(-x // b) * b


@functools.partial(jax.jit, static_argnames=("k", "block_v", "block_h",
                                             "out_dtype"))
def dist_topk_batched(coords: jax.Array, qcs: jax.Array, k: int, *,
                      qmask: jax.Array | None = None,
                      block_v: int = 256, block_h: int = 256,
                      out_dtype: str = "float32"):
    """Fused distance + row-top-k for a query batch in one kernel launch.

    coords (v, m), qcs (nq, h, m) -> Z, S (nq, v, k).
    ``qmask``: optional (nq, h) validity mask (1 = real query bin);
    padding columns added here for blocking are always masked out.
    ``out_dtype``: storage dtype of the Z ladder (a precision policy's
    storage role); selection always runs in float32 inside the kernel.
    """
    v, m = coords.shape
    nq, h, _ = qcs.shape
    block_v = min(block_v, _round_up(v, 8))
    block_h = min(block_h, _round_up(h, 8))
    vp = _round_up(v, block_v)
    hp = _round_up(h, block_h)
    mask = (jnp.ones((nq, h), jnp.float32) if qmask is None
            else qmask.astype(jnp.float32))
    mask = jnp.pad(mask, ((0, 0), (0, hp - h))).reshape(nq, 1, hp)
    coords_p = jnp.pad(coords, ((0, vp - v), (0, 0)))
    qcs_p = jnp.pad(qcs, ((0, 0), (0, hp - h), (0, 0)))
    z, s = dist_topk_pallas(coords_p, qcs_p, mask, k, block_v=block_v,
                            block_h=block_h, interpret=_interpret_default(),
                            out_dtype=out_dtype)
    return z[:, :v], s[:, :v]


@functools.partial(jax.jit, static_argnames=("k", "block_v", "block_h",
                                             "out_dtype"))
def dist_topk(coords: jax.Array, qc: jax.Array, k: int, *,
              qmask: jax.Array | None = None,
              block_v: int = 256, block_h: int = 256,
              out_dtype: str = "float32"):
    """Fused distance + row-top-k. coords (v, m), qc (h, m) -> Z, S (v, k).

    Single-query view of ``dist_topk_batched`` (query-batch grid of 1).
    ``qmask``: optional (h,) validity mask (1 = real query bin).
    """
    z, s = dist_topk_batched(coords, qc[None], k,
                             qmask=None if qmask is None else qmask[None],
                             block_v=block_v, block_h=block_h,
                             out_dtype=out_dtype)
    return z[0], s[0]


@functools.partial(jax.jit, static_argnames=("block_n", "block_h"))
def act_phase2_batched(x: jax.Array, zg: jax.Array, wg: jax.Array, *,
                       block_n: int = 256, block_h: int = 256) -> jax.Array:
    """Fused Phase-2/3 pour for a query batch in one kernel launch.

    x (n, hmax) shared residual weights; zg (nq, n, hmax, k) and
    wg (nq, n, hmax, k-1) per-query ladders -> t (nq, n). Padding
    rows/slots must carry zero weight (they do, by the Corpus
    construction), so block padding contributes exactly 0 cost."""
    n, hmax = x.shape
    block_n = min(block_n, _round_up(n, 8))
    block_h = min(block_h, _round_up(hmax, 8))
    np_, hp = _round_up(n, block_n), _round_up(hmax, block_h)
    pad2 = ((0, np_ - n), (0, hp - hmax))
    pad4 = ((0, 0),) + pad2 + ((0, 0),)
    t = act_phase2_pallas(jnp.pad(x, pad2), jnp.pad(zg, pad4),
                          jnp.pad(wg, pad4), block_n=block_n,
                          block_h=block_h, interpret=_interpret_default())
    return t[:, :n, 0]


@functools.partial(jax.jit, static_argnames=("block_n", "block_h"))
def act_phase2(x: jax.Array, zg: jax.Array, wg: jax.Array, *,
               block_n: int = 256, block_h: int = 256) -> jax.Array:
    """Fused Phase-2/3 pour. x (n, hmax), zg (n, hmax, k), wg (n, hmax, k-1)
    -> t (n,). Single-query view of ``act_phase2_batched``."""
    return act_phase2_batched(x, zg[None], wg[None], block_n=block_n,
                              block_h=block_h)[0]


@functools.partial(jax.jit, static_argnames=("block_n", "block_h"))
def act_phase2_cand(xg: jax.Array, zg: jax.Array, wg: jax.Array, *,
                    block_n: int = 256, block_h: int = 256) -> jax.Array:
    """Candidate-grid Phase-2/3 pour: per-query residuals.

    xg (nq, b, hmax) per-query candidate weights; zg (nq, b, hmax, k) /
    wg (nq, b, hmax, k-1) pre-gathered ladders -> t (nq, b). The unfused
    schedule for callers already holding gathered ladders; the ``cand_*``
    wrappers below fuse the gather into the same launch."""
    nq, b, hmax = xg.shape
    block_n = min(block_n, _round_up(b, 8))
    block_h = min(block_h, _round_up(hmax, 8))
    bp, hp = _round_up(b, block_n), _round_up(hmax, block_h)
    pad3 = ((0, 0), (0, bp - b), (0, hp - hmax))
    pad4 = pad3 + ((0, 0),)
    t = act_phase2_cand_pallas(jnp.pad(xg, pad3), jnp.pad(zg, pad4),
                               jnp.pad(wg, pad4), block_n=block_n,
                               block_h=block_h,
                               interpret=_interpret_default())
    return t[:, :b, 0]


# ------------------------------------------------------ candidate kernels
#
# Fused per-query candidate gather + Phase-2/3 reduction (cascade stages).
# Shapes: idsg/xg (nq, b, hmax) are the candidate sub-corpus
# (corpus.ids[cand] / corpus.w[cand] — already compacted, k+ times smaller
# than the ladder gathers these kernels avoid); the Phase-1 handoff rides
# in per-query tables. Padding added here (candidate rows to a block_n
# multiple, vocabulary rows to a block_v multiple) contributes exactly
# zero cost and is sliced off.


def _cand_blocking(idsg, xg, table, block_n: int, block_v: int):
    """Shared blocking for the fused candidate wrappers: clamp the tiles
    to the (8-rounded) data sizes, zero-pad the candidate axis to a
    block_n multiple and the table's vocabulary axis to a block_v
    multiple. Returns (idsg, xg, table, block_n, block_v, b) with ``b``
    the original candidate count to slice the output back to."""
    nq, b, hmax = idsg.shape
    v = table.shape[1]
    block_n = min(block_n, _round_up(b, 8))
    block_v = min(block_v, _round_up(v, 8))
    padb = ((0, 0), (0, _round_up(b, block_n) - b), (0, 0))
    table = jnp.pad(table, ((0, 0), (0, _round_up(v, block_v) - v), (0, 0)))
    return (jnp.pad(idsg, padb), jnp.pad(xg, padb), table, block_n,
            block_v, b)


@functools.partial(jax.jit, static_argnames=("iters", "block_n", "block_v"))
def cand_pour(idsg: jax.Array, xg: jax.Array, Z: jax.Array,
              W: jax.Array | None, iters: int, *, block_n: int = 128,
              block_v: int = 256) -> jax.Array:
    """Fused candidate gather + pour: the LC-ACT (iters >= 1) and LC-RWMD
    masked-min (iters == 0) candidate reductions in one kernel launch.

    idsg/xg (nq, b, hmax); Z (nq, v, >= iters+1) cost ladder;
    W (nq, v, >= iters) capacity ladder (``None`` when iters == 0)
    -> (nq, b) scores, matching the reference candidate engines to
    within a few ulps (exact gather + the reference reduction formulas;
    see ``kernels/cand_pour``'s conformance notes).
    """
    k = iters + 1
    table = Z[..., :k] if iters == 0 else \
        jnp.concatenate([Z[..., :k], W[..., :iters]], axis=-1)
    idsg, xg, table, block_n, block_v, b = _cand_blocking(
        idsg, xg, table, block_n, block_v)
    t = cand_pour_pallas(idsg, xg, table, k=k, iters=iters, mode="pour",
                         block_n=block_n, block_v=block_v,
                         interpret=_interpret_default())
    return t[:, :b]


@functools.partial(jax.jit, static_argnames=("block_n", "block_v"))
def cand_omr(idsg: jax.Array, xg: jax.Array, Z: jax.Array, W0: jax.Array,
             *, block_n: int = 128, block_v: int = 256) -> jax.Array:
    """Fused candidate gather + LC-OMR Algorithm-1 reduction.

    idsg/xg (nq, b, hmax); Z (nq, v, 2) top-2 costs; W0 (nq, v) first
    capacities -> (nq, b) scores.
    """
    table = jnp.concatenate([Z[..., :2], W0[..., None]], axis=-1)
    idsg, xg, table, block_n, block_v, b = _cand_blocking(
        idsg, xg, table, block_n, block_v)
    t = cand_pour_pallas(idsg, xg, table, k=2, iters=1, mode="omr",
                         block_n=block_n, block_v=block_v,
                         interpret=_interpret_default())
    return t[:, :b]


def _cand_dist(idsg, xg, Dq, qw, mode, block_n, block_v):
    idsg, xg, dq, block_n, block_v, b = _cand_blocking(
        idsg, xg, Dq, block_n, block_v)
    t = cand_dist_pallas(idsg, xg, dq, qw, mode=mode, block_n=block_n,
                         block_v=block_v, interpret=_interpret_default())
    return t[:, :b]


@functools.partial(jax.jit, static_argnames=("block_n", "block_v"))
def cand_rev_min(idsg: jax.Array, xg: jax.Array, Dq: jax.Array,
                 qw: jax.Array, *, block_n: int = 128,
                 block_v: int = 256) -> jax.Array:
    """Fused candidate gather + reverse-RWMD masked (min,+) reduction.

    idsg/xg (nq, b, hmax); Dq (nq, v, h) distance handoff; qw (nq, h)
    query weights -> (nq, b) scores (invalid slots mask to the finite
    ``lc.PAD_DIST``, matching ``lc.rev_min_cand_blocked``).
    """
    return _cand_dist(idsg, xg, Dq, qw, "rev_min", block_n, block_v)


@functools.partial(jax.jit, static_argnames=("block_n", "block_v"))
def cand_ict(idsg: jax.Array, xg: jax.Array, Dq: jax.Array,
             qw: jax.Array, *, block_n: int = 128,
             block_v: int = 256) -> jax.Array:
    """Fused candidate gather + LC-ICT full-ladder pour (Algorithm 2).

    idsg/xg (nq, b, hmax); Dq (nq, v, h); qw (nq, h) -> (nq, b) scores.
    Runs ``lc.ict_pour`` on the gathered tile, so the remainder dump
    stays at the max FINITE gathered cost — never ``lc.PAD_DIST``, where
    a ~1e-7 cumsum residue would explode to ~1e23.
    """
    return _cand_dist(idsg, xg, Dq, qw, "ict", block_n, block_v)


# --------------------------------------------------- static block metadata
#
# The per-grid-cell block layout of every kernel family, as DATA: the same
# clamp/pad arithmetic the wrappers above apply, but evaluated without
# tracing anything. ``repro.analysis.vmem`` turns these layouts into a
# static VMEM-footprint model (checked in CI, swept by the future tile
# autotuner), so any change to a wrapper's blocking MUST be mirrored here
# — the conformance test pins the two against each other on the padded
# shapes the wrappers actually launch.

_DTYPE_BYTES = {"float32": 4, "int32": 4, "bfloat16": 2, "float16": 2,
                "int8": 1, "uint8": 1, "bool": 1}


@dataclasses.dataclass(frozen=True)
class BlockBuffer:
    """One VMEM-resident buffer of a kernel grid cell.

    role: ``in`` / ``out`` blocks are pipelined by Pallas (double-buffered
    while the grid streams, so they count twice in the footprint);
    ``scratch`` covers the kernel body's dominant temporaries (single
    copy). The scratch entries are a documented lower-ish bound — Mosaic
    may materialize more registers — which is why the VMEM budget the
    checker enforces leaves headroom below the hardware's ~16 MB.
    """
    name: str
    shape: tuple[int, ...]
    dtype: str = "float32"
    role: str = "in"

    def __post_init__(self) -> None:
        assert self.role in ("in", "out", "scratch"), self.role
        assert self.dtype in _DTYPE_BYTES, self.dtype

    @property
    def nbytes(self) -> int:
        return math.prod(self.shape) * _DTYPE_BYTES[self.dtype]


@dataclasses.dataclass(frozen=True)
class KernelBlocks:
    """Static description of one kernel launch: grid + per-cell buffers."""
    family: str
    grid: tuple[int, ...]
    buffers: tuple[BlockBuffer, ...]

    def vmem_bytes(self, *, pipeline_depth: int = 2) -> int:
        """Per-core VMEM footprint of one grid cell: pipelined in/out
        blocks count ``pipeline_depth`` times (Pallas double-buffers the
        HBM<->VMEM streams by default), scratch once."""
        total = 0
        for b in self.buffers:
            total += b.nbytes * (1 if b.role == "scratch" else pipeline_depth)
        return total

    def buffer(self, name: str) -> BlockBuffer:
        for b in self.buffers:
            if b.name == name:
                return b
        raise KeyError(f"{self.family} has no buffer {name!r}; "
                       f"have {[b.name for b in self.buffers]}")


def _positive(**dims) -> None:
    bad = {k: v for k, v in dims.items() if v < 1}
    if bad:
        raise ValueError(f"kernel dims/blocks must be >= 1, got {bad}")


def _dist_topk_layout(*, nq: int, v: int, h: int, m: int, k: int,
                      block_v: int = 256, block_h: int = 256,
                      dtype: str = "float32") -> KernelBlocks:
    _positive(nq=nq, v=v, h=h, m=m, k=k, block_v=block_v, block_h=block_h)
    block_v = min(block_v, _round_up(v, 8))
    block_h = min(block_h, _round_up(h, 8))
    vp, hp = _round_up(v, block_v), _round_up(h, block_h)
    return KernelBlocks(
        family="dist_topk",
        grid=(nq, vp // block_v, hp // block_h),
        buffers=(
            BlockBuffer("coords", (block_v, m)),
            BlockBuffer("qcs", (1, block_h, m)),
            BlockBuffer("qmask", (1, 1, block_h)),
            # z is the Z-ladder STORAGE block (``dtype`` = the precision
            # policy's storage role — the axis that shrinks under bf16)
            BlockBuffer("z", (1, block_v, k), dtype, "out"),
            BlockBuffer("s", (1, block_v, k), "int32", "out"),
            # the (bv, bh) distance tile + its global column ids — the
            # body's working set that never leaves VMEM
            BlockBuffer("dist_tile", (block_v, block_h), role="scratch"),
            BlockBuffer("col_ids", (block_v, block_h), "int32", "scratch"),
        ))


def _act_phase2_layout(*, nq: int, n: int, h: int, iters: int,
                       block_n: int = 256, block_h: int = 256,
                       per_query_x: bool = False,
                       dtype: str = "float32") -> KernelBlocks:
    _positive(nq=nq, n=n, h=h, block_n=block_n, block_h=block_h)
    if iters < 0:
        raise ValueError(f"iters must be >= 0, got {iters}")
    block_n = min(block_n, _round_up(n, 8))
    block_h = min(block_h, _round_up(h, 8))
    np_, hp = _round_up(n, block_n), _round_up(h, block_h)
    x_shape = (1, block_n, block_h) if per_query_x else (block_n, block_h)
    return KernelBlocks(
        family="act_phase2_cand" if per_query_x else "act_phase2",
        grid=(nq, np_ // block_n, hp // block_h),
        buffers=(
            BlockBuffer("x", x_shape),
            # the gathered Phase-1 ladders ride in storage dtype; the
            # pour itself upcasts slice-by-slice to float32 scratch
            BlockBuffer("zg", (1, block_n, block_h, iters + 1), dtype),
            BlockBuffer("wg", (1, block_n, block_h, iters), dtype),
            BlockBuffer("t", (1, block_n, 1), role="out"),
            # pour temporaries: acc / prefix / poured / r, each (bn, bh)
            BlockBuffer("pour_tmp", (4, block_n, block_h), role="scratch"),
        ))


def _cand_table_width(mode: str, k: int, iters: int) -> int:
    if mode == "omr":
        return 3                                   # Z top-2 + W0
    return k + iters                               # Z ladder + W ladder


def _cand_pour_layout(*, nq: int, b: int, h: int, v: int, k: int,
                      iters: int, mode: str = "pour", block_n: int = 128,
                      block_v: int = 256,
                      dtype: str = "float32") -> KernelBlocks:
    from repro.kernels.cand_pour import POUR_MODES
    assert mode in POUR_MODES, mode
    _positive(nq=nq, b=b, h=h, v=v, k=k, block_n=block_n, block_v=block_v)
    width = _cand_table_width(mode, k, iters)
    block_n = min(block_n, _round_up(b, 8))
    block_v = min(block_v, _round_up(v, 8))
    bp, vp = _round_up(b, block_n), _round_up(v, block_v)
    r = block_n * h
    return KernelBlocks(
        family="cand_pour",
        grid=(nq, bp // block_n),
        buffers=(
            BlockBuffer("idsg", (1, block_n, h), "int32"),
            BlockBuffer("xg", (1, block_n, h)),
            # the query's FULL padded Phase-1 ladder rides in every cell
            # in storage dtype — the dominant slab bf16 halves
            BlockBuffer("table", (1, vp, width), dtype),
            BlockBuffer("t", (1, block_n), role="out"),
            # the one-hot gather matmul runs in the table's dtype (0/1
            # are exact in any float dtype); accumulation is f32
            BlockBuffer("onehot", (r, block_v), dtype, "scratch"),
            BlockBuffer("gathered", (r, width), role="scratch"),
            BlockBuffer("chunk", (block_v, width), dtype, "scratch"),
        ))


def _cand_dist_layout(*, nq: int, b: int, h: int, v: int, qh: int,
                      mode: str = "rev_min", block_n: int = 128,
                      block_v: int = 256,
                      dtype: str = "float32") -> KernelBlocks:
    from repro.kernels.cand_pour import DIST_MODES
    assert mode in DIST_MODES, mode
    _positive(nq=nq, b=b, h=h, v=v, qh=qh, block_n=block_n, block_v=block_v)
    block_n = min(block_n, _round_up(b, 8))
    block_v = min(block_v, _round_up(v, 8))
    bp, vp = _round_up(b, block_n), _round_up(v, block_v)
    r = block_n * h
    scratch = [
        BlockBuffer("onehot", (r, block_v), dtype, "scratch"),
        # the running gather accumulator: persists across the streamed
        # vocabulary slabs, holds the completed (r, qh) cost tensor on
        # the last one
        BlockBuffer("acc", (r, qh), role="scratch"),
        # rev_min: the PAD_DIST-masked copy; ict: ict_pour's sorted
        # ladder + cumsum, ~2 extra copies of the gathered cost tile
        BlockBuffer("reduce_tmp",
                    ((1 if mode == "rev_min" else 2) * r, qh),
                    role="scratch"),
    ]
    return KernelBlocks(
        family="cand_dist",
        grid=(nq, bp // block_n, vp // block_v),
        buffers=(
            BlockBuffer("idsg", (1, block_n, h), "int32"),
            BlockBuffer("xg", (1, block_n, h)),
            # one streamed slab per grid step — NOT the full (vp, qh)
            # handoff; this is what fits cand_dist at 20News dims.
            # Rides in storage dtype; the gather accumulates into f32.
            BlockBuffer("dq", (1, block_v, qh), dtype),
            BlockBuffer("qw", (1, qh)),
            BlockBuffer("t", (1, block_n), role="out"),
            *scratch,
        ))


#: family name -> layout function. The enumerable surface
#: ``repro.analysis.vmem`` iterates; every pallas_call in this package
#: belongs to exactly one family (``cand_pour`` covers modes pour/omr,
#: ``cand_dist`` modes rev_min/ict via the ``mode`` kwarg).
KERNEL_FAMILIES = {
    "dist_topk": _dist_topk_layout,
    "act_phase2": _act_phase2_layout,
    "act_phase2_cand": functools.partial(_act_phase2_layout,
                                         per_query_x=True),
    "cand_pour": _cand_pour_layout,
    "cand_dist": _cand_dist_layout,
}


def block_layout(family: str, **dims) -> KernelBlocks:
    """Static per-cell block layout of one kernel launch (see
    :data:`KERNEL_FAMILIES` for the per-family dim kwargs)."""
    if family not in KERNEL_FAMILIES:
        raise ValueError(f"unknown kernel family {family!r}; "
                         f"one of {sorted(KERNEL_FAMILIES)}")
    return KERNEL_FAMILIES[family](**dims)
