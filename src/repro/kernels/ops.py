"""Jitted public wrappers around the Pallas kernels.

Handle padding to block multiples, backend selection (interpret=True on
CPU — the container has no TPU; the kernels are written for TPU BlockSpec
tiling and validated in interpret mode), and shape restoration.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.act_phase2 import act_phase2_pallas
from repro.kernels.dist_topk import dist_topk_pallas


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x: int, b: int) -> int:
    return -(-x // b) * b


@functools.partial(jax.jit, static_argnames=("k", "block_v", "block_h"))
def dist_topk_batched(coords: jax.Array, qcs: jax.Array, k: int, *,
                      qmask: jax.Array | None = None,
                      block_v: int = 256, block_h: int = 256):
    """Fused distance + row-top-k for a query batch in one kernel launch.

    coords (v, m), qcs (nq, h, m) -> Z, S (nq, v, k).
    ``qmask``: optional (nq, h) validity mask (1 = real query bin);
    padding columns added here for blocking are always masked out.
    """
    v, m = coords.shape
    nq, h, _ = qcs.shape
    block_v = min(block_v, _round_up(v, 8))
    block_h = min(block_h, _round_up(h, 8))
    vp = _round_up(v, block_v)
    hp = _round_up(h, block_h)
    mask = (jnp.ones((nq, h), jnp.float32) if qmask is None
            else qmask.astype(jnp.float32))
    mask = jnp.pad(mask, ((0, 0), (0, hp - h))).reshape(nq, 1, hp)
    coords_p = jnp.pad(coords, ((0, vp - v), (0, 0)))
    qcs_p = jnp.pad(qcs, ((0, 0), (0, hp - h), (0, 0)))
    z, s = dist_topk_pallas(coords_p, qcs_p, mask, k, block_v=block_v,
                            block_h=block_h, interpret=_interpret_default())
    return z[:, :v], s[:, :v]


@functools.partial(jax.jit, static_argnames=("k", "block_v", "block_h"))
def dist_topk(coords: jax.Array, qc: jax.Array, k: int, *,
              qmask: jax.Array | None = None,
              block_v: int = 256, block_h: int = 256):
    """Fused distance + row-top-k. coords (v, m), qc (h, m) -> Z, S (v, k).

    Single-query view of ``dist_topk_batched`` (query-batch grid of 1).
    ``qmask``: optional (h,) validity mask (1 = real query bin).
    """
    z, s = dist_topk_batched(coords, qc[None], k,
                             qmask=None if qmask is None else qmask[None],
                             block_v=block_v, block_h=block_h)
    return z[0], s[0]


@functools.partial(jax.jit, static_argnames=("block_n", "block_h"))
def act_phase2_batched(x: jax.Array, zg: jax.Array, wg: jax.Array, *,
                       block_n: int = 256, block_h: int = 256) -> jax.Array:
    """Fused Phase-2/3 pour for a query batch in one kernel launch.

    x (n, hmax) shared residual weights; zg (nq, n, hmax, k) and
    wg (nq, n, hmax, k-1) per-query ladders -> t (nq, n). Padding
    rows/slots must carry zero weight (they do, by the Corpus
    construction), so block padding contributes exactly 0 cost."""
    n, hmax = x.shape
    block_n = min(block_n, _round_up(n, 8))
    block_h = min(block_h, _round_up(hmax, 8))
    np_, hp = _round_up(n, block_n), _round_up(hmax, block_h)
    pad2 = ((0, np_ - n), (0, hp - hmax))
    pad4 = ((0, 0),) + pad2 + ((0, 0),)
    t = act_phase2_pallas(jnp.pad(x, pad2), jnp.pad(zg, pad4),
                          jnp.pad(wg, pad4), block_n=block_n,
                          block_h=block_h, interpret=_interpret_default())
    return t[:, :n, 0]


@functools.partial(jax.jit, static_argnames=("block_n", "block_h"))
def act_phase2(x: jax.Array, zg: jax.Array, wg: jax.Array, *,
               block_n: int = 256, block_h: int = 256) -> jax.Array:
    """Fused Phase-2/3 pour. x (n, hmax), zg (n, hmax, k), wg (n, hmax, k-1)
    -> t (n,). Single-query view of ``act_phase2_batched``."""
    return act_phase2_batched(x, zg[None], wg[None], block_n=block_n,
                              block_h=block_h)[0]
