"""Pallas TPU kernel: fused pairwise-distance + running row-top-k (Phase 1).

The paper materializes the v x h distance matrix D on the GPU and then
reduces it. On TPU we tile V (over the grid's parallel axis) and Q (over an
arbitrary-order reduction axis), compute each (bv, bh) distance tile on the
MXU via the ||a-b||^2 = ||a||^2 + ||b||^2 - 2ab expansion, and merge the
tile's k smallest entries per row into a running (Z, S) carried in the
output refs — D never leaves VMEM. Output is O(v*k) instead of O(v*h).

k is small (<= 16 in the paper), so selection is a k-round masked row-min
network on the VPU rather than a sort: each round extracts the current row
minimum and masks it out with a one-hot built from broadcasted iota.

The grid carries a query-batch dimension as its outermost (parallel) axis:
a batch of nq queries runs as one kernel launch with coords tiles shared
across queries, so multi-query serving needs no host-side looping.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.precision import pad_dist_for

BIG = 1e30  # plain float: jnp scalars would be captured consts in the kernel


def _rowmin_extract(d, col_ids, big=BIG):
    """One selection round: per-row (min value, argmin col id), then mask.

    d: (bv, bh) working distances; col_ids: (bv, bh) global column ids.
    Returns (minval (bv,1), minidx (bv,1), d with the winner masked to
    ``big``).
    """
    minval = jnp.min(d, axis=1, keepdims=True)                    # (bv, 1)
    is_min = d == minval
    # Lowest column id among ties — matches lax.top_k tie-breaking.
    idx_cand = jnp.where(is_min, col_ids, jnp.int32(2**31 - 1))
    minidx = jnp.min(idx_cand, axis=1, keepdims=True)             # (bv, 1)
    d = jnp.where(col_ids == minidx, big, d)
    return minval, minidx, d


def _dist_topk_kernel(v_ref, q_ref, qmask_ref, z_ref, s_ref, *, k: int,
                      block_h: int, out_dtype):
    """Grid = (nq, v_blocks, h_blocks); the query batch is the outermost
    (parallel) axis, h the innermost sequential merge axis. Each (q, i)
    output block carries its running (Z, S) across the h sweep."""
    j = pl.program_id(2)
    # Sentinel exactly representable in the OUTPUT dtype: masked entries
    # survive the f32 -> out_dtype store bit-exactly, so downstream strict
    # ``< pad`` comparisons still exclude them (pad_dist_for(float32) is
    # bitwise the historical BIG). All selection work stays float32.
    big = pad_dist_for(out_dtype)

    vt = v_ref[...].astype(jnp.float32)                           # (bv, m)
    qt = q_ref[0].astype(jnp.float32)                             # (bh, m)
    v2 = jnp.sum(vt * vt, axis=1, keepdims=True)                  # (bv, 1)
    q2 = jnp.sum(qt * qt, axis=1, keepdims=True).T                # (1, bh)
    d = v2 + q2 - 2.0 * jax.lax.dot_general(
        vt, qt, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                       # (bv, bh)
    d = jnp.maximum(d, 0.0)
    # relative ZERO_SNAP (see core/geometry.py): exact zeros are load-bearing
    d = jnp.where(d < 1e-6 * (v2 + q2), 0.0, d)
    d = jnp.sqrt(d)
    # Invalid columns (padding / zero-weight query bins) never win.
    d = jnp.where(qmask_ref[0] > 0, d, big)                       # (1, bh) bcast

    bv = d.shape[0]
    col0 = j * block_h
    col_ids = col0 + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)

    # Tile-local top-k via k min-extraction rounds.
    zs, ss = [], []
    for _ in range(k):
        mv, mi, d = _rowmin_extract(d, col_ids, big)
        zs.append(mv)
        ss.append(mi)
    z_tile = jnp.concatenate(zs, axis=1)                          # (bv, k)
    s_tile = jnp.concatenate(ss, axis=1)                          # (bv, k)

    @pl.when(j == 0)
    def _init():
        z_ref[...] = z_tile[None].astype(out_dtype)
        s_ref[...] = s_tile[None]

    @pl.when(j > 0)
    def _merge():
        # Merge running (k) with tile (k): k extraction rounds over 2k
        # cands. The running Z re-enters the f32 accumulator first —
        # winner masking never happens in the storage dtype.
        zc = jnp.concatenate([z_ref[0].astype(jnp.float32), z_tile],
                             axis=1)                              # (bv, 2k)
        sc = jnp.concatenate([s_ref[0], s_tile], axis=1)
        out_z, out_s = [], []
        work = zc
        for _ in range(k):
            mv = jnp.min(work, axis=1, keepdims=True)
            is_min = work == mv
            cand = jnp.where(is_min, sc, jnp.int32(2**31 - 1))
            mi = jnp.min(cand, axis=1, keepdims=True)
            # Mask exactly one winner slot (first matching position).
            pos = jax.lax.broadcasted_iota(jnp.int32, work.shape, 1)
            win_pos = jnp.min(jnp.where(is_min & (sc == mi), pos,
                                        jnp.int32(2**31 - 1)),
                              axis=1, keepdims=True)
            work = jnp.where(pos == win_pos, big, work)
            out_z.append(mv)
            out_s.append(mi)
        z_ref[...] = jnp.concatenate(out_z, axis=1)[None].astype(out_dtype)
        s_ref[...] = jnp.concatenate(out_s, axis=1)[None]


@functools.partial(jax.jit,
                   static_argnames=("k", "block_v", "block_h", "interpret",
                                    "out_dtype"))
def dist_topk_pallas(coords: jax.Array, qc: jax.Array, qmask: jax.Array,
                     k: int, *, block_v: int = 256, block_h: int = 256,
                     interpret: bool = False, out_dtype: str = "float32"):
    """Fused Euclidean distance + row-top-k over a query batch.

    Args:
      coords: (v, m) vocabulary embedding vectors, shared by all queries.
      qc:     (nq, h, m) query-bin embedding vectors.
      qmask:  (nq, 1, h) 1.0 for valid query bins, 0.0 for padding.
      k:      number of smallest distances to keep per vocabulary row.
      out_dtype: storage dtype of Z (a precision policy's storage role);
        selection always runs in float32 with a sentinel representable in
        ``out_dtype`` (see ``_dist_topk_kernel``).
    Returns:
      Z: (nq, v, k) ascending distances in ``out_dtype``;
      S: (nq, v, k) int32 bin indices.
    Caller guarantees v % block_v == 0 and h % block_h == 0 (see ops.py).
    """
    v, m = coords.shape
    nq, h, _ = qc.shape
    assert v % block_v == 0 and h % block_h == 0, (v, h, block_v, block_h)
    grid = (nq, v // block_v, h // block_h)
    kernel = functools.partial(_dist_topk_kernel, k=k, block_h=block_h,
                               out_dtype=jnp.dtype(out_dtype))
    z, s = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_v, m), lambda q, i, j: (i, 0)),
            pl.BlockSpec((1, block_h, m), lambda q, i, j: (q, j, 0)),
            pl.BlockSpec((1, 1, block_h), lambda q, i, j: (q, 0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_v, k), lambda q, i, j: (q, i, 0)),
            pl.BlockSpec((1, block_v, k), lambda q, i, j: (q, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, v, k), jnp.dtype(out_dtype)),
            jax.ShapeDtypeStruct((nq, v, k), jnp.int32),
        ],
        interpret=interpret,
    )(coords, qc, qmask)
    return z, s
