"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests).

The ``cand_*`` oracles mirror the candidate kernels with an XLA gather in
place of the in-kernel one-hot gather — the reduction formulas are the
reference engines' own (``lc.pour`` / ``lc.ict_pour`` / the Algorithm-1
and masked-min expressions), so the fused kernels are expected to match
them exactly, not just within tolerance (``tests/test_cand_kernels.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.geometry import pairwise_dist
from repro.core.lc import PAD_DIST, gather_per_query, ict_pour, pour


def dist_topk_ref(coords: jax.Array, qc: jax.Array, qmask: jax.Array, k: int):
    """Materialized-D reference for ``dist_topk``: full (v, h) distance matrix
    then lax.top_k of the negated rows."""
    D = pairwise_dist(coords.astype(jnp.float32), qc.astype(jnp.float32))
    D = jnp.where(qmask.reshape(1, -1) > 0, D, PAD_DIST)
    neg, s = jax.lax.top_k(-D, k)
    return -neg, s


def dist_topk_batched_ref(coords: jax.Array, qcs: jax.Array,
                          qmask: jax.Array, k: int):
    """Per-query loop of ``dist_topk_ref``: the (nq, v, k) oracle for the
    query-batched kernel grid."""
    return jax.vmap(lambda qc, qm: dist_topk_ref(coords, qc, qm, k))(
        qcs, qmask)


def act_phase2_ref(x: jax.Array, zg: jax.Array, wg: jax.Array) -> jax.Array:
    """Sequential-rounds reference for ``act_phase2`` — implements the
    paper's eqs. (6)-(9) literally: k-1 min/subtract rounds then the dump."""
    x = x.astype(jnp.float32)
    iters = wg.shape[-1]
    t = jnp.zeros(x.shape[:-1], jnp.float32)
    for l in range(iters):
        y = jnp.minimum(x, wg[..., l].astype(jnp.float32))   # eq. (6)
        x = x - y                                            # eq. (7)
        t = t + jnp.sum(y * zg[..., l], axis=-1)             # eq. (8)
    t = t + jnp.sum(x * zg[..., iters], axis=-1)             # eq. (9)
    return t[..., None]


def act_phase2_batched_ref(x: jax.Array, zg: jax.Array,
                           wg: jax.Array) -> jax.Array:
    """Per-query loop of ``act_phase2_ref`` over shared x: the (nq, n)
    oracle for the query-batched pour grid."""
    return jax.vmap(lambda z, w: act_phase2_ref(x, z, w)[:, 0])(zg, wg)


def act_phase2_cand_ref(xg: jax.Array, zg: jax.Array,
                        wg: jax.Array) -> jax.Array:
    """Per-query loop with per-query residuals: the (nq, b) oracle for
    the candidate-grid pour (each query pours its own sub-corpus)."""
    return jax.vmap(lambda x, z, w: act_phase2_ref(x, z, w)[:, 0])(xg, zg, wg)


def cand_pour_ref(idsg: jax.Array, xg: jax.Array, Z: jax.Array,
                  W: jax.Array | None, iters: int) -> jax.Array:
    """XLA-gather oracle for ``cand_pour``: per-query ladder gather at the
    candidate entries, then the reference ``lc.pour``."""
    Zg = gather_per_query(Z[..., :iters + 1], idsg)
    if iters == 0:
        return jnp.sum(xg * Zg[..., 0], axis=-1)
    Wg = gather_per_query(W[..., :iters], idsg)
    return pour(xg, Zg, Wg, iters)


def cand_omr_ref(idsg: jax.Array, xg: jax.Array, Z: jax.Array,
                 W0: jax.Array) -> jax.Array:
    """XLA-gather oracle for ``cand_omr`` (Algorithm-1 top-2 reduction)."""
    Zg = gather_per_query(Z[..., :2], idsg)
    W0g = gather_per_query(W0, idsg)
    overlap = Zg[..., 0] == 0.0
    rest = xg - jnp.minimum(xg, W0g)
    per_entry = jnp.where(overlap, rest * Zg[..., 1], xg * Zg[..., 0])
    return jnp.sum(per_entry, axis=-1)


def cand_rev_min_ref(idsg: jax.Array, xg: jax.Array, Dq: jax.Array,
                     qw: jax.Array) -> jax.Array:
    """XLA-gather oracle for ``cand_rev_min`` (masked (min,+) . q_w)."""
    Dg = gather_per_query(Dq, idsg)                      # (nq, b, hmax, h)
    Dg = jnp.where((xg > 0.0)[..., None], Dg, jnp.asarray(PAD_DIST,
                                                          Dg.dtype))
    cmin = jnp.min(Dg, axis=2)                           # (nq, b, h)
    return jnp.sum(cmin * qw[:, None, :], axis=-1)


def cand_ict_ref(idsg: jax.Array, xg: jax.Array, Dq: jax.Array,
                 qw: jax.Array) -> jax.Array:
    """XLA-gather oracle for ``cand_ict`` (full-ladder Algorithm-2 pour,
    max-FINITE remainder dump via ``lc.ict_pour``)."""
    C = gather_per_query(Dq, idsg)                       # (nq, b, hmax, h)
    cap = jnp.broadcast_to(qw[:, None, None, :], C.shape)
    return ict_pour(xg, cap, C)
