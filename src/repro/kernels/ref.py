"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.geometry import pairwise_dist
from repro.core.lc import PAD_DIST


def dist_topk_ref(coords: jax.Array, qc: jax.Array, qmask: jax.Array, k: int):
    """Materialized-D reference for ``dist_topk``: full (v, h) distance matrix
    then lax.top_k of the negated rows."""
    D = pairwise_dist(coords.astype(jnp.float32), qc.astype(jnp.float32))
    D = jnp.where(qmask.reshape(1, -1) > 0, D, PAD_DIST)
    neg, s = jax.lax.top_k(-D, k)
    return -neg, s


def dist_topk_batched_ref(coords: jax.Array, qcs: jax.Array,
                          qmask: jax.Array, k: int):
    """Per-query loop of ``dist_topk_ref``: the (nq, v, k) oracle for the
    query-batched kernel grid."""
    return jax.vmap(lambda qc, qm: dist_topk_ref(coords, qc, qm, k))(
        qcs, qmask)


def act_phase2_ref(x: jax.Array, zg: jax.Array, wg: jax.Array) -> jax.Array:
    """Sequential-rounds reference for ``act_phase2`` — implements the
    paper's eqs. (6)-(9) literally: k-1 min/subtract rounds then the dump."""
    x = x.astype(jnp.float32)
    iters = wg.shape[-1]
    t = jnp.zeros(x.shape[:-1], jnp.float32)
    for l in range(iters):
        y = jnp.minimum(x, wg[..., l].astype(jnp.float32))   # eq. (6)
        x = x - y                                            # eq. (7)
        t = t + jnp.sum(y * zg[..., l], axis=-1)             # eq. (8)
    t = t + jnp.sum(x * zg[..., iters], axis=-1)             # eq. (9)
    return t[..., None]


def act_phase2_batched_ref(x: jax.Array, zg: jax.Array,
                           wg: jax.Array) -> jax.Array:
    """Per-query loop of ``act_phase2_ref`` over shared x: the (nq, n)
    oracle for the query-batched pour grid."""
    return jax.vmap(lambda z, w: act_phase2_ref(x, z, w)[:, 0])(zg, wg)
