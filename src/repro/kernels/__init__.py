"""Pallas TPU kernels for the paper's compute hot spots.

dist_topk   — fused pairwise-distance + row-top-k (LC-ACT Phase 1).
act_phase2  — fused k-round constrained pour (LC-ACT Phases 2+3).

Written for TPU (pl.pallas_call + BlockSpec VMEM tiling); validated with
interpret=True on CPU. ``ops`` holds the jitted padding wrappers; ``ref``
holds the pure-jnp oracles.
"""
from repro.kernels import ops, ref
from repro.kernels.ops import act_phase2, dist_topk

__all__ = ["ops", "ref", "act_phase2", "dist_topk"]
