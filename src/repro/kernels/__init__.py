"""Pallas TPU kernels for the paper's compute hot spots.

dist_topk   — fused pairwise-distance + row-top-k (LC-ACT Phase 1).
act_phase2  — fused k-round constrained pour (LC-ACT Phases 2+3), on the
              shared-x full-corpus grid or the per-query candidate grid.
cand_pour   — fused per-query candidate gather + Phase-2/3 reduction for
              the cascade's compacted stages (pour / OMR / reverse-min /
              ICT modes; the (nq, b, hmax, k) gather never hits HBM).

Written for TPU (pl.pallas_call + BlockSpec VMEM tiling); validated with
interpret=True on CPU. ``ops`` holds the jitted padding wrappers; ``ref``
holds the pure-jnp oracles.
"""
from repro.kernels import ops, ref
from repro.kernels.ops import (act_phase2, act_phase2_cand, cand_ict,
                               cand_omr, cand_pour, cand_rev_min, dist_topk)

__all__ = ["ops", "ref", "act_phase2", "act_phase2_cand", "cand_ict",
           "cand_omr", "cand_pour", "cand_rev_min", "dist_topk"]
