"""VMEM-driven tile autotuner for the fused Pallas kernels.

Tile sizes (``block_n`` / ``block_v`` / ``block_h``) decide both whether
a launch FITS (the 16 MiB double-buffered VMEM budget) and how fast it
runs (arithmetic intensity vs pipeline depth). Rather than hand-tuning,
this module closes the loop over the two artifacts PR 6 made static:

* candidate enumeration — :func:`admissible_configs` sweeps tile
  assignments and keeps only those ``analysis/vmem.check_launch`` admits
  (same clamp/pad arithmetic as the wrappers, evaluated without
  tracing), so no timed config can OOM a core;
* timing — :func:`tune` runs a paired-interleaved tournament
  (``benchmarks.common.paired``, the benches' own harness: interleaving
  cancels drift between the incumbent and the challenger) and caches the
  winner in a :class:`TuneCache` keyed by (kernel family, shape bucket,
  dtype) — shapes bucket to the next power of two, so one measurement
  serves the whole bucket.

``EngineConfig`` threads the policy: ``autotune="off"`` (default —
nothing here runs), ``"cached"`` (apply cached winners, never time; a
miss keeps the defaults, so builds are deterministic and cheap), or
``"force"`` (time admissible configs now and overwrite the cache).
Explicit ``block_*`` values always override: only knobs still at their
``EngineConfig`` dataclass defaults are eligible for autotuned
replacement (:func:`resolve_config`).
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os

from repro.analysis import vmem
from repro.kernels import ops

#: Per kernel family: the (EngineConfig knob, dim it tiles) pairs the
#: tuner sweeps. Dims absent from a launch's ``dims`` dict are skipped.
FAMILY_KNOBS: dict[str, tuple[tuple[str, str], ...]] = {
    "dist_topk": (("block_v", "v"), ("block_h", "h")),
    "act_phase2": (("block_n", "n"), ("block_h", "h")),
    "act_phase2_cand": (("block_n", "n"), ("block_h", "h")),
    "cand_pour": (("block_n", "b"), ("block_v", "v")),
    "cand_dist": (("block_n", "b"), ("block_v", "v")),
}

#: Tile candidates per knob. Sub-8 sizes are real choices: ``cand_dist``
#: at paper scale (h = 500) only fits with block_n = 2.
CANDIDATE_BLOCKS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _bucket(x: int) -> int:
    """Next power of two >= x (>= 1) — the shape-bucketing of cache keys."""
    b = 1
    while b < x:
        b *= 2
    return b


def admissible_configs(family: str, dims: dict, *,
                       budget_bytes: int = vmem.DEFAULT_VMEM_BUDGET_BYTES,
                       ) -> list[dict]:
    """Every tile assignment for ``family`` at ``dims`` that
    ``vmem.check_launch`` admits, deduplicated by the wrappers' clamped
    effective tiles (a 512 block over a 96-wide dim clamps to the same
    launch as 128 — one entry). Deterministic order: ascending tiles."""
    knobs = [(knob, dim) for knob, dim in FAMILY_KNOBS[family]
             if dim in dims]
    out, seen = [], set()
    for combo in itertools.product(CANDIDATE_BLOCKS, repeat=len(knobs)):
        cfg = {knob: blk for (knob, _), blk in zip(knobs, combo)}
        eff = tuple(min(blk, _round_up(dims[dim], 8))
                    for (_, dim), blk in zip(knobs, combo))
        if eff in seen:
            continue
        if vmem.check_launch(f"autotune:{family}", family, {**dims, **cfg},
                             budget_bytes=budget_bytes):
            continue                           # any violation -> rejected
        seen.add(eff)
        out.append(cfg)
    return out


@dataclasses.dataclass
class TuneCache:
    """Winner store: {cache key -> {knob: tile}}. JSON round-trippable so
    a tuning run on real hardware ships as a file."""
    entries: dict = dataclasses.field(default_factory=dict)

    @staticmethod
    def key(family: str, dims: dict, dtype: str = "float32") -> str:
        parts = []
        for k in sorted(dims):
            v = dims[k]
            parts.append(f"{k}={_bucket(v) if isinstance(v, int) else v}")
        return f"{family}|{','.join(parts)}|{dtype}"

    def get(self, family: str, dims: dict,
            dtype: str = "float32") -> dict | None:
        hit = self.entries.get(self.key(family, dims, dtype))
        return dict(hit) if hit is not None else None

    def put(self, family: str, dims: dict, config: dict,
            dtype: str = "float32") -> None:
        self.entries[self.key(family, dims, dtype)] = dict(config)

    def to_json(self) -> str:
        return json.dumps({"version": 1, "entries": self.entries},
                          indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "TuneCache":
        data = json.loads(text)
        return cls(entries=dict(data.get("entries", {})))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str | None) -> "TuneCache":
        """Empty cache when ``path`` is None or missing — a cold cache is
        the normal first-run state, not an error."""
        if path is None or not os.path.exists(path):
            return cls()
        with open(path) as f:
            return cls.from_json(f.read())


def tournament(configs: list[dict], make_run, reps: int = 5) -> dict:
    """Single-elimination paired timing: the incumbent meets each
    challenger in one interleaved ``paired`` bout; the faster (median of
    per-rep ratios) advances. O(len(configs)) bouts, drift-robust."""
    from benchmarks.common import paired

    best = configs[0]
    best_fn = make_run(best)
    for cfg in configs[1:]:
        fn = make_run(cfg)
        _, _, ratio = paired(best_fn, fn, reps)
        if ratio > 1.0:                        # incumbent slower
            best, best_fn = cfg, fn
    return best


def tune(family: str, dims: dict, make_run, *, cache: TuneCache | None = None,
         mode: str = "cached", dtype: str = "float32", reps: int = 5,
         budget_bytes: int = vmem.DEFAULT_VMEM_BUDGET_BYTES) -> dict | None:
    """Resolve the tile config for one launch shape.

    ``make_run(config) -> zero-arg callable`` builds the timed launch for
    a candidate (only invoked when timing actually happens). Returns the
    winning {knob: tile} dict, or ``None`` when ``mode="off"`` /
    ``mode="cached"`` misses / nothing is admissible.
    """
    if mode not in ("off", "cached", "force"):
        raise ValueError(f"unknown autotune mode {mode!r}; "
                         "one of ('off', 'cached', 'force')")
    if mode == "off":
        return None
    if mode == "cached":
        return cache.get(family, dims, dtype) if cache is not None else None
    configs = admissible_configs(family, dims, budget_bytes=budget_bytes)
    if not configs:
        return None
    best = tournament(configs, make_run, reps)
    if cache is not None:
        cache.put(family, dims, best, dtype)
    return best


# ------------------------------------------------------------------ index
# EngineConfig resolution: which launches an EmdIndex build will make and
# what to time them with. Shapes are capped for force-mode timing — the
# cache key still buckets the TRUE shape, only the measurement proxy
# shrinks (a paper-scale act_phase2 gather would need GBs on the host).


_TIME_CAPS = dict(n=4096, v=4096, b=512, nq=8)


def _capped(dims: dict) -> dict:
    return {k: min(v, _TIME_CAPS[k]) if k in _TIME_CAPS else v
            for k, v in dims.items()}


def _runner(family: str, dims: dict):
    """make_run factory for force-mode timing: synthetic inputs at the
    capped shape, fixed seed, jitted wrapper call per candidate config."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops as kops

    d = _capped(dims)
    rng = np.random.default_rng(0)

    if family == "dist_topk":
        coords = jnp.asarray(rng.normal(size=(d["v"], d["m"])), jnp.float32)
        qcs = jnp.asarray(rng.normal(size=(d["nq"], d["h"], d["m"])),
                          jnp.float32)

        def make_run(cfg):
            fn = jax.jit(lambda: kops.dist_topk_batched(
                coords, qcs, d["k"], **cfg))
            return fn
        return make_run

    if family in ("act_phase2", "act_phase2_cand"):
        x = jnp.asarray(rng.uniform(size=(d["n"], d["h"])), jnp.float32)
        k = d["iters"] + 1
        zg = jnp.asarray(np.sort(rng.uniform(
            size=(d["nq"], d["n"], d["h"], k)), -1), jnp.float32)
        wg = jnp.asarray(rng.uniform(
            size=(d["nq"], d["n"], d["h"], d["iters"])), jnp.float32)

        def make_run(cfg):
            return jax.jit(lambda: kops.act_phase2_batched(x, zg, wg, **cfg))
        return make_run

    assert family in ("cand_pour", "cand_dist"), family
    idsg = jnp.asarray(rng.integers(0, d["v"], size=(d["nq"], d["b"],
                                                     d["h"])), jnp.int32)
    xg = jnp.asarray(rng.uniform(size=(d["nq"], d["b"], d["h"])),
                     jnp.float32)
    if family == "cand_pour":
        k = d["k"]
        Z = jnp.asarray(np.sort(rng.uniform(size=(d["nq"], d["v"], k)), -1),
                        jnp.float32)
        W = jnp.asarray(rng.uniform(size=(d["nq"], d["v"], d["iters"])),
                        jnp.float32) if d["iters"] else None
        it = d["iters"]

        def make_run(cfg):
            return jax.jit(lambda: kops.cand_pour(idsg, xg, Z, W, it, **cfg))
        return make_run

    dq = jnp.asarray(rng.uniform(size=(d["nq"], d["v"], d["qh"])),
                     jnp.float32)
    qw = jnp.asarray(rng.uniform(size=(d["nq"], d["qh"])), jnp.float32)
    fn_k = kops.cand_ict if dims.get("mode") == "ict" else kops.cand_rev_min

    def make_run(cfg):
        return jax.jit(lambda: fn_k(idsg, xg, dq, qw, **cfg))
    return make_run


def index_plan(corpus, config) -> list[tuple[str, dict]]:
    """The (family, dims) launches an ``EmdIndex.build(corpus, config)``
    can make on its kernel path, in resolution order (first pick of a
    shared knob wins). Candidate families enter only with a cascade."""
    h, plan = corpus.hmax, []
    iters = config.effective_iters
    k = max(2, iters + 1)
    if config.spec.supports_kernels:
        plan.append(("dist_topk", dict(nq=8, v=corpus.v, h=h, m=corpus.m,
                                       k=k)))
        if iters >= 1:
            plan.append(("act_phase2", dict(nq=config.block_q, n=corpus.n,
                                            h=h, iters=iters)))
    if config.cascade is not None:
        b = 256
        plan.append(("cand_pour", dict(nq=config.block_q, b=b, h=h,
                                       v=corpus.v, k=k, iters=max(iters, 1),
                                       mode="pour")))
        plan.append(("cand_dist", dict(nq=config.block_q, b=b, h=h,
                                       v=corpus.v, qh=h, mode="ict")))
    return plan


def resolve_config(corpus, config):
    """Apply the autotune policy to an ``EngineConfig`` at build time.

    Returns ``(config, picks)``: the config with eligible block knobs
    replaced by tuned tiles, and ``{family: {knob: tile}}`` of what was
    applied (recorded by the benches). A knob is eligible only while it
    still equals its dataclass default — an explicit ``block_*`` always
    wins. ``"cached"`` never times (miss -> defaults kept); ``"force"``
    times every plan entry and persists to ``config.tune_cache``."""
    from repro.api.config import EngineConfig

    if config.autotune == "off":
        return config, {}
    cache = TuneCache.load(config.tune_cache)
    defaults = {f.name: f.default for f in dataclasses.fields(EngineConfig)}
    taken: set[str] = set()
    changes: dict = {}
    picks: dict = {}
    for family, dims in index_plan(corpus, config):
        make_run = (_runner(family, dims) if config.autotune == "force"
                    else None)
        pick = tune(family, dims, make_run, cache=cache,
                    mode=config.autotune)
        if not pick:
            continue
        applied = {}
        for knob, tile in pick.items():
            if knob in taken or getattr(config, knob) != defaults[knob]:
                continue
            taken.add(knob)
            changes[knob] = tile
            applied[knob] = tile
        if applied:
            picks[family] = applied
    if config.autotune == "force" and config.tune_cache is not None:
        cache.save(config.tune_cache)
    if changes:
        config = dataclasses.replace(config, **changes)
    return config, picks
