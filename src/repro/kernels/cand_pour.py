"""Pallas TPU kernels: fused candidate gather + Phase-2/3 reduction.

The cascade's candidate engines (``core/lc`` ``*_cand_blocked``) score each
query against its own (b,) surviving rows: the reference path gathers the
per-entry cost/capacity ladders with XLA (``Z[ids[cand]]`` — the
(nq, b, hmax, k) tensor lands in HBM) and then reduces. These kernels do
BOTH in one launch on a query-batch x candidate-block grid: each (q, i)
cell holds its query's full Phase-1 table in VMEM, gathers its candidate
block's per-entry ladder rows in-kernel, and reduces to the (1, bb) scores
— the (nq, b, hmax, k) gather tensor never materializes.

The in-kernel gather is a one-hot matmul streamed over vocabulary chunks:
for a chunk of ``block_v`` table rows, the (bb*hmax, block_v) one-hot of
the candidate entry ids against the chunk's row range is contracted with
the chunk on the MXU — the TPU idiom for an arbitrary-index gather (Mosaic
has no general dynamic-gather op). Every entry id hits exactly one chunk,
so accumulation across chunks adds exact zeros and the gathered ladder is
BITWISE the XLA gather's result.

The reductions reuse the reference engines' own formulas (``lc.pour``,
``lc.ict_pour``, the Algorithm-1/masked-min expressions) on identically
shaped tiles. The conformance contract (``tests/test_cand_kernels.py``):
the gather is bitwise-exact, scores match the reference candidate engines
to within a few ulps, and admissible cascades keep their exact-top-l
guarantee under the kernel path. The residual ulps are not the kernels':
XLA re-fuses the REFERENCE path's reduction per surrounding program
(FMA contraction), so even two pure-jnp programs of the same formula can
disagree by an ulp on CPU — the kernel body, compiled as an isolated
computation inside the grid loop, is the more stable of the two.

Covers every candidate reduction in the registry:
  mode "pour"    — LC-ACT Phase 2/3 (iters >= 1) and the LC-RWMD
                   masked-min dump (iters == 0), via ``lc.pour``.
  mode "omr"     — LC-OMR Algorithm-1 top-2 reduction.
  mode "rev_min" — reverse-RWMD masked (min,+) over the distance handoff.
  mode "ict"     — LC-ICT full-ladder pour (``lc.ict_pour``; the
                   remainder dump stays max-FINITE — see that docstring).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.lc import ict_pour, pour
from repro.core.precision import pad_dist_for

#: Modes whose ladder table stacks Z|W columns (Phase-1 ranked handoff).
POUR_MODES = ("pour", "omr")
#: Modes that consume the (v, h) distance handoff plus the query weights.
DIST_MODES = ("rev_min", "ict")


def _gather_rows(flat_ids, table, block_v: int):
    """In-kernel gather ``table[flat_ids]`` via chunked one-hot matmuls.

    flat_ids: (r,) int32 row ids into ``table`` (vp, width); vp is a
    ``block_v`` multiple (ops pads). Returns (r, width) float32, bitwise
    equal to an XLA gather: each id matches exactly one chunk, the one-hot
    contraction is 1.0 * row + exact zeros (table values are finite —
    padding costs are the finite ``lc.PAD_DIST``, never inf, so the
    0 * value products cannot produce NaN).
    """
    vp, width = table.shape
    r = flat_ids.shape[0]

    def chunk(u, acc):
        blk = jax.lax.dynamic_slice_in_dim(table, u * block_v, block_v, 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (r, block_v), 1)
        # One-hot in the TABLE's dtype (0/1 are exact in any float dtype)
        # so a bf16 storage table contracts without an f32 upcast copy;
        # the MXU still accumulates into float32.
        onehot = (flat_ids[:, None] - u * block_v == col).astype(blk.dtype)
        return acc + jax.lax.dot_general(
            onehot, blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    return jax.lax.fori_loop(0, vp // block_v, chunk,
                             jnp.zeros((r, width), jnp.float32))


def _cand_pour_kernel(idsg_ref, xg_ref, table_ref, t_ref, *, k: int,
                      iters: int, mode: str, block_v: int):
    """Grid = (nq, cand_blocks). One cell: gather this candidate block's
    (bb, hmax, k [+iters]) ladder rows from the query's VMEM-resident
    table, then run the reference reduction."""
    ids = idsg_ref[0]                                    # (bb, hmax) int32
    bb, hmax = ids.shape
    g = _gather_rows(ids.reshape(-1), table_ref[0], block_v)
    zg = g[:, :k].reshape(bb, hmax, k)
    x = xg_ref[0].astype(jnp.float32)
    if mode == "pour":
        wg = (g[:, k:].reshape(bb, hmax, iters) if iters
              else zg[..., :0])                          # unused at iters=0
        t = pour(x, zg, wg, iters)
    else:                                                # "omr": k == 2
        w0 = g[:, k].reshape(bb, hmax)
        overlap = zg[..., 0] == 0.0
        rest = x - jnp.minimum(x, w0)
        per_entry = jnp.where(overlap, rest * zg[..., 1], x * zg[..., 0])
        t = jnp.sum(per_entry, axis=-1)
    t_ref[...] = t[None]


def _cand_dist_kernel(idsg_ref, xg_ref, dq_ref, qw_ref, t_ref, acc_ref, *,
                      mode: str):
    """Grid = (nq, cand_blocks, vocab_blocks). The vocabulary axis is the
    INNERMOST (fastest) grid dimension: each step sees one (block_v, h)
    slab of the query's distance handoff, accumulates its one-hot-matmul
    gather contribution into the persistent VMEM scratch ``acc_ref``, and
    on the last slab reduces the completed (bb, hmax, h) cost tensor:
    masked (min,+) . q_w ("rev_min") or the full sorted ladder ("ict").

    Streaming keeps the per-launch dq residency at one ``block_v`` slab
    instead of the full (vp, h) table, so paper-scale handoffs (20News:
    vp ~ 70k, h = 500) fit the 16 MiB double-buffered VMEM budget. Each
    entry id matches exactly one slab, so the running sum adds exact
    zeros elsewhere and the gathered ladder stays BITWISE the XLA
    gather's result (values are non-negative; +0 init is exact)."""
    ids = idsg_ref[0]                                    # (bb, hmax)
    bb, hmax = ids.shape
    u = pl.program_id(2)
    blk = dq_ref[0]                                      # (block_v, h)
    block_v = blk.shape[0]
    r = bb * hmax
    col = jax.lax.broadcasted_iota(jnp.int32, (r, block_v), 1)
    # One-hot in the slab's dtype (see _gather_rows); f32 accumulation.
    onehot = (ids.reshape(-1)[:, None] - u * block_v == col
              ).astype(blk.dtype)
    contrib = jax.lax.dot_general(onehot, blk, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    @pl.when(u == 0)
    def _init():
        acc_ref[...] = contrib

    @pl.when(u > 0)
    def _accumulate():
        acc_ref[...] = acc_ref[...] + contrib

    @pl.when(u == pl.num_programs(2) - 1)
    def _reduce():
        qw = qw_ref[0].astype(jnp.float32)               # (h,)
        C = acc_ref[...].reshape(bb, hmax, qw.shape[0])
        x = xg_ref[0].astype(jnp.float32)
        if mode == "rev_min":
            # C is the f32 gather accumulator; reduced-precision dq
            # sentinels upcast to >= the f32 pad, so masking here in the
            # accumulator dtype keeps every sentinel comparison strict.
            big = jnp.asarray(pad_dist_for(C.dtype), C.dtype)
            Dg = jnp.where((x > 0.0)[..., None], C, big)
            cmin = jnp.min(Dg, axis=1)                   # (bb, h)
            # multiply + reduce, matching lc.rev_min_cand_blocked
            # bit-for-bit (a dot op's accumulation varies with the
            # tile's row count)
            t = jnp.sum(cmin * qw[None, :], axis=-1)
        else:                                            # "ict"
            cap = jnp.broadcast_to(qw[None, None, :], C.shape)
            t = ict_pour(x, cap, C)
        t_ref[...] = t[None]


def _check_cand(idsg, xg, block_n: int):
    nq, b, hmax = idsg.shape
    assert xg.shape == (nq, b, hmax), (xg.shape, idsg.shape)
    assert b % block_n == 0, (b, block_n)
    return nq, b, hmax


@functools.partial(jax.jit, static_argnames=("k", "iters", "mode",
                                             "block_n", "block_v",
                                             "interpret"))
def cand_pour_pallas(idsg: jax.Array, xg: jax.Array, table: jax.Array, *,
                     k: int, iters: int, mode: str = "pour",
                     block_n: int = 128, block_v: int = 256,
                     interpret: bool = False) -> jax.Array:
    """Fused candidate gather + pour/OMR reduction over a query batch.

    Args:
      idsg:  (nq, b, hmax) int32 vocabulary ids of each query's candidate
             rows (``corpus.ids[cand]``; padding slots/rows carry id 0
             and weight 0, contributing exactly 0 cost).
      xg:    (nq, b, hmax) residual weights (``corpus.w[cand]``).
      table: (nq, vp, k [+ iters]) per-query Phase-1 ladder, Z columns
             first then W ("pour" with iters >= 1) or W0 ("omr").
    Returns t: (nq, b) scores at the candidate rows.
    Caller guarantees b % block_n == 0 and vp % block_v == 0 (see ops.py).
    """
    assert mode in POUR_MODES, mode
    nq, b, hmax = _check_cand(idsg, xg, block_n)
    vp, width = table.shape[1], table.shape[2]
    assert vp % block_v == 0 and width == k + (1 if mode == "omr" else iters)
    kernel = functools.partial(_cand_pour_kernel, k=k, iters=iters,
                               mode=mode, block_v=block_v)
    return pl.pallas_call(
        kernel,
        grid=(nq, b // block_n),
        in_specs=[
            pl.BlockSpec((1, block_n, hmax), lambda q, i: (q, i, 0)),
            pl.BlockSpec((1, block_n, hmax), lambda q, i: (q, i, 0)),
            pl.BlockSpec((1, vp, width), lambda q, i: (q, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda q, i: (q, i)),
        out_shape=jax.ShapeDtypeStruct((nq, b), jnp.float32),
        interpret=interpret,
    )(idsg, xg, table)


@functools.partial(jax.jit, static_argnames=("mode", "block_n", "block_v",
                                             "interpret"))
def cand_dist_pallas(idsg: jax.Array, xg: jax.Array, dq: jax.Array,
                     qw: jax.Array, *, mode: str = "rev_min",
                     block_n: int = 128, block_v: int = 256,
                     interpret: bool = False) -> jax.Array:
    """Fused candidate gather + distance-handoff reduction (rev_min/ict).

    Args:
      idsg: (nq, b, hmax) int32 candidate-row vocabulary ids.
      xg:   (nq, b, hmax) residual weights (0 marks padding slots, which
            "rev_min" masks to the finite ``lc.PAD_DIST``).
      dq:   (nq, vp, h) query-major Phase-1 distance handoff (padded query
            bins already carry ``lc.PAD_DIST``).
      qw:   (nq, h) query weights (0 at padded bins).
    Returns t: (nq, b) scores at the candidate rows.
    Caller guarantees b % block_n == 0 and vp % block_v == 0 (see ops.py).

    Unlike ``cand_pour_pallas`` (whose narrow Z|W table fits VMEM whole),
    the (vp, h) distance handoff is streamed: the grid carries a third,
    innermost vocabulary axis delivering one (block_v, h) slab per step,
    with the gather accumulated in a VMEM scratch and the reduction run
    once on the final slab. The output block's index map ignores the
    vocab axis, so the (1, block_n) tile is written exactly once — on the
    last slab, just before the candidate index advances.
    """
    assert mode in DIST_MODES, mode
    nq, b, hmax = _check_cand(idsg, xg, block_n)
    vp, h = dq.shape[1], dq.shape[2]
    assert vp % block_v == 0 and qw.shape == (nq, h), (dq.shape, qw.shape)
    kernel = functools.partial(_cand_dist_kernel, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=(nq, b // block_n, vp // block_v),
        in_specs=[
            pl.BlockSpec((1, block_n, hmax), lambda q, i, u: (q, i, 0)),
            pl.BlockSpec((1, block_n, hmax), lambda q, i, u: (q, i, 0)),
            pl.BlockSpec((1, block_v, h), lambda q, i, u: (q, u, 0)),
            pl.BlockSpec((1, h), lambda q, i, u: (q, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda q, i, u: (q, i)),
        out_shape=jax.ShapeDtypeStruct((nq, b), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_n * hmax, h), jnp.float32)],
        interpret=interpret,
    )(idsg, xg, dq, qw)
