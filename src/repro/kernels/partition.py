"""shard_map partitioning shims: the fused Pallas kernels on the mesh.

Compiled (non-interpret) ``pallas_call`` has no SPMD partitioning rule,
so before these shims the distributed backend could only run the kernels
in interpret mode (where they lower to plain HLO and shard like any jnp
op) — ``EmdIndex`` kept ``use_kernels`` off on the mesh entirely. Each
shim here wraps one kernel wrapper from :mod:`repro.kernels.ops` in an
explicit ``shard_map``: the partitioning is stated once, per kernel, as
(in_specs, out_specs), and the body runs the unmodified single-device
wrapper on its shard — compiled on a real TPU mesh, interpreted on the
host-mesh CI conformance oracle, identical program structure either way.

The mesh is threaded EXPLICITLY (a hashable static argument on every
engine down from ``launch/search.py``), never read from ambient context:
the lc engines are inner ``jax.jit``s, and a context read at trace time
would not participate in their cache keys — two meshes would silently
share one trace.

Partitioning per kernel family:

* ``dist_topk`` (Phase 1) — queries over DP, vocabulary rows over
  "model". Each (vocab-shard, query-shard) cell computes its own
  distance tile and per-row top-k; the selection indexes the query's
  histogram slots (h, unsharded), so the per-row result never crosses
  shards. The W capacity gather runs inside the shard (``Q_w`` is
  DP-local, S indexes h). Downstream, the caller re-pins the (nq, v, k)
  ladders to the ``annotate.emd_ladder`` layout — the same replication
  all-gather the jnp pipeline performs.
* ``act_phase2`` (Phase 2/3) — database rows over "model", queries over
  DP. The body gathers its row shard's (bq, n/shard, hmax, k) ladders
  and pours; the per-shard query blocking (``lc._map_query_blocks``)
  runs INSIDE the shard, so the ``lax.map`` iterates a shard-LOCAL query
  axis — XLA's SPMD partitioner cannot iterate a scan over a DP-sharded
  axis, which is why the distributed query blocking lives here and not
  above the shard_map.
* candidate kernels (``cand_pour``/``cand_omr``/``cand_rev_min``/
  ``cand_ict``) — queries over DP only. The candidate sub-corpus gather
  (``corpus.ids[cand]``) stays OUTSIDE the shard_map on purpose: inside,
  the model-sharded corpus rows would have to replicate (an O(n * hmax)
  all-gather — exactly what the static collective checker's corpus-
  scaling guard forbids), while outside, XLA's partitioned gather moves
  only the (nq, b, hmax) candidate rows. The model axis is unmentioned
  in the specs: inputs are replicated over it and every model shard
  computes the same (nq/dp, b) block (``check_rep=False`` skips the
  replication proof current shard_map cannot do for these bodies).

Every shim has a divisibility precondition (``queries_shardable`` and
friends); callers fall back to the non-shard_map kernel path when a dim
does not split — still correct everywhere interpret mode runs.
"""
from __future__ import annotations

import functools
import math

import jax
from jax.sharding import PartitionSpec as P

from repro.core import lc
from repro.kernels import ops as kops
from repro.launch.mesh import data_axes, model_axis_size

if hasattr(jax, "shard_map"):                            # jax >= 0.6
    _shard_map = functools.partial(jax.shard_map, check_vma=False)
else:
    from jax.experimental.shard_map import shard_map as _sm
    _shard_map = functools.partial(_sm, check_rep=False)


def _dp(mesh):
    """The mesh's DP axes as one PartitionSpec entry."""
    axes = data_axes(mesh)
    return axes if len(axes) > 1 else axes[0]


def _dp_size(mesh) -> int:
    return math.prod(mesh.shape[a] for a in data_axes(mesh))


def queries_shardable(mesh, nq: int) -> bool:
    """True when the query batch splits evenly over the mesh's DP axes —
    the precondition of every shim here."""
    return nq % _dp_size(mesh) == 0


def phase1_shardable(mesh, nq: int, v: int) -> bool:
    """Precondition of :func:`dist_topk_sharded`: queries split over DP
    and vocabulary rows over "model"."""
    return queries_shardable(mesh, nq) and v % model_axis_size(mesh) == 0


def rows_shardable(mesh, nq: int, n: int) -> bool:
    """Precondition of :func:`act_pour_sharded`: queries split over DP
    and database rows over "model"."""
    return queries_shardable(mesh, nq) and n % model_axis_size(mesh) == 0


def dist_topk_sharded(mesh, coords, qcs, Q_w, k: int, *,
                      block_v: int = 256, block_h: int = 256,
                      out_dtype: str = "float32"):
    """Phase-1 kernel on the mesh: coords (v, m) sharded over "model",
    qcs (nq, h, m) / Q_w (nq, h) over DP -> Z, W each (nq, v, k) on the
    (DP, "model") grid, in ``out_dtype`` (a precision policy's storage
    role — this is the handoff whose replication all-gather the policy
    halves). Caller re-pins to the emd_ladder layout."""
    def body(coords_l, qcs_l, qw_l):
        Z, S = kops.dist_topk_batched(coords_l, qcs_l, k,
                                      qmask=(qw_l > 0.0), block_v=block_v,
                                      block_h=block_h, out_dtype=out_dtype)
        W = jax.vmap(lambda w, s: w[s])(qw_l, S).astype(out_dtype)
        return Z, W

    dp = _dp(mesh)
    return _shard_map(
        body, mesh=mesh,
        in_specs=(P("model", None), P(dp, None, None), P(dp, None)),
        out_specs=(P(dp, "model", None), P(dp, "model", None)),
    )(coords, qcs, Q_w)


def act_pour_sharded(mesh, ids, w, Z, W, iters: int, *, block_q: int = 8,
                     block_n: int = 256, block_h: int = 256):
    """Phase-2/3 kernel on the mesh: corpus ids/w (n, hmax) sharded over
    "model", handoff ladders Z (nq, v, iters+1) / W (nq, v, iters) over
    DP (replicated over "model" — the emd_ladder layout) -> (nq, n)
    scores on the (DP, "model") grid. ``iters >= 1`` (the zero-round dump
    has no kernel form). Query blocking runs per shard.

    Reduced-precision ladders (a policy's bf16 storage) cross the
    shard_map boundary BITCAST to a same-width unsigned integer and come
    back to their float dtype inside the shard: the in_specs replication
    all-gather otherwise runs on a float value XLA rewrites to f32 width
    (see ``annotate.emd_ladder``), doubling the handoff wire bytes the
    policy exists to halve."""
    assert iters >= 1, iters
    zdt, wdt = Z.dtype, W.dtype

    def _fence(a):
        if a.dtype == jax.numpy.float32:
            return a
        return jax.lax.bitcast_convert_type(
            a, jax.numpy.dtype(f"uint{a.dtype.itemsize * 8}"))

    def body(ids_l, w_l, Z_l, W_l):
        Z_l = (Z_l if Z_l.dtype == zdt
               else jax.lax.bitcast_convert_type(Z_l, zdt))
        W_l = (W_l if W_l.dtype == wdt
               else jax.lax.bitcast_convert_type(W_l, wdt))

        def blk(Zb, Wb):
            Zg = Zb[:, ids_l]                            # (bq, n/sh, hmax, k)
            Wg = Wb[:, ids_l]
            return kops.act_phase2_batched(w_l, Zg, Wg, block_n=block_n,
                                           block_h=block_h)
        return lc._map_query_blocks(blk, (Z_l, W_l), Z_l.shape[0], block_q)

    dp = _dp(mesh)
    return _shard_map(
        body, mesh=mesh,
        in_specs=(P("model", None), P("model", None),
                  P(dp, None, None), P(dp, None, None)),
        out_specs=P(dp, "model"),
    )(ids, w, _fence(Z), _fence(W))


def cand_sharded(mesh, fn, arrays, block_q: int = 8):
    """Candidate kernel on the mesh: every array in ``arrays`` leads with
    the query axis and shards over DP (trailing dims replicated); ``fn``
    maps the per-block slices to (bq, b) scores and runs inside the shard
    under per-shard query blocking. The candidate gather must already
    have happened OUTSIDE (see the module docstring)."""
    def body(*local):
        return lc._map_query_blocks(fn, local, local[0].shape[0], block_q)

    dp = _dp(mesh)
    in_specs = tuple(P(dp, *([None] * (a.ndim - 1))) for a in arrays)
    return _shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=P(dp, None))(*arrays)
