"""Pallas TPU kernel: fused ACT Phase-2/3 constrained pour.

The paper's GPU implementation performs k-1 separate passes over the
residual database matrix (eqs. 6-8), re-reading X from HBM every round. On
TPU we stream each (bn, bh) block of the database once, run the whole
k-round water-filling pour in VMEM/VREGs (the per-entry capacity ladder
Wg and cost ladder Zg ride along in the same block), and reduce to the
per-row transport cost in a single pass: HBM traffic / k vs the paper.

The pour itself uses the exclusive-prefix formulation (mathematically equal
to the sequential min/subtract rounds): r_l = clip(x - sum_{u<l} W_u, 0, W_l).
k is static and small, so the l-loop is unrolled Python.

The grid carries a query-batch dimension as its outermost (parallel) axis:
the residual-weight blocks of x are shared across queries while each query
streams its own (cost, capacity) ladders, so a batch of queries pours in
one kernel launch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pour_entry_costs(x, zg, wg, iters: int):
    """Per-entry poured cost of the k-round water-filling ladder — the
    pour machinery shared by the shared-x batched kernel and the
    candidate-grid (per-query x) extension below. x (bn, bh);
    zg (bn, bh, iters+1); wg (bn, bh, iters) -> (bn, bh)."""
    acc = jnp.zeros_like(x)
    prefix = jnp.zeros_like(x)
    poured = jnp.zeros_like(x)
    for l in range(iters):
        w_l = wg[..., l].astype(jnp.float32)                 # (bn, bh)
        z_l = zg[..., l].astype(jnp.float32)
        r = jnp.clip(x - prefix, 0.0, w_l)
        acc = acc + r * z_l
        poured = poured + r
        prefix = prefix + w_l
    remainder = jnp.maximum(x - poured, 0.0)
    return acc + remainder * zg[..., iters].astype(jnp.float32)


def _act_phase2_kernel(x_ref, zg_ref, wg_ref, t_ref, *, iters: int):
    """Grid = (nq, n_blocks, h_blocks); the query batch is the outermost
    (parallel) axis and h blocks accumulate into t. The x block is shared
    across queries (2-D block) on the full-corpus grid, or per-query
    (3-D block, leading 1) on the candidate grid — each query of a
    cascade scores its OWN (b, hmax) surviving sub-corpus."""
    j = pl.program_id(2)

    x = x_ref[...]
    if x.ndim == 3:                                          # candidate grid
        x = x[0]
    x = x.astype(jnp.float32)                                # (bn, bh)
    acc = pour_entry_costs(x, zg_ref[0], wg_ref[0], iters)
    partial = jnp.sum(acc, axis=1, keepdims=True)[None]      # (1, bn, 1)

    @pl.when(j == 0)
    def _init():
        t_ref[...] = partial

    @pl.when(j > 0)
    def _accum():
        t_ref[...] = t_ref[...] + partial


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_h", "interpret"))
def act_phase2_pallas(x: jax.Array, zg: jax.Array, wg: jax.Array, *,
                      block_n: int = 256, block_h: int = 256,
                      interpret: bool = False) -> jax.Array:
    """Fused Phase-2 pour + Phase-3 dump over a query batch.

    Args:
      x:  (n, hmax) residual database weights, shared by all queries
          (padding slots are 0).
      zg: (nq, n, hmax, iters+1) per-query ascending transport-cost ladder.
      wg: (nq, n, hmax, iters) per-query capacity ladder (query weights).
    Returns t: (nq, n, 1) transport-cost lower bounds.
    Caller guarantees n % block_n == 0 and hmax % block_h == 0 (see ops.py).
    """
    n, hmax = x.shape
    nq, iters = wg.shape[0], wg.shape[-1]
    assert zg.shape == (nq, n, hmax, iters + 1), (zg.shape, x.shape, iters)
    assert n % block_n == 0 and hmax % block_h == 0, (n, hmax, block_n, block_h)
    grid = (nq, n // block_n, hmax // block_h)
    kernel = functools.partial(_act_phase2_kernel, iters=iters)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_h), lambda q, i, j: (i, j)),
            pl.BlockSpec((1, block_n, block_h, iters + 1),
                         lambda q, i, j: (q, i, j, 0)),
            pl.BlockSpec((1, block_n, block_h, iters),
                         lambda q, i, j: (q, i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n, 1), lambda q, i, j: (q, i, 0)),
        out_shape=jax.ShapeDtypeStruct((nq, n, 1), jnp.float32),
        interpret=interpret,
    )(x, zg, wg)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_h", "interpret"))
def act_phase2_cand_pallas(xg: jax.Array, zg: jax.Array, wg: jax.Array, *,
                           block_n: int = 256, block_h: int = 256,
                           interpret: bool = False) -> jax.Array:
    """Candidate-grid extension of :func:`act_phase2_pallas`: the database
    axis is each query's OWN candidate block, so the residual weights are
    per-query too (a cascade's stage-s+1 sub-corpus differs per query).

    Args:
      xg: (nq, b, hmax) per-query candidate residual weights.
      zg: (nq, b, hmax, iters+1) / wg: (nq, b, hmax, iters) pre-gathered
          per-candidate ladders.
    Returns t: (nq, b, 1) transport-cost lower bounds.

    This is the unfused half of the candidate pour — callers that already
    hold gathered ladders (or back-ends without the in-kernel one-hot
    gather of ``cand_pour``) tile the same pour over (query, candidate)
    blocks. The fused ``cand_pour`` kernel subsumes gather + pour in one
    launch and is what the ``lc`` candidate engines route to.
    Caller guarantees b % block_n == 0 and hmax % block_h == 0 (ops.py).
    """
    nq, b, hmax = xg.shape
    iters = wg.shape[-1]
    assert zg.shape == (nq, b, hmax, iters + 1), (zg.shape, xg.shape)
    assert b % block_n == 0 and hmax % block_h == 0, (b, hmax, block_n,
                                                      block_h)
    grid = (nq, b // block_n, hmax // block_h)
    kernel = functools.partial(_act_phase2_kernel, iters=iters)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n, block_h), lambda q, i, j: (q, i, j)),
            pl.BlockSpec((1, block_n, block_h, iters + 1),
                         lambda q, i, j: (q, i, j, 0)),
            pl.BlockSpec((1, block_n, block_h, iters),
                         lambda q, i, j: (q, i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n, 1), lambda q, i, j: (q, i, 0)),
        out_shape=jax.ShapeDtypeStruct((nq, b, 1), jnp.float32),
        interpret=interpret,
    )(xg, zg, wg)
