"""Synthetic dataset generators mirroring the paper's two evaluation domains.

The container is offline, so we synthesize datasets with the same structure
as the paper's:

* ``make_text_like`` — 20-Newsgroups-like: sparse histograms over a large
  vocabulary embedded in R^m (word2vec-like, L2-normalized), with
  class-conditional topic structure so nearest-neighbor precision is a
  meaningful signal.
* ``make_image_like`` — MNIST-like: dense 2-D pixel histograms, class =
  digit-like blob pattern; optional background floor to reproduce the
  RWMD collapse of Table 6.
"""
from __future__ import annotations

import numpy as np

from repro.core.histogram import docs_to_corpus, images_to_corpus
from repro.core.lc import Corpus


def make_text_like(n_docs: int = 64, n_classes: int = 4, vocab: int = 512,
                   m: int = 32, doc_len: int = 60, hmax: int = 32,
                   seed: int = 0) -> tuple[Corpus, np.ndarray]:
    """Class-conditional sparse documents over an embedded vocabulary."""
    rng = np.random.default_rng(seed)
    coords = rng.normal(size=(vocab, m))
    coords /= np.linalg.norm(coords, axis=1, keepdims=True)  # word2vec-style L2
    # Each class owns a topic: a distribution concentrated on a coherent
    # region of the embedding space (words near a class anchor).
    anchors = rng.normal(size=(n_classes, m))
    anchors /= np.linalg.norm(anchors, axis=1, keepdims=True)
    sim = coords @ anchors.T                                  # (vocab, n_classes)
    topic_logits = 6.0 * sim
    topic_probs = np.exp(topic_logits - topic_logits.max(axis=0))
    topic_probs /= topic_probs.sum(axis=0)
    labels = rng.integers(0, n_classes, size=n_docs)
    docs = []
    for u in range(n_docs):
        mix = 0.85 * topic_probs[:, labels[u]] + 0.15 / vocab
        mix /= mix.sum()
        docs.append(rng.choice(vocab, size=doc_len, p=mix))
    corpus = docs_to_corpus(docs, coords.astype(np.float32), hmax)
    return corpus, labels


def make_clustered_text(n_docs: int, n_topics: int = 64, vocab: int = 2048,
                        m: int = 16, hmax: int = 32, zipf_a: float = 1.3,
                        min_len: int = 4, seed: int = 0,
                        shard_docs: int = 16384) -> tuple[Corpus, np.ndarray]:
    """Large-corpus generator: mixture-of-topics documents with zipfian
    lengths, built in memory-bounded shards so ``n_docs`` can reach 1M+.

    Unlike :func:`make_text_like` (a per-document Python loop with an
    explicit multinomial draw — fine at thousands of rows, hours at a
    million), each shard here is fully vectorized: a document's ``hmax``
    candidate words are the top-``hmax`` of Gumbel-perturbed topic
    log-probabilities (the Gumbel-top-k trick — equivalent to sampling
    ``hmax`` DISTINCT words ``p``-proportionally), its length is a
    clipped Zipf draw (many short docs, a heavy tail), and its weights
    are normalized exponentials over the first ``length`` slots. Peak
    extra memory is O(``shard_docs`` x ``vocab``) regardless of
    ``n_docs``, and rows land directly in the preallocated dense-bucket
    arrays — no intermediate doc list.

    Topic structure matches the paper's text workloads: ``n_topics``
    anchors in the embedding space, softmax word affinities, one topic
    per document (the returned labels) — which is exactly the clustered
    geometry that gives IVF/tree candidate sources something to index.
    """
    if n_docs < 1 or not 1 <= min_len <= hmax:
        raise ValueError(f"need n_docs >= 1 and 1 <= min_len <= hmax, got "
                         f"{n_docs}/{min_len}/{hmax}")
    rng = np.random.default_rng(seed)
    coords = rng.normal(size=(vocab, m)).astype(np.float32)
    coords /= np.linalg.norm(coords, axis=1, keepdims=True)
    anchors = rng.normal(size=(n_topics, m))
    anchors /= np.linalg.norm(anchors, axis=1, keepdims=True)
    logits = 6.0 * (coords @ anchors.T)                # (vocab, n_topics)
    logp = logits - logits.max(axis=0)
    logp = (logp - np.log(np.exp(logp).sum(axis=0))).T  # (n_topics, vocab)
    labels = rng.integers(0, n_topics, size=n_docs)
    ids = np.zeros((n_docs, hmax), np.int32)
    w = np.zeros((n_docs, hmax), np.float32)
    for s in range(0, n_docs, shard_docs):
        e = min(s + shard_docs, n_docs)
        k = e - s
        gumbel = rng.gumbel(size=(k, vocab))
        scores = logp[labels[s:e]] + gumbel
        # top-hmax by perturbed score = hmax distinct p-weighted words;
        # descending-score order so truncating to a doc's length keeps a
        # correctly-distributed size-``length`` Gumbel-top-k sample.
        top = np.argpartition(scores, vocab - hmax,
                              axis=1)[:, vocab - hmax:]
        order = np.argsort(-np.take_along_axis(scores, top, axis=1),
                           axis=1)
        top = np.take_along_axis(top, order, axis=1)
        lens = np.clip(rng.zipf(zipf_a, size=k), min_len, hmax)
        slot = np.arange(hmax)[None, :]
        live = slot < lens[:, None]
        wt = rng.exponential(size=(k, hmax)).astype(np.float32) * live
        wt /= wt.sum(axis=1, keepdims=True)
        ids[s:e] = np.where(live, top, 0)
        w[s:e] = wt
    return Corpus(ids=ids, w=w, coords=coords), labels


def make_image_like(n_images: int = 64, n_classes: int = 4, side: int = 12,
                    include_background: bool = False,
                    seed: int = 0) -> tuple[Corpus, np.ndarray]:
    """Digit-like greyscale blobs: each class is a fixed stroke pattern with
    per-sample jitter, rendered on a side x side grid."""
    rng = np.random.default_rng(seed)
    # Class prototypes: 3 gaussian strokes per class at fixed positions.
    protos = rng.uniform(1.5, side - 2.5, size=(n_classes, 3, 2))
    yy, xx = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    grid = np.stack([yy, xx], axis=-1).astype(np.float64)    # (side, side, 2)
    labels = rng.integers(0, n_classes, size=n_images)
    images = np.zeros((n_images, side, side))
    for u in range(n_images):
        centers = protos[labels[u]] + rng.normal(scale=0.6, size=(3, 2))
        for c in centers:
            d2 = np.sum((grid - c) ** 2, axis=-1)
            images[u] += np.exp(-d2 / 2.0)
        images[u] *= images[u] > 0.05 * images[u].max()      # sparsify
    corpus = images_to_corpus(images, include_background=include_background)
    return corpus, labels
