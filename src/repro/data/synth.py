"""Synthetic dataset generators mirroring the paper's two evaluation domains.

The container is offline, so we synthesize datasets with the same structure
as the paper's:

* ``make_text_like`` — 20-Newsgroups-like: sparse histograms over a large
  vocabulary embedded in R^m (word2vec-like, L2-normalized), with
  class-conditional topic structure so nearest-neighbor precision is a
  meaningful signal.
* ``make_image_like`` — MNIST-like: dense 2-D pixel histograms, class =
  digit-like blob pattern; optional background floor to reproduce the
  RWMD collapse of Table 6.
"""
from __future__ import annotations

import numpy as np

from repro.core.histogram import docs_to_corpus, images_to_corpus
from repro.core.lc import Corpus


def make_text_like(n_docs: int = 64, n_classes: int = 4, vocab: int = 512,
                   m: int = 32, doc_len: int = 60, hmax: int = 32,
                   seed: int = 0) -> tuple[Corpus, np.ndarray]:
    """Class-conditional sparse documents over an embedded vocabulary."""
    rng = np.random.default_rng(seed)
    coords = rng.normal(size=(vocab, m))
    coords /= np.linalg.norm(coords, axis=1, keepdims=True)  # word2vec-style L2
    # Each class owns a topic: a distribution concentrated on a coherent
    # region of the embedding space (words near a class anchor).
    anchors = rng.normal(size=(n_classes, m))
    anchors /= np.linalg.norm(anchors, axis=1, keepdims=True)
    sim = coords @ anchors.T                                  # (vocab, n_classes)
    topic_logits = 6.0 * sim
    topic_probs = np.exp(topic_logits - topic_logits.max(axis=0))
    topic_probs /= topic_probs.sum(axis=0)
    labels = rng.integers(0, n_classes, size=n_docs)
    docs = []
    for u in range(n_docs):
        mix = 0.85 * topic_probs[:, labels[u]] + 0.15 / vocab
        mix /= mix.sum()
        docs.append(rng.choice(vocab, size=doc_len, p=mix))
    corpus = docs_to_corpus(docs, coords.astype(np.float32), hmax)
    return corpus, labels


def make_image_like(n_images: int = 64, n_classes: int = 4, side: int = 12,
                    include_background: bool = False,
                    seed: int = 0) -> tuple[Corpus, np.ndarray]:
    """Digit-like greyscale blobs: each class is a fixed stroke pattern with
    per-sample jitter, rendered on a side x side grid."""
    rng = np.random.default_rng(seed)
    # Class prototypes: 3 gaussian strokes per class at fixed positions.
    protos = rng.uniform(1.5, side - 2.5, size=(n_classes, 3, 2))
    yy, xx = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    grid = np.stack([yy, xx], axis=-1).astype(np.float64)    # (side, side, 2)
    labels = rng.integers(0, n_classes, size=n_images)
    images = np.zeros((n_images, side, side))
    for u in range(n_images):
        centers = protos[labels[u]] + rng.normal(scale=0.6, size=(3, 2))
        for c in centers:
            d2 = np.sum((grid - c) ** 2, axis=-1)
            images[u] += np.exp(-d2 / 2.0)
        images[u] *= images[u] > 0.05 * images[u].max()      # sparsify
    corpus = images_to_corpus(images, include_background=include_background)
    return corpus, labels
