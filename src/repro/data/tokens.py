"""Deterministic sharded synthetic token pipeline.

Every (step, shard) microbatch is a pure function of (seed, step, shard) —
stateless, so ANY replica can recompute ANY microbatch. This is the property
the straggler-mitigation and elastic-rescale paths rely on (runtime/fault.py):
no data-loader state needs to move when work is re-assigned.

The stream is a Zipf-ish unigram mix with short-range repetition structure so
the training loss has signal (a pure-uniform stream has no learnable
structure and makes convergence tests vacuous).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1


def _rng_for(cfg: DataConfig, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard]))


def shard_batch(cfg: DataConfig, step: int, shard: int) -> dict:
    """One shard's slice of the global batch at ``step``: tokens + labels."""
    assert cfg.global_batch % cfg.n_shards == 0
    b = cfg.global_batch // cfg.n_shards
    rng = _rng_for(cfg, step, shard)
    # Zipf unigram distribution over the vocab.
    ranks = np.arange(1, cfg.vocab + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(cfg.vocab, size=(b, cfg.seq_len + 1), p=probs)
    # Inject copy structure: with p=0.5 each position repeats t-2's token.
    rep = rng.uniform(size=(b, cfg.seq_len + 1)) < 0.5
    toks[:, 2:] = np.where(rep[:, 2:], toks[:, :-2], toks[:, 2:])
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


def global_batch(cfg: DataConfig, step: int) -> dict:
    """Assembled global batch (host-side; drivers normally keep shards)."""
    shards = [shard_batch(cfg, step, s) for s in range(cfg.n_shards)]
    return {k: np.concatenate([s[k] for s in shards], axis=0)
            for k in shards[0]}
