"""Linear-complexity engines vs per-pair oracles + paper-table phenomena."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lc, retrieval
from repro.core.histogram import pair_from_corpus
from repro.core.relaxations import act_dir, omr_dir, rwmd_dir
from repro.data.synth import make_image_like, make_text_like


@pytest.fixture(scope="module")
def corpus():
    return make_text_like(n_docs=14, vocab=96, m=8, doc_len=30, hmax=16,
                          seed=3)


@pytest.mark.parametrize("iters", [0, 1, 2, 5])
@pytest.mark.parametrize("use_kernels", [False, True])
def test_lc_act_equals_pairwise(corpus, iters, use_kernels):
    c, _ = corpus
    t = lc.lc_act_scores(c, c.ids[0], c.w[0], iters=iters,
                         use_kernels=use_kernels)
    for u in range(c.n):
        x, q, C = pair_from_corpus(c, u, 0)
        ref = float(act_dir(x, q, C, iters=iters))
        assert abs(ref - float(t[u])) < 1e-5


def test_lc_omr_equals_pairwise(corpus):
    c, _ = corpus
    t = lc.lc_omr_scores(c, c.ids[1], c.w[1])
    for u in range(c.n):
        x, q, C = pair_from_corpus(c, u, 1)
        assert abs(float(omr_dir(x, q, C)) - float(t[u])) < 1e-5


def test_lc_rwmd_reverse_direction(corpus):
    c, _ = corpus
    t = lc.lc_rwmd_scores_rev(c, c.ids[2], c.w[2], block=4)
    for u in range(c.n):
        x, q, C = pair_from_corpus(c, u, 2)
        assert abs(float(rwmd_dir(q, x, C.T)) - float(t[u])) < 1e-5


def test_self_distance_zero(corpus):
    c, _ = corpus
    t = lc.lc_act_scores(c, c.ids[5], c.w[5], iters=3)
    assert float(t[5]) < 1e-6


def test_symmetric_scores_is_max():
    a = jnp.asarray([[0.0, 1.0], [2.0, 0.0]])
    s = lc.symmetric_scores(a)
    assert np.allclose(np.asarray(s), [[0, 2], [2, 0]])


def test_table6_dense_rwmd_collapse():
    """Paper Table 6: with background included, RWMD is ~0 for every pair
    (random neighbors) while OMR/ACT still rank correctly."""
    c, labels = make_image_like(n_images=24, include_background=True, seed=1)
    rw = lc.lc_rwmd_scores(c, c.ids[0], c.w[0])
    assert float(jnp.max(rw)) < 1e-6          # total collapse
    om = lc.lc_omr_scores(c, c.ids[0], c.w[0])
    assert float(jnp.max(om)) > 1e-3          # OMR still discriminates
    S_omr = retrieval.all_pairs_scores(c, method="omr")
    S_rw = retrieval.all_pairs_scores(c, method="rwmd")
    p_omr = retrieval.precision_at_l(S_omr, jnp.asarray(labels), 4)
    p_rw = retrieval.precision_at_l(S_rw, jnp.asarray(labels), 4)
    assert p_omr > p_rw + 0.2


def test_act_precision_at_least_rwmd_sparse():
    c, labels = make_text_like(n_docs=40, n_classes=5, vocab=256, m=12,
                               doc_len=30, hmax=24, seed=7)
    labels = jnp.asarray(labels)
    S_rw = retrieval.all_pairs_scores(c, method="rwmd")
    S_a = retrieval.all_pairs_scores(c, method="act", iters=3)
    assert (retrieval.precision_at_l(S_a, labels, 8)
            >= retrieval.precision_at_l(S_rw, labels, 8) - 0.02)


def test_search_top_l(corpus):
    c, _ = corpus
    scores, idx = retrieval.search(c, c.ids[3], c.w[3], top_l=5,
                                   method="act", iters=2)
    assert idx.shape == (5,)
    assert int(idx[0]) == 3                    # self is nearest
    assert float(scores[0]) < 1e-6
    assert np.all(np.diff(np.asarray(scores)) >= -1e-7)
