"""Checkpoint store: roundtrip, integrity, atomicity, resume."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store


@pytest.fixture
def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((2, 2), jnp.bfloat16),
                       "c": [jnp.zeros(3), jnp.asarray(5)]}}


def test_roundtrip(tmp_path, tree):
    d = str(tmp_path)
    store.save(d, 7, tree, extra={"loss": 1.5})
    assert store.latest_step(d) == 7
    out = store.restore(d, 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert store.restore_extra(d, 7)["loss"] == 1.5


import jax  # noqa: E402  (used in roundtrip comparison)


def test_corruption_detected(tmp_path, tree):
    d = str(tmp_path)
    path = store.save(d, 1, tree)
    victim = os.path.join(path, "a.npy")
    arr = np.load(victim)
    arr_flat = arr.ravel()
    arr_flat[0] += 1
    np.save(victim, arr)
    with pytest.raises(IOError, match="corruption"):
        store.restore(d, 1, tree)
    # verify=False permits (for forensics)
    store.restore(d, 1, tree, verify=False)


def test_latest_ignores_torn_tmp(tmp_path, tree):
    d = str(tmp_path)
    store.save(d, 3, tree)
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    os.makedirs(os.path.join(d, "step_00000010"))  # no manifest => torn
    assert store.latest_step(d) == 3


def test_save_overwrites_same_step(tmp_path, tree):
    d = str(tmp_path)
    store.save(d, 2, tree)
    tree2 = jax.tree.map(lambda a: a * 0 + 9, tree)
    store.save(d, 2, tree2)
    out = store.restore(d, 2, tree)
    assert float(np.asarray(jax.tree.leaves(out)[0]).ravel()[0]) == 9.0


def test_manifest_contents(tmp_path, tree):
    d = str(tmp_path)
    p = store.save(d, 4, tree)
    with open(os.path.join(p, "manifest.json")) as f:
        man = json.load(f)
    assert man["step"] == 4
    assert "a" in man["leaves"]
    assert man["leaves"]["a"]["shape"] == [3, 4]
    assert len(man["leaves"]["a"]["sha256"]) == 64


def test_corruption_is_typed_for_fallback(tmp_path, tree):
    """SHA mismatch surfaces as CheckpointCorrupt (a subclass of the
    IOError older callers catch) so recovery code can fall back to an
    older snapshot on type, not on string matching."""
    d = str(tmp_path)
    path = store.save(d, 1, tree)
    with open(os.path.join(path, "a.npy"), "r+b") as f:
        f.seek(8)
        f.write(b"\xff")
    with pytest.raises(store.CheckpointCorrupt):
        store.restore(d, 1, tree)
    assert issubclass(store.CheckpointCorrupt, IOError)


def test_latest_skips_partial_manifest(tmp_path, tree):
    """A manifest truncated mid-write (crash on a filesystem without
    atomic rename) is torn: skipped by steps()/latest_step, typed on
    direct load."""
    d = str(tmp_path)
    store.save(d, 3, tree)
    p = store.save(d, 5, tree)
    man = os.path.join(p, store.MANIFEST)
    with open(man) as f:
        content = f.read()
    with open(man, "w") as f:
        f.write(content[:len(content) // 2])       # torn mid-write
    assert store.steps(d) == [3]
    assert store.latest_step(d) == 3
    with pytest.raises(store.CheckpointCorrupt, match="partial"):
        store.load_manifest(d, 5)


def test_latest_skips_missing_leaf_file(tmp_path, tree):
    """Manifest intact but a leaf file missing (partially copied /
    crashed move): the completeness gate must refuse the step."""
    d = str(tmp_path)
    store.save(d, 2, tree)
    p = store.save(d, 4, tree)
    os.remove(os.path.join(p, "a.npy"))
    assert store.latest_step(d) == 2
    with pytest.raises(store.CheckpointCorrupt, match="unreadable"):
        store.restore(d, 4, tree)


def test_crash_mid_save_leaves_previous_snapshot_live(tmp_path, tree,
                                                      monkeypatch):
    """Simulated crash DURING save (before the atomic publish rename):
    the staging .tmp dir is left behind, latest_step still points at the
    previous complete checkpoint, and a retried save succeeds."""
    d = str(tmp_path)
    store.save(d, 1, tree)

    real_rename = os.rename

    def crash(src, dst):
        raise OSError("simulated crash before atomic publish")

    monkeypatch.setattr(store.os, "rename", crash)
    with pytest.raises(OSError, match="simulated crash"):
        store.save(d, 2, tree)
    monkeypatch.setattr(store.os, "rename", real_rename)
    # The torn attempt is invisible: only the staging dir exists.
    assert os.path.isdir(os.path.join(d, "step_00000002.tmp"))
    assert store.steps(d) == [1]
    assert store.latest_step(d) == 1
    # Retry after restart: overwrites the stale .tmp and publishes.
    store.save(d, 2, tree)
    assert store.latest_step(d) == 2
    out = store.restore(d, 2, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_missing_manifest_is_corrupt(tmp_path, tree):
    d = str(tmp_path)
    os.makedirs(os.path.join(d, "step_00000006"))
    with pytest.raises(store.CheckpointCorrupt, match="manifest missing"):
        store.restore(d, 6, tree)
    assert store.latest_step(d) is None
