"""Checkpoint store: roundtrip, integrity, atomicity, resume."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store


@pytest.fixture
def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((2, 2), jnp.bfloat16),
                       "c": [jnp.zeros(3), jnp.asarray(5)]}}


def test_roundtrip(tmp_path, tree):
    d = str(tmp_path)
    store.save(d, 7, tree, extra={"loss": 1.5})
    assert store.latest_step(d) == 7
    out = store.restore(d, 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert store.restore_extra(d, 7)["loss"] == 1.5


import jax  # noqa: E402  (used in roundtrip comparison)


def test_corruption_detected(tmp_path, tree):
    d = str(tmp_path)
    path = store.save(d, 1, tree)
    victim = os.path.join(path, "a.npy")
    arr = np.load(victim)
    arr_flat = arr.ravel()
    arr_flat[0] += 1
    np.save(victim, arr)
    with pytest.raises(IOError, match="corruption"):
        store.restore(d, 1, tree)
    # verify=False permits (for forensics)
    store.restore(d, 1, tree, verify=False)


def test_latest_ignores_torn_tmp(tmp_path, tree):
    d = str(tmp_path)
    store.save(d, 3, tree)
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    os.makedirs(os.path.join(d, "step_00000010"))  # no manifest => torn
    assert store.latest_step(d) == 3


def test_save_overwrites_same_step(tmp_path, tree):
    d = str(tmp_path)
    store.save(d, 2, tree)
    tree2 = jax.tree.map(lambda a: a * 0 + 9, tree)
    store.save(d, 2, tree2)
    out = store.restore(d, 2, tree)
    assert float(np.asarray(jax.tree.leaves(out)[0]).ravel()[0]) == 9.0


def test_manifest_contents(tmp_path, tree):
    d = str(tmp_path)
    p = store.save(d, 4, tree)
    with open(os.path.join(p, "manifest.json")) as f:
        man = json.load(f)
    assert man["step"] == 4
    assert "a" in man["leaves"]
    assert man["leaves"]["a"]["shape"] == [3, 4]
    assert len(man["leaves"]["a"]["sha256"]) == 64
