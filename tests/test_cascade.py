"""The cascaded prune-and-rescore subsystem (``repro.cascade``).

Covers: spec validation + the static admissibility table, candidate-
compacted scorer parity against the full-corpus engines, the blocked
(ladder-merged) top-k, the API wiring, and the central exactness
property — an admissible cascade whose budgets cover the true top-l
neighbors' stage ranks returns the identical top-l index set as
full-corpus rescoring, for EVERY registered rescorer (the 8-device mesh
version of the same property runs in tests/test_distributed.py).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro import cascade
from repro.cascade import (CASCADES, CascadeSpec, CascadeStage, rescore,
                           topk_recall, topk_smallest)
from repro.core import retrieval
from repro.data.synth import make_text_like


@pytest.fixture(scope="module")
def corpus_labels():
    # doc_len < hmax: padded slots on both the corpus and query side.
    return make_text_like(n_docs=40, n_classes=4, vocab=128, m=8,
                          doc_len=10, hmax=16, seed=3)


# ------------------------------------------------------------ spec layer

def test_stage_and_spec_validation():
    with pytest.raises(ValueError, match="unknown cascade stage method"):
        CascadeStage("nope", 8)
    with pytest.raises(ValueError, match="budget"):
        CascadeStage("rwmd", 0)
    with pytest.raises(ValueError, match="budget"):
        CascadeStage("rwmd", 1.5)
    with pytest.raises(ValueError, match="non-increasing"):
        CascadeSpec(stages=(CascadeStage("wcd", 8),
                            CascadeStage("rwmd", 16)))
    with pytest.raises(ValueError, match="at least one"):
        CascadeSpec(stages=())
    with pytest.raises(ValueError, match="unknown rescorer"):
        CascadeSpec(stages=(CascadeStage("rwmd", 8),), rescorer="nope")
    with pytest.raises(ValueError, match="unknown cascade preset"):
        cascade.resolve_spec("nope")
    # hashable (rides inside EngineConfig / keys jit caches)
    spec = CascadeSpec(stages=(CascadeStage("rwmd", 8),))
    assert hash(spec) == hash(CascadeSpec(stages=(CascadeStage("rwmd", 8),)))


def test_admissibility_table():
    lb = cascade.is_lower_bound
    # Theorem-2 chain: RWMD <= OMR <= ACT-k <= ICT <= EMD
    assert lb("rwmd", 0, "omr", 0) and lb("omr", 0, "act", 1)
    assert lb("act", 2, "act", 3) and not lb("act", 3, "act", 2)
    assert lb("act", 3, "ict", 0) and not lb("ict", 0, "act", 3)
    for m in ("rwmd", "omr", "act", "ict", "wcd", "rwmd_rev"):
        assert lb(m, 1, "emd", 0)
        # the fixed-iteration sinkhorn plan is not exactly feasible, so
        # nothing is PROVABLY below it (identity aside)
        assert not lb(m, 1, "sinkhorn", 0)
    assert lb("sinkhorn", 0, "sinkhorn", 0)
    # act with zero rounds degenerates to RWMD
    assert lb("act", 0, "omr", 0)
    # wcd / rwmd_rev / bow are NOT comparable inside the directional chain
    assert not lb("wcd", 0, "act", 3)
    assert not lb("rwmd_rev", 0, "act", 3)
    assert not lb("bow", 0, "emd", 0)
    # every measure bounds itself
    assert lb("wcd", 0, "wcd", 0) and lb("bow", 0, "bow", 0)


def test_presets_valid_and_flagged():
    for name, spec in CASCADES.items():
        assert cascade.resolve_spec(name) is spec
        assert spec.describe()
    assert not CASCADES["fast"].admissible           # wcd vs act rescorer
    assert CASCADES["chain"].admissible
    assert CASCADES["tight"].admissible
    assert CASCADES["exact"].admissible


def test_resolve_budgets_clamps():
    spec = CascadeSpec(stages=(CascadeStage("wcd", 0.5),
                               CascadeStage("rwmd", 0.1)), rescorer="act")
    assert spec.resolve_budgets(100, 4) == (50, 10)
    assert spec.resolve_budgets(100, 30) == (50, 30)    # floor at top_l
    assert spec.resolve_budgets(10, 4) == (5, 4)
    with pytest.raises(ValueError, match="top_l"):
        spec.resolve_budgets(10, 11)
    big = CascadeSpec(stages=(CascadeStage("rwmd", 1000),), rescorer="act")
    assert big.resolve_budgets(64, 4) == (64,)          # cap at n
    # mixed absolute/fractional budgets skip construction-time ordering;
    # a ladder that stops pruning on this corpus errors instead of
    # silently collapsing the later stage
    mixed = CascadeSpec(stages=(CascadeStage("wcd", 10),
                                CascadeStage("rwmd", 0.9)), rescorer="act")
    assert mixed.resolve_budgets(10, 2) == (10, 9)
    with pytest.raises(ValueError, match="non-monotonically"):
        mixed.resolve_budgets(1000, 4)


def test_rescorer_registry():
    names = rescore.names()
    for required in ("act", "ict", "sinkhorn", "emd"):
        assert required in names
    assert rescore.resolve("act").jittable
    assert rescore.resolve("sinkhorn").jittable
    assert not rescore.resolve("emd").jittable          # host-side LP


# ------------------------------------------------- candidate compaction

@pytest.mark.parametrize("method", sorted(
    m for m, s in retrieval.METHODS.items() if s.cand_fn is not None))
def test_cand_scores_match_full_engine(corpus_labels, method):
    """The gather-compacted scorers reproduce the full-corpus batched
    engine at the candidate rows (same per-row reduction order)."""
    c, _ = corpus_labels
    nq, b = 5, 9
    qi, qw = c.ids[:nq], c.w[:nq]
    rng = np.random.default_rng(0)
    cand = jnp.asarray(np.stack([rng.choice(c.n, b, replace=False)
                                 for _ in range(nq)]).astype(np.int32))
    full = np.asarray(retrieval.batch_scores(c, qi, qw, method=method,
                                             iters=2, block_q=2))
    got = np.asarray(retrieval.cand_scores(c, qi, qw, cand, method=method,
                                           iters=2, block_q=2))
    want = np.take_along_axis(full, np.asarray(cand), axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_cand_scores_rejects_methods_without_cand_fn(corpus_labels,
                                                     monkeypatch):
    c, _ = corpus_labels
    gutted = dataclasses.replace(retrieval.METHODS["act"], cand_fn=None)
    monkeypatch.setitem(retrieval.METHODS, "gutted", gutted)
    with pytest.raises(ValueError, match="candidate-compacted"):
        retrieval.cand_scores(c, c.ids[:2], c.w[:2],
                              jnp.zeros((2, 3), jnp.int32), method="gutted")


def test_ict_registered_and_chain_position(corpus_labels):
    """Satellite: ict is a registry method and Theorem 2 holds for the
    batch engines on real (padded) corpus rows."""
    c, _ = corpus_labels
    assert "ict" in retrieval.METHODS
    qi, qw = c.ids[:4], c.w[:4]
    chain = [np.asarray(retrieval.batch_scores(c, qi, qw, method=m,
                                               iters=it))
             for m, it in (("rwmd", 0), ("omr", 0), ("act", 1),
                           ("act", 3), ("ict", 0))]
    for lo, hi in zip(chain, chain[1:], strict=False):
        assert (lo <= hi + 1e-5).all()


# ------------------------------------------------------- blocked top-k

@pytest.mark.parametrize("blocks", [1, 2, 4, 8])
def test_blocked_topk_matches_plain(blocks):
    rng = np.random.default_rng(1)
    s = jnp.asarray(rng.normal(size=(5, 64)).astype(np.float32))
    v0, i0 = topk_smallest(s, 7)
    v, i = topk_smallest(s, 7, blocks=blocks)
    np.testing.assert_allclose(np.asarray(v), np.asarray(v0))
    np.testing.assert_array_equal(np.sort(np.asarray(i), 1),
                                  np.sort(np.asarray(i0), 1))


def test_blocked_topk_uneven_split_falls_back():
    s = jnp.asarray(np.random.default_rng(2).normal(size=(3, 50)),
                    jnp.float32)
    v0, i0 = topk_smallest(s, 5)
    v, i = topk_smallest(s, 5, blocks=4)          # 50 % 4 != 0
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i0))
    np.testing.assert_allclose(np.asarray(v), np.asarray(v0))


def _check_topk_ties(s, k, blocks):
    """Tie-breaking contract of ``topk_smallest`` on duplicate scores:
    blocks=1 must match ``lax.top_k`` EXACTLY (values and indices — the
    lowest index wins ties); the blocked ladder merge returns the same
    values with a score-consistent, duplicate-free index set (its ties
    resolve by (block, local rank) — a recall-silent difference, pinned
    here so a silent regression cannot slip in)."""
    import jax

    neg, ref_i = jax.lax.top_k(-s, k)
    v, i = topk_smallest(s, k, blocks=blocks)
    np.testing.assert_array_equal(np.asarray(v), -np.asarray(neg))
    iv = np.asarray(i)
    if blocks == 1:
        np.testing.assert_array_equal(iv, np.asarray(ref_i))
        return
    # every selected index carries exactly its reported score, no index
    # is selected twice, and the multiset of scores matches lax.top_k's
    np.testing.assert_array_equal(np.take_along_axis(np.asarray(s), iv, 1),
                                  np.asarray(v))
    assert all(len(set(row)) == len(row) for row in iv)


@pytest.mark.parametrize("blocks", [1, 2, 4])
def test_topk_smallest_tie_breaking_fixed_seeds(blocks):
    """Satellite: duplicate-heavy scores (integers in a tiny range) hit
    tie-breaking on every row."""
    for seed in (0, 1, 2):
        rng = np.random.default_rng(seed)
        s = jnp.asarray(rng.integers(0, 4, size=(6, 32)), jnp.float32)
        _check_topk_ties(s, 7, blocks)


def test_topk_smallest_tie_breaking_property():
    """Hypothesis sweep of the tie-breaking contract."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None, derandomize=True)
    @given(seed=st.integers(0, 2**31 - 1), rows=st.integers(1, 5),
           n=st.sampled_from([16, 32, 48]), k=st.integers(1, 9),
           spread=st.integers(1, 5), blocks=st.sampled_from([1, 2, 4, 8]))
    def run(seed, rows, n, k, spread, blocks):
        rng = np.random.default_rng(seed)
        s = jnp.asarray(rng.integers(0, spread, size=(rows, n)),
                        jnp.float32)
        _check_topk_ties(s, k, blocks)

    run()


def test_topk_recall():
    a = np.array([[0, 1, 2], [3, 4, 5]])
    assert topk_recall(a, a) == 1.0
    assert topk_recall(a, np.array([[0, 1, 9], [3, 4, 9]])) == \
        pytest.approx(2 / 3)
    with pytest.raises(ValueError, match="shape"):
        topk_recall(a, a[:, :2])


# ------------------------------------------------- exactness property

def _rank_budgets(stage_scores, ref_idx, top_l):
    """Smallest budget per stage that keeps every reference top-l item:
    1 + the worst stable-sort rank of any reference item, maxed over
    queries (matches lax.top_k's lowest-index tie rule)."""
    budgets = []
    for s in stage_scores:
        order = np.argsort(s, axis=1, kind="stable")
        rank = np.empty_like(order)
        np.put_along_axis(rank, order, np.arange(s.shape[1])[None, :],
                          axis=1)
        need = int(np.take_along_axis(rank, ref_idx, axis=1).max()) + 1
        budgets.append(max(top_l, need))
    # budgets must be non-increasing along the ladder
    for i in range(len(budgets) - 2, -1, -1):
        budgets[i] = max(budgets[i], budgets[i + 1])
    return budgets


#: Admissible stage ladder for each registered rescorer (a measure always
#: bounds itself; the chain/EMD relations cover the rest).
_ADMISSIBLE_STAGES = {
    "act": (("rwmd", 0), ("omr", 0)),
    "ict": (("rwmd", 0), ("act", 1)),
    "omr": (("rwmd", 0),),
    "rwmd": (("rwmd", 0),),
    "rwmd_rev": (("rwmd_rev", 0),),
    "bow": (("bow", 0),),
    "wcd": (("wcd", 0),),
    "sinkhorn": (("wcd", 0), ("rwmd", 0)),
    "emd": (("wcd", 0), ("rwmd", 0)),
}


def _full_rescorer_scores(c, qi, qw, rescorer, iters, use_kernels=False):
    """Full-corpus scores THROUGH the rescorer's own candidate scorer
    (cand = every row), so the cascade and the reference share float
    behavior exactly."""
    nq = qi.shape[0]
    all_rows = jnp.broadcast_to(jnp.arange(c.n, dtype=jnp.int32),
                                (nq, c.n))
    r = rescore.resolve(rescorer)
    if r.jittable:
        return np.asarray(r.fn(c, qi, qw, all_rows, iters=iters,
                               use_kernels=use_kernels))
    return np.asarray(r.host_fn(c, qi, qw, np.asarray(all_rows)))


def _check_admissible_exactness(rescorer: str, seed: int,
                                use_kernels: bool = False):
    """One instance of the acceptance property: an admissible cascade
    (every stage a provable lower bound of the rescorer, budgets >= top_l
    and >= the stage-score rank of every true top-l neighbor) returns the
    identical top-l index set as full-corpus rescoring.
    ``use_kernels`` runs the SAME property with the fused candidate
    kernels (interpret mode) in every stage and the rescorer — budgets
    and the reference ranking are derived from the kernel path's own
    scores, so coverage holds on the path under test."""
    c, _ = make_text_like(n_docs=20, n_classes=3, vocab=64, m=6,
                          doc_len=8, hmax=8, seed=seed)
    nq, top_l = 3, 3
    qi, qw = c.ids[:nq], c.w[:nq]
    iters = 2 if rescorer == "act" else 1
    full = _full_rescorer_scores(c, qi, qw, rescorer, iters, use_kernels)
    ref_idx = np.argsort(full, axis=1, kind="stable")[:, :top_l]

    stages = _ADMISSIBLE_STAGES[rescorer]
    stage_scores = [np.asarray(retrieval.batch_scores(
        c, qi, qw, method=m, iters=it, use_kernels=use_kernels))
        for m, it in stages]
    budgets = _rank_budgets(stage_scores, ref_idx, top_l)
    spec = CascadeSpec(
        stages=tuple(CascadeStage(m, b, iters=it)
                     for (m, it), b in zip(stages, budgets, strict=True)),
        rescorer=rescorer, rescorer_iters=iters)
    # sinkhorn is deliberately outside the provable table (its
    # fixed-iteration plan is not exactly feasible); rank-covering
    # budgets still make the cascade exact by construction
    assert spec.admissible == (rescorer != "sinkhorn"), spec.describe()

    res = cascade.cascade_search(c, qi, qw, spec, top_l,
                                 use_kernels=use_kernels)
    got = np.sort(np.asarray(res.indices), axis=1)
    assert got.shape == (nq, top_l)
    np.testing.assert_array_equal(got, np.sort(ref_idx, axis=1),
                                  err_msg=spec.describe())


@pytest.mark.parametrize("use_kernels", [False, True],
                         ids=["reference", "kernels"])
@pytest.mark.parametrize("rescorer", sorted(_ADMISSIBLE_STAGES))
def test_admissible_cascade_exact_fixed_seeds(rescorer, use_kernels):
    """The acceptance property on pinned seeds (always runs, even where
    hypothesis is unavailable) — every registered rescorer, on the
    reference path AND composed with the fused candidate kernels."""
    for seed in (3, 17):
        _check_admissible_exactness(rescorer, seed, use_kernels)


@pytest.mark.parametrize("use_kernels", [False, True],
                         ids=["reference", "kernels"])
@pytest.mark.parametrize("rescorer", sorted(_ADMISSIBLE_STAGES))
def test_admissible_cascade_exact_property(rescorer, use_kernels):
    """Hypothesis sweep of the same property over random corpora, for
    every admissible ladder with and without the fused kernels."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def run(seed):
        _check_admissible_exactness(rescorer, seed, use_kernels)

    run()


def test_cascade_kernel_path_matches_reference_path(corpus_labels):
    """Acceptance: an admissible cascade whose budgets cover the true
    top-l stage ranks under BOTH paths returns the identical top-l set
    with use_kernels=True and False, and the rescorer scores of that set
    agree to the last ulps (the fused kernels reuse the reference
    reductions — see kernels/cand_pour)."""
    c, _ = corpus_labels
    nq, top_l, iters = 4, 4, 2
    qi, qw = c.ids[:nq], c.w[:nq]
    stages = (("rwmd", 0), ("omr", 0))
    results = {}
    for uk in (False, True):
        full = _full_rescorer_scores(c, qi, qw, "act", iters, uk)
        ref_idx = np.argsort(full, axis=1, kind="stable")[:, :top_l]
        ss = [np.asarray(retrieval.batch_scores(c, qi, qw, method=m,
                                                iters=it, use_kernels=uk))
              for m, it in stages]
        results[uk] = (_rank_budgets(ss, ref_idx, top_l), ref_idx)
    budgets = [max(a, b) for a, b in zip(results[False][0],
                                         results[True][0], strict=True)]
    spec = CascadeSpec(stages=tuple(CascadeStage(m, b, iters=it)
                                    for (m, it), b in zip(stages, budgets,
                                                          strict=True)),
                       rescorer="act", rescorer_iters=iters)
    assert spec.admissible
    res_r = cascade.cascade_search(c, qi, qw, spec, top_l)
    res_k = cascade.cascade_search(c, qi, qw, spec, top_l,
                                   use_kernels=True)
    order_r = np.argsort(np.asarray(res_r.indices), axis=1)
    order_k = np.argsort(np.asarray(res_k.indices), axis=1)
    np.testing.assert_array_equal(
        np.take_along_axis(np.asarray(res_r.indices), order_r, 1),
        np.take_along_axis(np.asarray(res_k.indices), order_k, 1))
    s_r = np.take_along_axis(np.asarray(res_r.scores), order_r, 1)
    s_k = np.take_along_axis(np.asarray(res_k.scores), order_k, 1)
    from test_cand_kernels import assert_ulp_equal
    assert_ulp_equal(s_k, s_r, err_msg="cascade kernel-vs-reference")


def test_emdindex_pallas_backend_cascade(corpus_labels):
    """EngineConfig(backend="pallas", cascade=...) reaches the fused
    candidate kernels through the API and agrees with the reference
    backend at generous budgets."""
    import dataclasses as dc

    from repro.api import EmdIndex, EngineConfig
    c, _ = corpus_labels
    qi, qw = c.ids[:5], c.w[:5]
    spec = CascadeSpec(stages=(CascadeStage("rwmd", 24),
                               CascadeStage("omr", 12)),
                       rescorer="act", rescorer_iters=2)
    cfg = EngineConfig(method="act", iters=2, top_l=4, cascade=spec,
                       backend="pallas")
    s_k, i_k = EmdIndex.build(c, cfg).search(qi, qw)
    ref = EmdIndex.build(c, dc.replace(cfg, backend="reference"))
    s_r, i_r = ref.search(qi, qw)
    np.testing.assert_array_equal(np.sort(np.asarray(i_k), 1),
                                  np.sort(np.asarray(i_r), 1))
    np.testing.assert_allclose(np.sort(np.asarray(s_k), 1),
                               np.sort(np.asarray(s_r), 1),
                               rtol=1e-5, atol=1e-6)


def test_full_budget_cascade_bitwise_exact(corpus_labels):
    """budget == n degenerates to full-corpus rescoring: identical
    indices AND scores."""
    c, _ = corpus_labels
    qi, qw = c.ids[:4], c.w[:4]
    spec = CascadeSpec(stages=(CascadeStage("rwmd", c.n),),
                       rescorer="act", rescorer_iters=2)
    res = cascade.cascade_search(c, qi, qw, spec, 5)
    full = retrieval.batch_scores(c, qi, qw, method="act", iters=2)
    v, i = topk_smallest(full, 5)
    np.testing.assert_array_equal(np.asarray(res.indices), np.asarray(i))
    np.testing.assert_allclose(np.asarray(res.scores), np.asarray(v),
                               rtol=1e-6, atol=1e-7)


def test_cascade_masks_pad_rows(corpus_labels):
    """n_valid: zero-weight pad rows (which score 0 = best for LC
    methods) never enter candidacy."""
    c, _ = corpus_labels
    from repro.core.lc import Corpus
    padded = Corpus(ids=jnp.pad(c.ids, ((0, 8), (0, 0))),
                    w=jnp.pad(c.w, ((0, 8), (0, 0))), coords=c.coords)
    spec = CascadeSpec(stages=(CascadeStage("rwmd", 16),),
                       rescorer="act", rescorer_iters=1)
    res = cascade.cascade_search(padded, c.ids[:4], c.w[:4], spec, 6,
                                 n_valid=c.n)
    assert int(np.asarray(res.indices).max()) < c.n


def test_stage_rows_strictly_fewer_candidates(corpus_labels):
    """The budget ladder: every post-prefetch stage reads strictly fewer
    rows than full-corpus scoring (the bench's row-count claim)."""
    spec = CASCADES["fast"]
    rows = cascade.stage_rows(spec, 1000, 16)
    assert rows == {"stage1.wcd": 1000, "stage2.rwmd": 400,
                    "rescore.act": 50}
    assert sum(v for k, v in rows.items()
               if not k.startswith("stage1")) < 1000


# ------------------------------------------------------------ API layer

def test_emdindex_cascade_config_and_adhoc(corpus_labels):
    from repro.api import EmdIndex, EngineConfig
    c, _ = corpus_labels
    qi, qw = c.ids[:5], c.w[:5]
    spec = CascadeSpec(stages=(CascadeStage("rwmd", 24),
                               CascadeStage("omr", 12)),
                       rescorer="act", rescorer_iters=2)
    via_config = EmdIndex.build(c, EngineConfig(method="act", iters=2,
                                                top_l=4, cascade=spec))
    s, i = via_config.search(qi, qw)
    assert s.shape == (5, 4) and i.shape == (5, 4)
    plain = EmdIndex.build(c, EngineConfig(method="act", iters=2, top_l=4))
    s2, i2 = plain.search(qi, qw, cascade=spec)       # ad-hoc spec
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s2), rtol=1e-6)
    # single query keeps the uniform shape contract
    s1, i1 = via_config.search(c.ids[0], c.w[0])
    assert s1.shape == (4,) and i1.shape == (4,)
    np.testing.assert_array_equal(
        np.asarray(i1), np.asarray(via_config.search(c.ids[:1],
                                                     c.w[:1])[1][0]))
    # generous budgets here => the cascade agrees with full search
    _, i_full = plain.search(qi, qw)
    assert topk_recall(i, i_full) == 1.0
    # the per-call escape hatch honors the same symmetric/cascade
    # incompatibility EngineConfig enforces
    sym = EmdIndex.build(c, EngineConfig(method="rwmd", symmetric=True))
    with pytest.raises(ValueError, match="symmetric"):
        sym.search(qi, qw, cascade="fast")


def test_emdindex_cascade_distributed_single_device(corpus_labels):
    import dataclasses as dc

    from repro.api import EmdIndex, EngineConfig
    c, _ = corpus_labels
    qi, qw = c.ids[:5], c.w[:5]
    spec = CascadeSpec(stages=(CascadeStage("rwmd", 24),
                               CascadeStage("omr", 12)),
                       rescorer="act", rescorer_iters=2)
    cfg = EngineConfig(method="act", iters=2, top_l=4, cascade=spec,
                       backend="distributed", pad_multiple=16, block_q=3)
    dst = EmdIndex.build(c, cfg)
    assert dst._padded_corpus.n > c.n                 # pad rows in play
    ref = EmdIndex.build(c, dc.replace(cfg, backend="reference"))
    s_d, i_d = dst.search(qi, qw)
    s_r, i_r = ref.search(qi, qw)
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_r))
    np.testing.assert_allclose(np.asarray(s_d), np.asarray(s_r),
                               rtol=1e-5, atol=1e-6)
    assert int(np.asarray(i_d).max()) < c.n           # pads masked
    with pytest.raises(ValueError, match="baked at build time"):
        dst.search(qi, qw, cascade="fast")
    with pytest.raises(ValueError, match="top_l"):
        dst.search(qi, qw, top_l=7)


def test_engine_config_cascade_validation():
    from repro.api import EngineConfig
    with pytest.raises(ValueError, match="unknown cascade preset"):
        EngineConfig(cascade="nope")
    with pytest.raises(ValueError, match="symmetric"):
        EngineConfig(method="rwmd", symmetric=True, cascade="fast")
    with pytest.raises(ValueError, match="host"):
        EngineConfig(backend="distributed", cascade="exact")
    cfg = EngineConfig(cascade="fast")
    assert cfg.cascade_spec is CASCADES["fast"]
    assert hash(cfg) == hash(EngineConfig(cascade="fast"))


def test_precision_and_recall_accept_precomputed_scores(corpus_labels):
    """Satellite: precision_at_l takes precomputed scores; recall_at_l
    measures cascade-vs-exact style agreement from the API."""
    from repro.api import EmdIndex, EngineConfig
    c, labels = corpus_labels
    index = EmdIndex.build(c, EngineConfig(method="act", iters=2))
    S = index.all_pairs()
    assert index.precision_at_l(labels, 4) == \
        index.precision_at_l(labels, 4, scores=S)
    assert index.recall_at_l(S, 4) == 1.0
    assert index.recall_at_l(S, 4, scores=S) == 1.0
    # a looser bound's ranking agrees only partially with the tight one
    loose = EmdIndex.build(c, EngineConfig(method="wcd"))
    r = loose.recall_at_l(S, 4)
    assert 0.0 < r <= 1.0
    with pytest.raises(ValueError, match="shape"):
        retrieval.recall_at_l(S, S[:, :3], 4)
