"""Coverage for the remaining utility layers: histogram construction, the
WMD pruned-search baseline, the report builder, and the retrieval registry."""
import json

import numpy as np

from repro.core import retrieval
from repro.core.histogram import docs_to_corpus, images_to_corpus
from repro.core.wmd import wmd_search
from repro.data.synth import make_text_like


def test_docs_to_corpus_truncates_and_normalizes():
    docs = [[0, 0, 1, 2, 2, 2], [3] * 10, list(range(8))]
    coords = np.random.default_rng(0).normal(size=(8, 4))
    c = docs_to_corpus(docs, coords, hmax=4)
    w = np.asarray(c.w)
    np.testing.assert_allclose(w.sum(axis=1), 1.0, rtol=1e-6)
    # doc 2 has 8 distinct tokens but hmax=4 -> truncated to 4 bins
    assert (w[2] > 0).sum() == 4
    # doc 0: token 2 is most frequent
    ids0 = np.asarray(c.ids[0])
    assert 2 in ids0[np.asarray(w[0]) > 0]


def test_images_to_corpus_modes():
    imgs = np.zeros((3, 4, 4))
    imgs[:, 1, 1] = 1.0
    imgs[1, 2, 2] = 2.0
    sparse = images_to_corpus(imgs, include_background=False)
    dense = images_to_corpus(imgs, include_background=True)
    assert sparse.hmax == 2                      # max nonzeros
    assert dense.hmax == 16                      # every pixel
    np.testing.assert_allclose(np.asarray(dense.w).sum(1), 1.0, rtol=1e-5)
    assert sparse.coords.shape == (16, 2)


def test_wmd_search_exact_ranking_consistency():
    corpus, labels = make_text_like(n_docs=12, vocab=64, m=6, doc_len=20,
                                    hmax=12, seed=9)
    val, idx = wmd_search(corpus, 0, top_l=3)
    assert len(idx) == 3 and 0 not in idx        # self excluded
    assert (np.diff(val) >= -1e-9).all()         # sorted ascending
    # WMD distances dominate the RWMD lower bounds
    from repro.core.lc import lc_rwmd_scores
    lb = np.asarray(lc_rwmd_scores(corpus, corpus.ids[0], corpus.w[0]))
    for u, v in zip(idx, val, strict=True):
        assert v >= lb[u] - 1e-5


def test_retrieval_registry_complete():
    assert set(retrieval.METHODS) == {"rwmd", "rwmd_rev", "omr", "act",
                                      "ict", "bow", "wcd"}
    for name, spec in retrieval.METHODS.items():
        assert isinstance(spec, retrieval.MethodSpec)
        assert spec.name == name and spec.paper_name
        if spec.reverse is not None:
            assert retrieval.METHODS[spec.reverse].reverse == name


def test_report_builder(tmp_path):
    from repro.analysis import report
    rec = {"arch": "a", "shape": "s", "mesh": "16x16", "devices": 256,
           "t_compute": 1.0, "t_memory": 0.5, "t_collective": 2.0,
           "bottleneck": "collective", "hlo_flops": 1e15,
           "model_flops": 8e14, "useful_flops_ratio": 0.8}
    p = tmp_path / "r.jsonl"
    p.write_text(json.dumps(rec) + "\n" + json.dumps(rec) + "\n")
    recs = report.load(str(p))
    assert len(recs) == 1                        # dedup keeps last
    tbl = report.table(recs, "16x16")
    assert "| a | s |" in tbl and "0.500" in tbl
    assert "worst roofline" in report.summary(recs, "16x16")


def test_search_step_single_device_matches_engine():
    from repro.launch.search import make_search_step
    from repro.core.lc import lc_act_scores
    import jax
    corpus, _ = make_text_like(n_docs=10, vocab=64, m=6, doc_len=18,
                               hmax=10, seed=2)
    step = make_search_step(iters=2, top_l=4)
    scores, idx = jax.jit(step)(corpus.ids, corpus.w, corpus.coords,
                                corpus.ids[:3], corpus.w[:3])
    for u in range(3):
        ref = lc_act_scores(corpus, corpus.ids[u], corpus.w[u], iters=2)
        neg, ridx = jax.lax.top_k(-ref, 4)
        np.testing.assert_allclose(np.asarray(scores[u]), np.asarray(-neg),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(idx[u]), np.asarray(ridx))
