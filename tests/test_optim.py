"""Optimizer, schedule, grad accumulation, int8 compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw
from repro.optim.grad_utils import (accumulate_grads, compress_int8,
                                    decompress_int8)


def test_adamw_converges_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adamw.init(params)
    cfg = adamw.AdamWConfig(peak_lr=0.1, min_lr=0.01, warmup_steps=5,
                            total_steps=300, weight_decay=0.0)
    loss_fn = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(300):
        grads = jax.grad(loss_fn)(params)
        params, state, _ = adamw.update(grads, state, params, cfg)
    assert float(loss_fn(params)) < 1e-3


def test_schedule_shape():
    cfg = adamw.AdamWConfig(peak_lr=1.0, min_lr=0.1, warmup_steps=10,
                            total_steps=100)
    lrs = [float(adamw.schedule(jnp.int32(s), cfg)) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 1e-6
    assert lrs[100] <= 0.1 + 1e-6
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:], strict=False))  # decay


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10.0}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-5
    assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-5


def test_accumulate_grads_matches_monolithic():
    params = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0]])}
    batch = {"x": jnp.arange(8.0).reshape(8, 1), "y": jnp.ones((8, 2))}

    def loss_fn(p, b):
        pred = b["x"] @ jnp.ones((1, 2)) @ p["w"]
        return jnp.mean((pred - b["y"]) ** 2)

    l1, g1 = accumulate_grads(loss_fn, params, batch, 1)
    l4, g4 = accumulate_grads(loss_fn, params, batch, 4)
    assert abs(float(l1) - float(l4)) < 1e-5
    np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g4["w"]),
                               rtol=1e-5)


def test_int8_compression_unbiased_and_tight(rng):
    x = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    key = jax.random.PRNGKey(0)
    # unbiased: mean of many stochastic quantizations approaches x
    acc = jnp.zeros_like(x)
    n = 64
    for i in range(n):
        q, s = compress_int8(x, jax.random.fold_in(key, i))
        acc = acc + decompress_int8(q, s)
    err = float(jnp.max(jnp.abs(acc / n - x)))
    scale = float(jnp.max(jnp.abs(x))) / 127
    assert err < 3 * scale / np.sqrt(n) + 1e-6
    # single-shot error bounded by one quantization step
    q, s = compress_int8(x, key)
    assert float(jnp.max(jnp.abs(decompress_int8(q, s) - x))) <= float(s) + 1e-6
