"""Sharding rules: every arch gets valid (divisible) specs on the
production mesh topology; analysis utilities behave."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.jaxpr_cost import cost_of
from repro.configs import ARCH_IDS, get_config
from repro.launch import steps as St
from repro.models.config import SHAPES
from repro.sharding import rules


class FakeMesh:
    """Shape-only stand-in (rules only consult .shape / .axis_names)."""
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _check_divisible(tree, specs, mesh):
    for leaf, spec in zip(jax.tree.leaves(tree),
                          jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P)),
                          strict=True):
        for dim, ax in zip(leaf.shape, tuple(spec), strict=False):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0, (leaf.shape, spec)


@pytest.mark.parametrize("name", ARCH_IDS)
@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["1pod", "2pod"])
def test_param_specs_divide(name, mesh):
    cfg = get_config(name)
    params = St.abstract_params(cfg)
    specs = rules.param_specs(params, mesh)
    _check_divisible(params, specs, mesh)


@pytest.mark.parametrize("name", ["gemma3-27b", "nemotron-4-340b",
                                  "mixtral-8x22b"])
def test_big_matrices_are_sharded(name):
    """The big leaves must not silently fall through to replication."""
    cfg = get_config(name)
    params = St.abstract_params(cfg)
    specs = rules.param_specs(params, MESH1)
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    sizes = dict()
    leaves = dict(jax.tree_util.tree_flatten_with_path(params)[0])
    for path, spec in flat:
        leaf = leaves[path]
        n = int(np.prod(leaf.shape))
        if n > 50e6:
            assert any(ax is not None for ax in tuple(spec)), (path, spec)
    del sizes


@pytest.mark.parametrize("name", ARCH_IDS)
def test_cache_specs_divide(name):
    cfg = get_config(name)
    shape = SHAPES["decode_32k"]
    cache = St.abstract_cache(cfg, shape)
    specs = rules.cache_specs(cache, cfg, MESH1)
    _check_divisible(cache, specs, MESH1)


def test_batch_specs_fallback_unshardable():
    batch = {"tokens": jax.ShapeDtypeStruct((1, 8), np.int32),
             "cache_index": jax.ShapeDtypeStruct((), np.int32)}
    specs = rules.batch_specs(batch, MESH1)
    assert tuple(specs["tokens"]) == (None, None)   # B=1 can't shard
    assert tuple(specs["cache_index"]) == ()


def test_jaxpr_cost_exact_on_known_program():
    import jax.numpy as jnp

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    c = cost_of(f, a, ws)
    assert c["flops"] == 10 * 2 * 64 ** 3        # scan body x length

    def g(x):
        return jax.grad(lambda y: jnp.sum((y @ y) ** 2))(x)
    c2 = cost_of(g, a)
    assert c2["flops"] >= 3 * 2 * 64 ** 3        # fwd + 2 bwd matmuls


def test_hlo_collective_parser_on_real_psum():
    from repro.analysis.hlo_collectives import collective_bytes
    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    # single-device module: no collectives expected
    c = jax.jit(lambda x: x @ x).lower(
        jax.ShapeDtypeStruct((32, 32), np.float32)).compile()
    out = collective_bytes(c.as_text(), 1)
    assert sum(out.values()) == 0.0
