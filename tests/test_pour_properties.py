"""Hypothesis property tests of the LC engine internals: the prefix-sum
pour vs the paper's literal sequential rounds, and the partitionable
k-selection."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core.lc import pour, smallest_k, streaming_smallest_k
from repro.kernels.ref import act_phase2_ref

settings.register_profile("ci2", deadline=None, max_examples=30)
settings.load_profile("ci2")


@given(st.integers(1, 12), st.integers(1, 10), st.integers(1, 6),
       st.integers(0, 2**31 - 1))
def test_pour_equals_sequential_rounds(n, hmax, iters, seed):
    """The exclusive-prefix water-filling == eqs. (6)-(9) literal loop."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.uniform(size=(n, hmax))
                    * (r.uniform(size=(n, hmax)) > 0.3), jnp.float32)
    zg = jnp.asarray(np.sort(r.uniform(size=(n, hmax, iters + 1)), -1),
                     jnp.float32)
    wg = jnp.asarray(r.uniform(size=(n, hmax, iters)) * 0.4, jnp.float32)
    got = pour(x, zg, wg, iters)
    want = act_phase2_ref(x, zg, wg)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@given(st.integers(1, 20), st.integers(1, 16), st.integers(0, 2**31 - 1))
def test_smallest_k_properties(rows, h, seed):
    import jax
    r = np.random.default_rng(seed)
    k = min(r.integers(1, 9), h)
    d = jnp.asarray(r.normal(size=(rows, h)), jnp.float32)
    z, s = smallest_k(d, int(k))
    # ascending values, valid indices, matches lax.top_k
    assert (np.diff(np.asarray(z), axis=1) >= -1e-7).all()
    assert ((np.asarray(s) >= 0) & (np.asarray(s) < h)).all()
    neg, sr = jax.lax.top_k(-d, int(k))
    np.testing.assert_allclose(np.asarray(z), -np.asarray(neg), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


@given(st.integers(1, 20), st.integers(1, 24), st.integers(1, 24),
       st.integers(0, 2**31 - 1))
def test_streaming_topk_equals_smallest_k(rows, h, chunk, seed):
    """The single-pass streaming selection == the k-rescan smallest_k for
    every chunking, including heavy ties (quantized values): ties resolve
    to the lowest column index in both."""
    r = np.random.default_rng(seed)
    k = int(min(r.integers(1, 9), h))
    d = jnp.asarray(np.round(r.normal(size=(rows, h)), 1), jnp.float32)
    z1, s1 = smallest_k(d, k)
    z2, s2 = streaming_smallest_k(d, k, chunk=int(chunk))
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


@given(st.integers(2, 10), st.integers(0, 2**31 - 1))
def test_pour_monotone_in_iters(hmax, seed):
    """More constrained-transfer rounds never decrease the bound
    (ACT-k monotonicity at the engine level)."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.uniform(size=(4, hmax)), jnp.float32)
    kmax = 5
    z_full = jnp.asarray(np.sort(r.uniform(size=(4, hmax, kmax + 1)), -1),
                         jnp.float32)
    w_full = jnp.asarray(r.uniform(size=(4, hmax, kmax)) * 0.4, jnp.float32)
    prev = None
    for it in range(kmax + 1):
        t = np.asarray(pour(x, z_full[..., :it + 1], w_full[..., :it], it))
        if prev is not None:
            assert (t >= prev - 1e-5).all()
        prev = t
