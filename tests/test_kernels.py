"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("v,h,m,k", [
    (64, 32, 8, 1), (100, 50, 16, 4), (256, 256, 4, 8), (70, 33, 3, 2),
    (512, 17, 300, 8), (31, 128, 2, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dist_topk_matches_ref(v, h, m, k, dtype, rng):
    coords = jnp.asarray(rng.normal(size=(v, m)), dtype)
    qc = jnp.asarray(rng.normal(size=(h, m)), dtype)
    qmask = jnp.asarray(rng.uniform(size=h) > 0.2, jnp.float32)
    if not float(qmask.sum()):
        qmask = qmask.at[0].set(1.0)
    z, s = ops.dist_topk(coords, qc, k, qmask=qmask, block_v=32, block_h=16)
    zr, sr = ref.dist_topk_ref(coords, qc, qmask, k)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(z), np.asarray(zr), rtol=tol,
                               atol=tol)
    # indices may differ only under distance ties
    mismatch = np.asarray(s) != np.asarray(sr)
    if mismatch.any():
        zv = np.asarray(z)
        assert np.allclose(zv[mismatch], np.asarray(zr)[mismatch], atol=tol)


@pytest.mark.parametrize("n,hmax,iters", [
    (10, 7, 1), (64, 32, 3), (33, 17, 7), (128, 500, 2), (5, 9, 15),
])
def test_act_phase2_matches_ref(n, hmax, iters, rng):
    x = jnp.asarray(rng.uniform(size=(n, hmax)) *
                    (rng.uniform(size=(n, hmax)) > 0.3), jnp.float32)
    zg = jnp.asarray(np.sort(rng.uniform(size=(n, hmax, iters + 1)), axis=-1),
                     jnp.float32)
    wg = jnp.asarray(rng.uniform(size=(n, hmax, iters)) * 0.3, jnp.float32)
    t = ops.act_phase2(x, zg, wg, block_n=16, block_h=8)
    tr = ref.act_phase2_ref(x, zg, wg)
    np.testing.assert_allclose(np.asarray(t), np.asarray(tr)[:, 0],
                               rtol=1e-5, atol=1e-6)


def test_act_phase2_conserves_mass_cost_bound(rng):
    """Poured cost is bounded by total mass x max cost (sanity invariant)."""
    n, hmax, it = 32, 16, 3
    x = jnp.asarray(rng.uniform(size=(n, hmax)), jnp.float32)
    zg = jnp.asarray(np.sort(rng.uniform(size=(n, hmax, it + 1)), axis=-1),
                     jnp.float32)
    wg = jnp.asarray(rng.uniform(size=(n, hmax, it)), jnp.float32)
    t = ops.act_phase2(x, zg, wg)
    bound = np.asarray(jnp.sum(x, axis=1)) * float(zg.max())
    assert (np.asarray(t) <= bound + 1e-5).all()
    assert (np.asarray(t) >= 0).all()


@pytest.mark.parametrize("nq,v,h,m,k", [
    (1, 64, 32, 8, 4), (3, 100, 50, 16, 4), (5, 70, 33, 3, 2),
])
def test_dist_topk_batched_matches_ref(nq, v, h, m, k, rng):
    coords = jnp.asarray(rng.normal(size=(v, m)), jnp.float32)
    qcs = jnp.asarray(rng.normal(size=(nq, h, m)), jnp.float32)
    qmask = jnp.asarray(rng.uniform(size=(nq, h)) > 0.2, jnp.float32)
    qmask = qmask.at[:, 0].set(1.0)
    z, s = ops.dist_topk_batched(coords, qcs, k, qmask=qmask, block_v=32,
                                 block_h=16)
    zr, sr = ref.dist_topk_batched_ref(coords, qcs, qmask, k)
    assert z.shape == (nq, v, k)
    np.testing.assert_allclose(np.asarray(z), np.asarray(zr), rtol=1e-5,
                               atol=1e-5)
    mismatch = np.asarray(s) != np.asarray(sr)
    if mismatch.any():                       # ties may reorder indices
        assert np.allclose(np.asarray(z)[mismatch],
                           np.asarray(zr)[mismatch], atol=1e-5)


@pytest.mark.parametrize("nq,n,hmax,iters", [
    (1, 10, 7, 1), (4, 33, 17, 3), (6, 16, 9, 7),
])
def test_act_phase2_batched_matches_ref(nq, n, hmax, iters, rng):
    x = jnp.asarray(rng.uniform(size=(n, hmax)) *
                    (rng.uniform(size=(n, hmax)) > 0.3), jnp.float32)
    zg = jnp.asarray(np.sort(rng.uniform(size=(nq, n, hmax, iters + 1)), -1),
                     jnp.float32)
    wg = jnp.asarray(rng.uniform(size=(nq, n, hmax, iters)) * 0.3,
                     jnp.float32)
    t = ops.act_phase2_batched(x, zg, wg, block_n=16, block_h=8)
    tr = ref.act_phase2_batched_ref(x, zg, wg)
    assert t.shape == (nq, n)
    np.testing.assert_allclose(np.asarray(t), np.asarray(tr), rtol=1e-5,
                               atol=1e-6)


def test_dist_topk_sorted_ascending(rng):
    coords = jnp.asarray(rng.normal(size=(64, 5)), jnp.float32)
    qc = jnp.asarray(rng.normal(size=(40, 5)), jnp.float32)
    z, _ = ops.dist_topk(coords, qc, 6, block_v=32, block_h=16)
    zv = np.asarray(z)
    assert (np.diff(zv, axis=1) >= -1e-6).all()


def test_kernel_path_in_engine(rng):
    from repro.core.lc import lc_act_scores
    from repro.data.synth import make_text_like
    corpus, _ = make_text_like(n_docs=10, vocab=64, m=8, doc_len=20, hmax=12)
    for iters in (0, 2):
        a = lc_act_scores(corpus, corpus.ids[0], corpus.w[0], iters=iters)
        b = lc_act_scores(corpus, corpus.ids[0], corpus.w[0], iters=iters,
                          use_kernels=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)
