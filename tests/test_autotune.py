"""Unit tests of the VMEM-driven tile autotuner (``kernels/autotune``).

Everything here is static — enumeration, cache round-trips, and the
``EngineConfig`` resolution policy. Timing (the ``"force"`` tournament)
is exercised only through the admissibility of what it would time: by
construction it can only pick configs ``analysis/vmem.check_launch``
admits, which is the property tested (fixed cases + a hypothesis sweep
when hypothesis is installed)."""
import dataclasses

import pytest

from repro.analysis import vmem
from repro.api import EmdIndex, EngineConfig
from repro.data.synth import make_text_like
from repro.kernels import autotune

# ------------------------------------------------------------ enumeration

FIXED_CASES = (
    ("dist_topk", dict(nq=8, v=2048, h=256, m=64, k=8)),
    ("act_phase2", dict(nq=8, n=4096, h=128, iters=7)),
    ("cand_pour", dict(nq=8, b=256, h=64, v=512, k=4, iters=3,
                       mode="pour")),
    ("cand_dist", dict(nq=8, b=256, h=500, v=4096, qh=500, mode="ict")),
)


@pytest.mark.parametrize("family,dims", FIXED_CASES,
                         ids=[f for f, _ in FIXED_CASES])
def test_every_enumerated_config_passes_check_launch(family, dims):
    cfgs = autotune.admissible_configs(family, dims)
    assert cfgs, (family, dims)
    for cfg in cfgs:
        assert vmem.check_launch(f"t:{family}", family, {**dims, **cfg}) \
            == [], (family, cfg)


def test_enumeration_is_deterministic_and_deduped():
    family, dims = FIXED_CASES[0]
    a = autotune.admissible_configs(family, dims)
    b = autotune.admissible_configs(family, dims)
    assert a == b
    # dedup key: the wrappers' clamped effective tiles must be unique
    def eff(cfg):
        return tuple(min(blk, -(-dims[d] // 8) * 8)
                     for (k, d), blk in zip(autotune.FAMILY_KNOBS[family],
                                            [cfg[k] for k, _ in
                                             autotune.FAMILY_KNOBS[family]]))
    effs = [eff(c) for c in a]
    assert len(effs) == len(set(effs))


def test_paper_scale_cand_dist_admits_small_block_n():
    """The acceptance shape: blocked-vocab cand_dist at the 20News paper
    profile (hmax = qh = 500, vocab ~ 69682) must fit the 16 MiB budget
    — and only fits with small row tiles, which therefore must be in
    the candidate set."""
    dims = dict(nq=8, b=256, h=500, v=69682, qh=500, mode="ict")
    cfgs = autotune.admissible_configs("cand_dist", dims)
    assert cfgs, "nothing admissible at the paper profile"
    assert all(c["block_n"] <= 4 for c in cfgs)
    assert any(c["block_n"] == 2 for c in cfgs)


def test_admissible_configs_hypothesis_property():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=25, deadline=None)
    @hyp.given(v=st.integers(1, 512), h=st.integers(1, 128),
               m=st.integers(1, 64), k=st.integers(1, 8),
               nq=st.integers(1, 8))
    def prop(v, h, m, k, nq):
        dims = dict(nq=nq, v=v, h=h, m=m, k=k)
        for cfg in autotune.admissible_configs("dist_topk", dims):
            assert vmem.check_launch("h:dist_topk", "dist_topk",
                                     {**dims, **cfg}) == []
    prop()


# ------------------------------------------------------------- TuneCache

def test_tune_cache_round_trip(tmp_path):
    cache = autotune.TuneCache()
    dims = dict(nq=8, v=2048, h=256, m=64, k=8)
    cache.put("dist_topk", dims, {"block_v": 128, "block_h": 64})
    assert cache.get("dist_topk", dims) == {"block_v": 128, "block_h": 64}
    # shape bucketing: 2048 and 1500 share the next-pow2 bucket
    assert cache.get("dist_topk", dict(dims, v=1500)) \
        == {"block_v": 128, "block_h": 64}
    assert cache.get("dist_topk", dict(dims, v=4096)) is None
    assert cache.get("dist_topk", dims, dtype="bfloat16") is None

    path = tmp_path / "tune.json"
    cache.save(str(path))
    loaded = autotune.TuneCache.load(str(path))
    assert loaded.entries == cache.entries
    assert autotune.TuneCache.from_json(cache.to_json()).entries \
        == cache.entries
    # cold-cache states are empty, not errors
    assert autotune.TuneCache.load(None).entries == {}
    assert autotune.TuneCache.load(str(tmp_path / "no.json")).entries == {}


def test_tune_cached_mode_never_times():
    """``mode="cached"`` must not invoke the timing factory at all — a
    make_run that explodes proves it."""
    def boom(cfg):
        raise AssertionError("cached mode timed a config")
    dims = dict(nq=8, v=256, h=32, m=16, k=4)
    assert autotune.tune("dist_topk", dims, boom, cache=autotune.TuneCache(),
                         mode="cached") is None
    assert autotune.tune("dist_topk", dims, boom, mode="off") is None
    with pytest.raises(ValueError):
        autotune.tune("dist_topk", dims, boom, mode="sometimes")


# ------------------------------------------------- EngineConfig resolution

def _corpus():
    c, _ = make_text_like(n_docs=32, n_classes=4, vocab=96, m=8,
                          doc_len=12, hmax=16, seed=3)
    return c


def test_resolve_config_off_ignores_cache(tmp_path):
    corpus = _corpus()
    path = tmp_path / "tune.json"
    cache = autotune.TuneCache()
    for family, dims in autotune.index_plan(
            corpus, EngineConfig(method="act", iters=2)):
        cache.put(family, dims, {"block_v": 4, "block_h": 4,
                                 "block_n": 4})
    cache.save(str(path))
    cfg = EngineConfig(method="act", iters=2, autotune="off",
                       tune_cache=str(path))
    out, picks = autotune.resolve_config(corpus, cfg)
    assert out is cfg and picks == {}
    idx = EmdIndex.build(corpus, cfg)
    assert idx.tuned_blocks == {}


def test_resolve_config_cached_is_deterministic(tmp_path):
    corpus = _corpus()
    cfg0 = EngineConfig(method="act", iters=2)
    plan = autotune.index_plan(corpus, cfg0)
    assert [f for f, _ in plan] == ["dist_topk", "act_phase2"]
    path = tmp_path / "tune.json"
    cache = autotune.TuneCache()
    cache.put("dist_topk", plan[0][1], {"block_v": 64, "block_h": 32})
    cache.save(str(path))

    cfg = dataclasses.replace(cfg0, autotune="cached",
                              tune_cache=str(path))
    out1, picks1 = autotune.resolve_config(corpus, cfg)
    out2, picks2 = autotune.resolve_config(corpus, cfg)
    assert out1 == out2 and picks1 == picks2      # never times -> stable
    assert out1.block_v == 64 and out1.block_h == 32
    assert picks1 == {"dist_topk": {"block_v": 64, "block_h": 32}}
    # act_phase2 missed the cache: block_n keeps its dataclass default
    assert out1.block_n == cfg0.block_n

    idx = EmdIndex.build(corpus, cfg)
    assert idx.tuned_blocks == picks1
    assert idx.config.block_v == 64


def test_resolve_config_explicit_override_wins(tmp_path):
    corpus = _corpus()
    plan = autotune.index_plan(corpus, EngineConfig(method="act", iters=2))
    path = tmp_path / "tune.json"
    cache = autotune.TuneCache()
    cache.put("dist_topk", plan[0][1], {"block_v": 64, "block_h": 32})
    cache.save(str(path))
    cfg = EngineConfig(method="act", iters=2, autotune="cached",
                       tune_cache=str(path), block_v=128)
    out, picks = autotune.resolve_config(corpus, cfg)
    assert out.block_v == 128                     # explicit knob held
    assert out.block_h == 32                      # default knob replaced
    assert picks == {"dist_topk": {"block_h": 32}}
