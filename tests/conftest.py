import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running distributed/subprocess tests")
    config.addinivalue_line(
        "markers", "chaos: deterministic fault-injection serving tests")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
