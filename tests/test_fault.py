"""Fault tolerance: failure recovery determinism + straggler tracking."""
import time

import jax.numpy as jnp
import numpy as np

from repro.data.tokens import DataConfig, global_batch, shard_batch
from repro.runtime.fault import FaultTolerantRunner, StragglerStats


def _step(state, batch):
    return {"w": state["w"] + jnp.sum(batch["tokens"] % 7),
            "n": state["n"] + 1}


def _data(step):
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=4, seed=1)
    b = global_batch(cfg, step)
    return {"tokens": jnp.asarray(b["tokens"])}


def test_recovery_reproduces_failure_free_run(tmp_path):
    init = {"w": jnp.float32(0.0), "n": jnp.int32(0)}
    clean = FaultTolerantRunner(_step, _data, str(tmp_path / "clean"),
                                ckpt_every=5)
    ref = clean.run(init, 23)

    fail_at = {3, 11, 12, 19}
    calls = {"n": 0}

    def flaky(state, batch):
        calls["n"] += 1
        # fail the FIRST time we hit each designated step
        step = int(state["n"])
        if step in fail_at:
            fail_at.discard(step)
            raise RuntimeError(f"injected node failure at step {step}")
        return _step(state, batch)

    runner = FaultTolerantRunner(flaky, _data, str(tmp_path / "flaky"),
                                 ckpt_every=5)
    out = runner.run(init, 23)
    assert runner.restarts == 4
    assert int(out["n"]) == int(ref["n"]) == 23
    assert float(out["w"]) == float(ref["w"])   # bit-identical replay


def test_resume_from_disk(tmp_path):
    init = {"w": jnp.float32(0.0), "n": jnp.int32(0)}
    d = str(tmp_path / "resume")
    r1 = FaultTolerantRunner(_step, _data, d, ckpt_every=5)
    r1.run(init, 10)
    # new process/runner picks up from the checkpoint, not from scratch
    seen = []
    r2 = FaultTolerantRunner(_step, _data, d, ckpt_every=5)
    out = r2.run(init, 15, on_step=lambda s, _: seen.append(s))
    assert seen == [11, 12, 13, 14, 15]
    ref = FaultTolerantRunner(_step, _data, str(tmp_path / "ref"),
                              ckpt_every=5).run(init, 15)
    assert float(out["w"]) == float(ref["w"])


def test_straggler_flagging():
    st = StragglerStats()
    for i in range(20):
        assert not st.record(i, 1.0, factor=3.0)
    assert st.record(20, 10.0, factor=3.0)
    assert st.flagged_steps == [20]


def test_data_pipeline_deterministic_and_shardable():
    cfg = DataConfig(vocab=50, seq_len=16, global_batch=8, seed=4, n_shards=4)
    a = shard_batch(cfg, step=3, shard=2)
    b = shard_batch(cfg, step=3, shard=2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # shards are disjoint slices of a consistent global batch
    g = global_batch(cfg, step=3)
    assert g["tokens"].shape == (8, 16)
    np.testing.assert_array_equal(g["tokens"][4:6], a["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_straggler_times_window_is_bounded():
    """StragglerStats.times is a bounded deque: a long-running service
    never grows it past TIME_WINDOW entries, and the median tracks the
    recent window, not all history."""
    from repro.runtime.fault import TIME_WINDOW
    st = StragglerStats()
    for i in range(10 * TIME_WINDOW):
        st.record(i, 1.0, factor=3.0)
    assert len(st.times) == TIME_WINDOW
    # Flood the window with slow steps: the median follows, so a
    # now-normal 1.0s step is no longer flagged against ancient history.
    for i in range(TIME_WINDOW):
        st.record(1000 + i, 9.0, factor=3.0)
    assert not st.record(5000, 9.0, factor=3.0)


def test_replayed_steps_excluded_from_straggler_stats(tmp_path):
    """Failed and replayed steps must not enter the timing stats: the
    failed attempt measured the failure and the replay runs against warm
    caches — either would bias the median the flagging threshold uses.
    Every successful step is timed EXACTLY once despite 4 rollbacks."""
    init = {"w": jnp.float32(0.0), "n": jnp.int32(0)}
    fail_at = {3, 11, 12, 19}

    def flaky(state, batch):
        step = int(state["n"])
        if step in fail_at:
            fail_at.discard(step)
            raise RuntimeError(f"injected failure at step {step}")
        return _step(state, batch)

    runner = FaultTolerantRunner(flaky, _data, str(tmp_path / "flaky"),
                                 ckpt_every=5)
    runner.run(init, 23)
    assert runner.restarts == 4
    # 23 successful steps -> exactly 23 timing samples; the replayed
    # steps (e.g. 11-15 rerun after the step-12 failure rolled back to
    # the step-10 checkpoint) were not re-recorded.
    assert len(runner.straggler.times) == 23

    clean = FaultTolerantRunner(_step, _data, str(tmp_path / "clean"),
                                ckpt_every=5)
    clean.run(init, 23)
    assert len(clean.straggler.times) == 23
