"""The candidate-source subsystem (``repro.candidates``).

Covers: the spec layer (validation, registry resolution, measured-recall
labeling through ``CascadeSpec``), the FullScan bitwise-identity
property (a full-scan-sourced cascade IS the unsourced cascade), the
build helpers (pack_table accounting, kmeans shape/assignment
invariants), the two sublinear sources' candidate contracts (valid ids,
mask semantics, budget truncation, exact-centroid refine ordering), the
cluster tree's clamped triangle-inequality bound (a true lower bound on
member centroid distances), ``state_structs``/``wrap`` round-trips, and
end-to-end recall sanity on a clustered corpus. Mesh parity for the
sourced step lives in tests/test_distributed.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import candidates as cs
from repro import cascade
from repro.candidates import (EMPTY_CENTER, SOURCES, CentroidLSHSpec,
                              ClusterTreeSpec, FullScanSpec, SourceSpec,
                              corpus_centroids, kmeans, pack_table,
                              resolve_source)
from repro.cascade import CascadeSpec, CascadeStage
from repro.data.synth import make_clustered_text, make_text_like


@pytest.fixture(scope="module")
def corpus_labels():
    # Clustered geometry (what the sources index) with pad slots in play.
    return make_clustered_text(192, n_topics=4, vocab=128, m=8, hmax=16,
                               min_len=8, seed=7)


# ----------------------------------------------------------- spec layer

def test_registry_and_resolution():
    assert set(SOURCES) >= {"full_scan", "centroid_lsh", "cluster_tree"}
    assert isinstance(resolve_source("full_scan"), FullScanSpec)
    spec = CentroidLSHSpec(n_buckets=8, probes=2, bucket_cap=4)
    assert resolve_source(spec) is spec
    with pytest.raises(ValueError, match="unknown candidate source"):
        resolve_source("nope")
    with pytest.raises(TypeError):
        resolve_source(42)


def test_spec_validation():
    with pytest.raises(ValueError, match="probes"):
        CentroidLSHSpec(n_buckets=4, probes=5)
    with pytest.raises(ValueError, match="power-of-two"):
        CentroidLSHSpec(quantizer="hyperplane", n_buckets=6, probes=2)
    with pytest.raises(ValueError, match="unknown quantizer"):
        CentroidLSHSpec(quantizer="nope")
    with pytest.raises(ValueError, match="refine"):
        CentroidLSHSpec(n_buckets=8, probes=2, bucket_cap=4, refine=0)
    with pytest.raises(ValueError, match="exceeds the probed width"):
        CentroidLSHSpec(n_buckets=8, probes=2, bucket_cap=4, refine=9)
    with pytest.raises(ValueError, match="beam"):
        ClusterTreeSpec(branching=4, beam=5)
    with pytest.raises(ValueError, match="probes"):
        ClusterTreeSpec(branching=4, beam=2, probes=3)
    with pytest.raises(ValueError, match="exceeds the probed width"):
        ClusterTreeSpec(branching=4, depth=1, beam=2, probes=2,
                        leaf_cap=4, refine=16)
    # hashable + dataclasses.replace-able (ride in CascadeSpec / jit keys)
    spec = ClusterTreeSpec(branching=4, depth=2, beam=2, probes=2,
                           leaf_cap=8)
    assert hash(spec) == hash(dataclasses.replace(spec))
    assert spec.n_leaves == 16 and spec.n_nodes == 20
    assert spec.width == 16
    assert CentroidLSHSpec(n_buckets=8, probes=2, bucket_cap=4,
                           refine=6).width == 6
    assert CentroidLSHSpec(n_buckets=8, probes=2).width is None


def test_measured_recall_labeling():
    """Sublinear sources force admissible=False (recall must be
    MEASURED); the full scan preserves the cascade's own label."""
    stages = (CascadeStage("rwmd", 16),)
    unsourced = CascadeSpec(stages=stages, rescorer="act")
    lsh = CascadeSpec(stages=stages, rescorer="act",
                      source=CentroidLSHSpec(n_buckets=8, probes=2,
                                             bucket_cap=8))
    fullscan = CascadeSpec(stages=stages, rescorer="act",
                           source="full_scan")
    assert unsourced.admissible and not unsourced.sourced
    assert not lsh.admissible and lsh.sourced
    assert fullscan.admissible and not fullscan.sourced
    assert lsh.source.describe() in lsh.describe()
    # string kinds resolve through the registry at spec construction
    named = CascadeSpec(stages=stages, source="centroid_lsh")
    assert isinstance(named.source, CentroidLSHSpec)


# -------------------------------------------------------- build helpers

def test_pack_table_lossless_and_capped():
    assign = np.array([0, 2, 0, 2, 2, 1])
    rows, mask, dropped = pack_table(assign, 3, None)
    assert dropped == 0 and rows.shape == (3, 3)
    assert rows[mask].size == 6
    np.testing.assert_array_equal(sorted(rows[2][mask[2]]), [1, 3, 4])
    # explicit cap keeps each bucket's FIRST rows and counts the drop
    rows_c, mask_c, dropped_c = pack_table(assign, 3, 2)
    assert dropped_c == 1 and rows_c.shape == (3, 2)
    np.testing.assert_array_equal(rows_c[2][mask_c[2]], [1, 3])
    # singleton bucket: one valid slot, rest masked
    assert mask[1].sum() == 1 and rows[1][mask[1]][0] == 5


def test_kmeans_invariants(rng):
    x = rng.normal(size=(200, 6)).astype(np.float32)
    c, a = kmeans(x, 8, 3, rng)
    assert c.shape == (8, 6) and a.shape == (200,)
    assert a.min() >= 0 and a.max() < 8
    # final assignment is the argmin against the returned centers
    d = np.linalg.norm(x[:, None, :] - c[None, :, :], axis=-1)
    np.testing.assert_array_equal(a, np.argmin(d, axis=1))


def test_corpus_centroids_blocked_matches_direct(corpus_labels):
    corpus, _ = corpus_labels
    got = corpus_centroids(corpus, block=17)      # force many partials
    ref = np.einsum("bh,bhm->bm", np.asarray(corpus.w, np.float32),
                    np.asarray(corpus.coords,
                               np.float32)[np.asarray(corpus.ids)])
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)


# ----------------------------------------- full-scan bitwise identity

def test_fullscan_source_bitwise_identity(corpus_labels):
    """A cascade sourced with FullScanSpec takes the ORIGINAL stage-1
    path: indices AND scores are bitwise those of the unsourced spec."""
    corpus, _ = corpus_labels
    q_ids, q_w = corpus.ids[:6], corpus.w[:6]
    stages = (CascadeStage("wcd", 64), CascadeStage("rwmd", 16))
    plain = CascadeSpec(stages=stages, rescorer="act", rescorer_iters=2)
    sourced = CascadeSpec(stages=stages, rescorer="act",
                          rescorer_iters=2, source="full_scan")
    src = sourced.source.build(corpus)
    r0 = cascade.cascade_search(corpus, q_ids, q_w, plain, 4)
    r1 = cascade.cascade_search(corpus, q_ids, q_w, sourced, 4,
                                source=src)
    np.testing.assert_array_equal(np.asarray(r0.indices),
                                  np.asarray(r1.indices))
    np.testing.assert_array_equal(np.asarray(r0.scores),
                                  np.asarray(r1.scores))


def test_fullscan_bitwise_hypothesis_property():
    """Derandomized hypothesis sweep of the same identity over corpus
    shapes, budgets, and seeds."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None, derandomize=True)
    @given(n=st.integers(12, 40), seed=st.integers(0, 5),
           budget=st.integers(4, 12))
    def prop(n, seed, budget):
        corpus, _ = make_text_like(n_docs=n, n_classes=3, vocab=48, m=6,
                                   doc_len=8, hmax=8, seed=seed)
        q_ids, q_w = corpus.ids[:3], corpus.w[:3]
        stages = (CascadeStage("rwmd", budget),)
        plain = CascadeSpec(stages=stages, rescorer="act",
                            rescorer_iters=1)
        sourced = dataclasses.replace(plain, source="full_scan")
        r0 = cascade.cascade_search(corpus, q_ids, q_w, plain, 3)
        r1 = cascade.cascade_search(corpus, q_ids, q_w, sourced, 3,
                                    source=sourced.source.build(corpus))
        np.testing.assert_array_equal(np.asarray(r0.indices),
                                      np.asarray(r1.indices))
        np.testing.assert_array_equal(np.asarray(r0.scores),
                                      np.asarray(r1.scores))

    prop()


# ------------------------------------------------- candidate contracts

SUBLINEAR_SPECS = [
    CentroidLSHSpec(n_buckets=8, probes=3, bucket_cap=32),
    CentroidLSHSpec(n_buckets=8, probes=3, bucket_cap=32, refine=48),
    CentroidLSHSpec(quantizer="hyperplane", n_buckets=8, probes=3,
                    bucket_cap=48),
    ClusterTreeSpec(branching=4, depth=2, beam=3, probes=2, leaf_cap=24),
    ClusterTreeSpec(branching=4, depth=2, beam=3, probes=2, leaf_cap=24,
                    refine=32),
]


@pytest.mark.parametrize("spec", SUBLINEAR_SPECS,
                         ids=lambda s: s.describe())
def test_candidate_contract(corpus_labels, spec):
    """Valid ids, mask semantics, width, budget truncation, and jit
    parity for every sublinear source."""
    corpus, _ = corpus_labels
    src = spec.build(corpus)
    q_ids, q_w = corpus.ids[:5], corpus.w[:5]
    ids, mask = src.candidates(corpus, q_ids, q_w)
    ids, mask = np.asarray(ids), np.asarray(mask)
    assert ids.shape == (5, src.width) and mask.shape == ids.shape
    assert ids.min() >= 0 and ids.max() < corpus.n
    assert mask.any(axis=1).all()           # every query sees candidates
    # masked-valid candidates are unique per query
    for q in range(5):
        live = ids[q][mask[q]]
        assert len(set(live.tolist())) == live.size
    # budget truncation keeps a prefix
    bids, bmask = src.candidates(corpus, q_ids, q_w, budget=7)
    np.testing.assert_array_equal(np.asarray(bids), ids[:, :7])
    np.testing.assert_array_equal(np.asarray(bmask), mask[:, :7])
    # the step jits with the source as a pytree argument
    jcorpus = dataclasses.replace(
        corpus, ids=jnp.asarray(corpus.ids), w=jnp.asarray(corpus.w),
        coords=jnp.asarray(corpus.coords))
    jids, jmask = jax.jit(
        lambda s, qi, qw: s.candidates(jcorpus, qi, qw))(
            src, jnp.asarray(np.asarray(q_ids)),
            jnp.asarray(np.asarray(q_w)))
    np.testing.assert_array_equal(np.asarray(jids), ids)
    np.testing.assert_array_equal(np.asarray(jmask), mask)


@pytest.mark.parametrize("spec", SUBLINEAR_SPECS,
                         ids=lambda s: s.describe())
def test_state_structs_match_build_and_wrap(corpus_labels, spec):
    corpus, _ = corpus_labels
    src = spec.build(corpus)
    leaves = jax.tree_util.tree_leaves(src)
    structs = spec.state_structs(corpus.m)
    assert len(leaves) == len(structs)
    for leaf, struct in zip(leaves, structs, strict=True):
        assert leaf.shape == struct.shape, spec.describe()
        assert leaf.dtype == struct.dtype
    rebuilt = spec.wrap(leaves)
    for a, b in zip(leaves, jax.tree_util.tree_leaves(rebuilt),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_refine_is_exact_centroid_topk(corpus_labels):
    """Under ``refine`` the emitted candidates are exactly the
    ``refine`` centroid-nearest of the probed rows, ascending."""
    corpus, _ = corpus_labels
    base = CentroidLSHSpec(n_buckets=8, probes=3, bucket_cap=32)
    refined = dataclasses.replace(base, refine=24)
    q_ids, q_w = corpus.ids[:4], corpus.w[:4]
    raw_ids, raw_mask = base.build(corpus).candidates(corpus, q_ids, q_w)
    ids, mask = refined.build(corpus).candidates(corpus, q_ids, q_w)
    raw_ids, raw_mask = np.asarray(raw_ids), np.asarray(raw_mask)
    ids, mask = np.asarray(ids), np.asarray(mask)
    cents = corpus_centroids(corpus)
    qc = np.einsum("qh,qhm->qm", np.asarray(q_w, np.float32),
                   np.asarray(corpus.coords)[np.asarray(q_ids)])
    for q in range(4):
        live = raw_ids[q][raw_mask[q]]
        d = np.linalg.norm(cents[live] - qc[q], axis=-1)
        want = set(live[np.argsort(d, kind="stable")[:24]].tolist())
        got = ids[q][mask[q]]
        dg = np.linalg.norm(cents[got] - qc[q], axis=-1)
        assert set(got.tolist()) == want
        assert (np.diff(dg) >= -1e-6).all()        # ascending order


def test_cluster_tree_ti_bound_is_admissible(corpus_labels):
    """The CLAMPED bound max(d(q, center) - radius, 0) lower-bounds the
    centroid distance from the query to EVERY row under the node — the
    triangle-inequality pruning invariant."""
    corpus, _ = corpus_labels
    spec = ClusterTreeSpec(branching=4, depth=2, beam=4, probes=4,
                           leaf_cap=None)
    src = spec.build(corpus)
    cents = corpus_centroids(corpus)
    qc = np.einsum("qh,qhm->qm", np.asarray(corpus.w[:6], np.float32),
                   np.asarray(corpus.coords)[np.asarray(corpus.ids[:6])])
    nodes = np.asarray(src.nodes)
    radii = np.asarray(src.radii)
    rows = np.asarray(src.rows)
    mask = np.asarray(src.mask)
    off = cs.cluster_tree._level_offset(spec.branching, spec.depth)
    for leaf in range(spec.n_leaves):
        member = rows[leaf][mask[leaf]]
        if member.size == 0:
            continue
        node = off + leaf
        d = np.linalg.norm(nodes[node] - qc, axis=-1)
        bound = np.maximum(d - radii[node], 0.0)
        true = np.linalg.norm(cents[member][None, :, :]
                              - qc[:, None, :], axis=-1).min(axis=1)
        assert (bound <= true + 1e-5).all()


def test_empty_bucket_sentinel(rng):
    """More buckets than rows: empty buckets keep the far sentinel and
    never show up as masked-valid candidates."""
    corpus, _ = make_text_like(n_docs=10, n_classes=2, vocab=32, m=4,
                               doc_len=6, hmax=8, seed=1)
    spec = CentroidLSHSpec(n_buckets=16, probes=16, bucket_cap=4)
    src = spec.build(corpus)
    cents = np.asarray(src.centroids)
    empty = ~np.asarray(src.mask).any(axis=1)
    assert empty.any()
    assert (cents[empty] == EMPTY_CENTER).all()
    ids, mask = src.candidates(corpus, corpus.ids[:3], corpus.w[:3])
    assert int(np.asarray(mask).sum(axis=1).max()) <= 10


# --------------------------------------------------- cascade integration

def test_sourced_cascade_requires_matching_source(corpus_labels):
    corpus, _ = corpus_labels
    spec = CascadeSpec(stages=(CascadeStage("rwmd", 16),),
                       rescorer="act",
                       source=CentroidLSHSpec(n_buckets=8, probes=2,
                                              bucket_cap=16))
    q_ids, q_w = corpus.ids[:3], corpus.w[:3]
    with pytest.raises(ValueError, match="spec.source.build"):
        cascade.cascade_search(corpus, q_ids, q_w, spec, 4)
    other = CentroidLSHSpec(n_buckets=4, probes=2,
                            bucket_cap=16).build(corpus)
    with pytest.raises(ValueError, match="does not match"):
        cascade.cascade_search(corpus, q_ids, q_w, spec, 4, source=other)
    unsourced = CascadeSpec(stages=(CascadeStage("rwmd", 16),),
                            rescorer="act")
    with pytest.raises(ValueError, match="does not declare"):
        cascade.cascade_search(corpus, q_ids, q_w, unsourced, 4,
                               source=other)


def test_sourced_cascade_recall_and_traffic(corpus_labels):
    """End-to-end: generous probes on the clustered corpus recover most
    of the full cascade's top-l while scoring strictly fewer stage-1
    rows; stage_rows reports the sourced width."""
    corpus, _ = corpus_labels
    q_ids, q_w = corpus.ids[:8], corpus.w[:8]
    full = CascadeSpec(stages=(CascadeStage("wcd", 96),
                               CascadeStage("rwmd", 32)),
                       rescorer="act", rescorer_iters=2)
    ref = cascade.cascade_search(corpus, q_ids, q_w, full, 8)
    spec = CascadeSpec(
        stages=(CascadeStage("rwmd", 32),), rescorer="act",
        rescorer_iters=2,
        source=CentroidLSHSpec(n_buckets=8, probes=4, bucket_cap=48,
                               refine=96))
    src = spec.source.build(corpus)
    got = cascade.cascade_search(corpus, q_ids, q_w, spec, 8, source=src)
    assert cascade.topk_recall(got.indices, ref.indices) >= 0.8
    rows = cascade.stage_rows(spec, corpus.n, 8)
    # stage-1 scores the sourced width (96 probed rows), not the corpus
    assert rows["stage1.rwmd"] == 96
    assert rows["rescore.act"] == 32
    assert spec.source.width == 96 < corpus.n
