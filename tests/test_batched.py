"""Batched multi-query engine vs the per-query scan path.

The batched engine amortizes Phase 1 across the query batch and streams
Phase 2 in query blocks; every registered method must reproduce the
scanned (``lax.map`` of single-query graphs) scores. The same pipeline
stages back the mesh step (``engine="dist"``), tested here on one host.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import EmdIndex, EngineConfig
from repro.core import lc, retrieval
from repro.core.geometry import pairwise_dist
from repro.data.synth import make_text_like


@pytest.fixture(scope="module")
def corpus():
    return make_text_like(n_docs=13, n_classes=4, vocab=96, m=8, doc_len=30,
                          hmax=16, seed=3)


def _assert_close(a, b):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("method", sorted(retrieval.METHODS))
def test_batched_matches_scan(corpus, method):
    c, _ = corpus
    nq = 5
    got = retrieval.batch_scores(c, c.ids[:nq], c.w[:nq], method=method,
                                 engine="batched", iters=2, block_q=2)
    want = retrieval.batch_scores(c, c.ids[:nq], c.w[:nq], method=method,
                                  engine="scan", iters=2)
    assert got.shape == (nq, c.n)
    _assert_close(got, want)


@pytest.mark.parametrize("method", [m for m, s in retrieval.METHODS.items()
                                    if s.supports_kernels])
def test_batched_matches_scan_kernels(corpus, method):
    c, _ = corpus
    nq = 5
    kw = dict(iters=2, use_kernels=True, block_v=32, block_h=8)
    got = retrieval.batch_scores(c, c.ids[:nq], c.w[:nq], method=method,
                                 engine="batched", block_q=2, **kw)
    want = retrieval.batch_scores(c, c.ids[:nq], c.w[:nq], method=method,
                                  engine="scan", **kw)
    _assert_close(got, want)


def test_batched_matches_scan_symmetric(corpus):
    c, _ = corpus
    nq = 6
    got = retrieval.batch_scores(c, c.ids[:nq], c.w[:nq], method="rwmd",
                                 engine="batched", symmetric=True, block_q=4)
    want = retrieval.batch_scores(c, c.ids[:nq], c.w[:nq], method="rwmd",
                                  engine="scan", symmetric=True)
    _assert_close(got, want)


def test_batched_matches_python_loop(corpus):
    """The scan path is the bit-for-bit oracle; the batched path must also
    match a plain Python loop of single-query calls within tolerance."""
    c, _ = corpus
    nq = 4
    got = retrieval.batch_scores(c, c.ids[:nq], c.w[:nq], method="act",
                                 engine="batched", iters=3, block_q=3)
    for u in range(nq):
        want = retrieval.query_scores(c, c.ids[u], c.w[u], method="act",
                                      iters=3)
        _assert_close(got[u], want)


@pytest.mark.parametrize("block_q", [1, 3, 8, 16])
def test_batched_query_block_padding(corpus, block_q):
    """nq not a multiple of block_q: padding queries must not leak."""
    c, _ = corpus
    nq = 5
    got = retrieval.batch_scores(c, c.ids[:nq], c.w[:nq], method="act",
                                 engine="batched", iters=1, block_q=block_q)
    want = retrieval.batch_scores(c, c.ids[:nq], c.w[:nq], method="act",
                                  engine="scan", iters=1)
    assert got.shape == (nq, c.n)
    _assert_close(got, want)


def test_all_pairs_batched_matches_scan(corpus):
    c, _ = corpus
    got = retrieval.all_pairs_scores(c, method="omr", engine="batched",
                                     block_q=4)
    want = retrieval.all_pairs_scores(c, method="omr", engine="scan")
    _assert_close(got, want)


@pytest.mark.parametrize("method", sorted(retrieval.METHODS))
def test_dist_engine_matches_batched_single_host(corpus, method):
    """``engine="dist"`` — the graph the mesh step traces — scores like
    the plain batched engine on a single host (the sharding constraints
    no-op and the mesh-specialized overrides are schedule changes only)."""
    c, _ = corpus
    nq = 5
    got = retrieval.batch_scores(c, c.ids[:nq], c.w[:nq], method=method,
                                 engine="dist", iters=2, block_q=2)
    want = retrieval.batch_scores(c, c.ids[:nq], c.w[:nq], method=method,
                                  engine="batched", iters=2, block_q=2)
    _assert_close(got, want)


def test_dist_engine_symmetric(corpus):
    c, _ = corpus
    got = retrieval.batch_scores(c, c.ids[:4], c.w[:4], method="rwmd",
                                 engine="dist", symmetric=True, block_q=3)
    want = retrieval.batch_scores(c, c.ids[:4], c.w[:4], method="rwmd",
                                  engine="scan", symmetric=True)
    _assert_close(got, want)


def test_symmetric_batched_shares_one_distance_matmul(corpus):
    """The symmetric rwmd engine computes the stacked (v, nq*h) distance
    tensor ONCE and shares it between the two directions (separate
    directional calls each carry their own Phase-1 matmul)."""
    c, _ = corpus
    qi, qw = c.ids[:4], c.w[:4]
    count = lambda f: str(jax.make_jaxpr(f)(qi, qw)).count("dot_general")
    n_sym = count(lambda i, w: lc.lc_rwmd_symmetric_scores_batched(c, i, w))
    n_fwd = count(lambda i, w: lc.lc_rwmd_scores_batched(c, i, w))
    n_rev = count(lambda i, w: lc.lc_rwmd_scores_rev_batched(c, i, w))
    assert n_sym < n_fwd + n_rev


def test_stack_query_bins_dedup():
    """Corpus-as-queries stacks (nq*h >= DEDUP_STACK_RATIO * v) dedup
    repeated vocabulary ids before the Phase-1 matmul; the re-expanded
    distance tensor matches the naive per-slot stacking."""
    rng = np.random.default_rng(0)
    coords = jnp.asarray(rng.normal(size=(8, 3)), jnp.float32)
    Q_ids = jnp.asarray(rng.integers(0, 8, size=(8, 5)), jnp.int32)
    Q_w = jnp.asarray(rng.uniform(0.1, 1.0, size=(8, 5)), jnp.float32)
    qc, inv = lc.stack_query_bins(coords, Q_ids)        # 40 slots >= 4*8
    assert inv is not None and qc.shape == (8, 3)
    D = lc.phase1_stacked_dist(coords, Q_ids, Q_w)
    naive = pairwise_dist(coords,
                          coords[Q_ids.reshape(-1)]).reshape(8, 8, 5)
    np.testing.assert_allclose(np.asarray(D), np.asarray(naive),
                               rtol=1e-6, atol=1e-7)
    # small serving batches skip the dedup sort entirely
    _, inv_small = lc.stack_query_bins(coords, Q_ids[:1])
    assert inv_small is None


@pytest.mark.parametrize("method", ["rwmd", "act", "omr", "rwmd_rev"])
def test_all_pairs_parity_under_dedup(method):
    """All-pairs corpus-as-queries on a small vocabulary crosses the
    dedup gate; the batched engine must still match the scanned
    per-query oracle."""
    c, _ = make_text_like(n_docs=12, n_classes=3, vocab=40, m=6,
                          doc_len=30, hmax=16, seed=7)
    assert c.n * c.hmax >= lc.DEDUP_STACK_RATIO * c.v
    got = retrieval.all_pairs_scores(c, method=method, engine="batched",
                                     iters=2, block_q=5)
    want = retrieval.all_pairs_scores(c, method=method, engine="scan",
                                      iters=2)
    _assert_close(got, want)


def test_batch_scores_rejects_unknown_engine(corpus):
    c, _ = corpus
    with pytest.raises(ValueError, match="unknown engine"):
        retrieval.batch_scores(c, c.ids[:2], c.w[:2], engine="nope")


def test_emdindex_batch_engine_parity(corpus):
    """EngineConfig.batch_engine switches the EmdIndex serving path."""
    c, _ = corpus
    nq = 5
    fast = EmdIndex.build(c, EngineConfig(method="act", iters=2,
                                          batch_engine="batched", block_q=2))
    slow = fast.with_config(batch_engine="scan")
    _assert_close(fast.scores(c.ids[:nq], c.w[:nq]),
                  slow.scores(c.ids[:nq], c.w[:nq]))
    # single-query scoring is engine-independent
    _assert_close(fast.scores(c.ids[0], c.w[0]),
                  slow.scores(c.ids[0], c.w[0]))


def test_emdindex_rejects_bad_batch_engine():
    with pytest.raises(ValueError, match="batch_engine"):
        EngineConfig(batch_engine="vmap")


# ---------------------------------------------------------------- top-k

@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("chunk", [512, 8, 3])
@pytest.mark.parametrize("shape", [(40, 17), (3, 9, 21), (64, 5)])
def test_streaming_topk_matches_smallest_k(seed, shape, chunk):
    """Single-pass streaming selection == k-rescan smallest_k, including
    under heavy ties (values quantized to one decimal): ties resolve to
    the lowest column index in both. chunk < h exercises the streamed
    tile-merge path (chunk=512 is the single-tile degenerate case)."""
    r = np.random.default_rng(seed)
    k = int(r.integers(1, min(9, shape[-1]) + 1))
    d = jnp.asarray(np.round(r.normal(size=shape), 1), jnp.float32)
    z1, s1 = lc.smallest_k(d, k)
    z2, s2 = lc.streaming_smallest_k(d, k, chunk=chunk)
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_streaming_topk_handles_pad_dist_columns():
    """PAD_DIST (masked query bin) columns never displace real bins, and
    the degenerate exhausted-row behavior (re-picking the lowest masked
    column once only PAD_DIST values remain) matches smallest_k exactly."""
    d = jnp.asarray([[1.0, lc.PAD_DIST, lc.PAD_DIST, 0.5]], jnp.float32)
    for chunk in (512, 2):
        z, s = lc.streaming_smallest_k(d, 3, chunk=chunk)
        zr, sr = lc.smallest_k(d, 3)
        np.testing.assert_array_equal(np.asarray(z), np.asarray(zr))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))
        np.testing.assert_allclose(np.asarray(z[0]), [0.5, 1.0, lc.PAD_DIST])
        np.testing.assert_array_equal(np.asarray(s[0][:2]), [3, 0])
