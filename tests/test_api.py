"""The unified serving API: EmdIndex over reference / Pallas / distributed
engines, EngineConfig validation, and the typed method registry."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.api import EmdIndex, EngineConfig, METHODS
from repro.core import lc, retrieval
from repro.data.synth import make_text_like


@pytest.fixture(scope="module")
def corpus_labels():
    # doc_len < hmax so every histogram row has zero-weight padded slots —
    # queries drawn from the corpus exercise query-side padding too.
    return make_text_like(n_docs=24, n_classes=4, vocab=128, m=8,
                          doc_len=10, hmax=16, seed=3)


def _backends(method="act", iters=2, **kw):
    return [EngineConfig(method=method, iters=iters, backend=b,
                         pad_multiple=16, top_l=5, **kw)
            for b in ("reference", "pallas", "distributed")]


def test_cross_backend_top_l_parity(corpus_labels):
    """Acceptance: reference, pallas, and distributed (single-device mesh)
    produce identical top-l results."""
    corpus, _ = corpus_labels
    q_ids, q_w = corpus.ids[:6], corpus.w[:6]
    results = []
    for cfg in _backends():
        index = EmdIndex.build(corpus, cfg)
        scores, idx = index.search(q_ids, q_w)
        results.append((np.asarray(scores), np.asarray(idx)))
    (s_ref, i_ref), (s_pal, i_pal), (s_dst, i_dst) = results
    np.testing.assert_array_equal(i_ref, i_pal)
    np.testing.assert_array_equal(i_ref, i_dst)
    np.testing.assert_allclose(s_ref, s_pal, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(s_ref, s_dst, rtol=1e-5, atol=1e-6)


def test_cross_backend_all_pairs_parity(corpus_labels):
    corpus, _ = corpus_labels
    mats = [np.asarray(EmdIndex.build(corpus, cfg).all_pairs())
            for cfg in _backends(method="rwmd", iters=0)]
    np.testing.assert_allclose(mats[0], mats[1], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(mats[0], mats[2], rtol=1e-5, atol=1e-6)
    # symmetric by construction
    np.testing.assert_array_equal(mats[0], mats[0].T)


@pytest.mark.parametrize("method,iters,single_fn", [
    ("act", 3, lambda c, qi, qw: lc.lc_act_scores(c, qi, qw, iters=3)),
    ("rwmd", 0, lc.lc_rwmd_scores),
])
def test_batched_scores_bit_for_bit(corpus_labels, method, iters, single_fn):
    """(nq, h) through EmdIndex.scores with ``batch_engine="scan"`` == a
    Python loop of single-query engine calls, bit-for-bit, including
    padded query slots; the default batched engine is allclose."""
    corpus, _ = corpus_labels
    nq = 7
    q_ids, q_w = corpus.ids[:nq], corpus.w[:nq]
    assert bool((np.asarray(q_w) == 0.0).any()), "want padded query slots"
    index = EmdIndex.build(corpus, EngineConfig(method=method, iters=iters,
                                                batch_engine="scan"))
    scanned = np.asarray(index.scores(q_ids, q_w))
    assert scanned.shape == (nq, corpus.n)
    looped = np.stack([np.asarray(single_fn(corpus, q_ids[u], q_w[u]))
                       for u in range(nq)])
    np.testing.assert_array_equal(scanned, looped)
    batched = np.asarray(index.with_config(batch_engine="batched")
                         .scores(q_ids, q_w))
    np.testing.assert_allclose(batched, looped, rtol=1e-5, atol=1e-6)


def test_single_and_batch_shapes_uniform(corpus_labels):
    corpus, _ = corpus_labels
    for cfg in _backends():
        index = EmdIndex.build(corpus, cfg)
        s1 = index.scores(corpus.ids[0], corpus.w[0])
        sb = index.scores(corpus.ids[:3], corpus.w[:3])
        assert s1.shape == (corpus.n,)
        assert sb.shape == (3, corpus.n)
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(sb[0]))
        t1, i1 = index.search(corpus.ids[0], corpus.w[0], top_l=4)
        tb, ib = index.search(corpus.ids[:3], corpus.w[:3], top_l=4)
        assert t1.shape == (4,) and ib.shape == (3, 4)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(ib[0]))


def test_symmetric_single_query_path(corpus_labels):
    """Paper's symmetric measure per query: max of the two directions."""
    corpus, _ = corpus_labels
    index = EmdIndex.build(corpus, EngineConfig(method="rwmd",
                                                symmetric=True))
    got = np.asarray(index.scores(corpus.ids[2], corpus.w[2]))
    fwd = np.asarray(lc.lc_rwmd_scores(corpus, corpus.ids[2], corpus.w[2]))
    rev = np.asarray(lc.lc_rwmd_scores_rev(corpus, corpus.ids[2],
                                           corpus.w[2]))
    np.testing.assert_array_equal(got, np.maximum(fwd, rev))
    # the symmetric single-query column matches the all-pairs matrix row
    S = np.asarray(retrieval.all_pairs_scores(corpus, method="rwmd"))
    np.testing.assert_allclose(got, S[2], rtol=1e-5, atol=1e-6)


def test_rwmd_rev_registered_and_linked():
    assert "rwmd_rev" in METHODS
    assert METHODS["rwmd"].reverse == "rwmd_rev"
    assert METHODS["rwmd_rev"].reverse == "rwmd"
    assert METHODS["act"].uses_iters and METHODS["act"].supports_kernels
    assert METHODS["bow"].symmetric and METHODS["wcd"].symmetric


def test_rwmd_rev_all_pairs_is_transpose_direction(corpus_labels):
    corpus, _ = corpus_labels
    fwd = np.stack([np.asarray(lc.lc_rwmd_scores(corpus, corpus.ids[u],
                                                 corpus.w[u]))
                    for u in range(corpus.n)])
    rev = np.asarray(retrieval.batch_scores(corpus, corpus.ids, corpus.w,
                                            method="rwmd_rev"))
    np.testing.assert_allclose(rev, fwd.T, rtol=1e-5, atol=1e-6)


def test_search_jittable_end_to_end(corpus_labels):
    """retrieval.search composes under an outer jit (static dispatch, no
    per-call retracing of the method table)."""
    corpus, _ = corpus_labels

    @jax.jit
    def nested(c, qi, qw):
        s, i = retrieval.search(c, qi, qw, top_l=3, method="omr")
        return s + 0.0, i
    s, i = nested(corpus, corpus.ids[1], corpus.w[1])
    ref = np.asarray(lc.lc_omr_scores(corpus, corpus.ids[1], corpus.w[1]))
    np.testing.assert_allclose(np.asarray(s), np.sort(ref)[:3],
                               rtol=1e-5, atol=1e-6)


def test_kernel_block_kwargs_thread_through(corpus_labels):
    """use_kernels/block kwargs are honored by every kernel-capable
    method, not only ACT."""
    corpus, _ = corpus_labels
    for method in ("rwmd", "omr", "act"):
        a = retrieval.query_scores(corpus, corpus.ids[4], corpus.w[4],
                                   method=method, use_kernels=False)
        b = retrieval.query_scores(corpus, corpus.ids[4], corpus.w[4],
                                   method=method, use_kernels=True,
                                   block_v=32, block_h=16, block_n=16)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_engine_config_validation():
    with pytest.raises(ValueError, match="unknown method"):
        EngineConfig(method="nope")
    with pytest.raises(ValueError, match="unknown backend"):
        EngineConfig(backend="gpu")
    with pytest.raises(ValueError, match="iters"):
        EngineConfig(iters=-1)
    with pytest.raises(ValueError, match="reverse"):
        EngineConfig(method="act", symmetric=True)
    # the mesh step is registry-derived: every method (and the symmetric
    # measure) is a valid distributed config now
    for method in METHODS:
        assert EngineConfig(method=method,
                            backend="distributed").method == method
    assert EngineConfig(method="rwmd", symmetric=True,
                        backend="distributed").symmetric
    assert isinstance(EngineConfig(), EngineConfig)
    # frozen + hashable (usable as a jit-cache key)
    cfg = EngineConfig()
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.iters = 3
    assert hash(cfg) == hash(EngineConfig())


def test_distributable_methods_covers_registry():
    from repro.api import DISTRIBUTABLE_METHODS
    assert tuple(sorted(METHODS)) == DISTRIBUTABLE_METHODS


def test_scores_shardings_honor_dist_out(monkeypatch):
    """MethodSpec.dist_out drives the distributed step's output layout:
    "data" resolves to the mesh's DP axes, other entries pass through."""
    from jax.sharding import PartitionSpec as P
    from repro.launch import search as dsearch
    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh(1, 1)
    _, out = dsearch.scores_shardings(mesh, None, method="act")
    assert out.spec == P("data", "model")
    hinted = dataclasses.replace(METHODS["wcd"], dist_out=("data", None))
    monkeypatch.setitem(retrieval.METHODS, "wcd_hinted", hinted)
    _, out = dsearch.scores_shardings(mesh, None, method="wcd_hinted")
    assert out.spec == P("data", None)


@pytest.mark.parametrize("method", sorted(METHODS))
def test_every_method_distributed_parity_single_device(corpus_labels,
                                                       method):
    """Acceptance: EmdIndex(backend="distributed") serves EVERY registered
    method, scoring within tolerance of the single-host batched engine —
    here on the default single-device mesh (the multi-device version runs
    in tests/test_distributed.py), with pad rows present and a block_q
    that does not divide the query count."""
    corpus, _ = corpus_labels
    nq = 5
    cfg = EngineConfig(method=method, iters=2, backend="distributed",
                       pad_multiple=16, block_q=3)
    dst = EmdIndex.build(corpus, cfg)
    assert dst._padded_corpus.n > corpus.n          # pad rows in play
    ref = EmdIndex.build(corpus, dataclasses.replace(cfg,
                                                     backend="reference"))
    s_dst = np.asarray(dst.scores(corpus.ids[:nq], corpus.w[:nq]))
    s_ref = np.asarray(ref.scores(corpus.ids[:nq], corpus.w[:nq]))
    np.testing.assert_allclose(s_dst, s_ref, rtol=1e-5, atol=1e-6)


def test_symmetric_distributed_matches_reference(corpus_labels):
    """The paper's symmetric measure now runs on the mesh path too."""
    corpus, _ = corpus_labels
    cfg = EngineConfig(method="rwmd", symmetric=True, backend="distributed",
                       pad_multiple=16)
    got = np.asarray(EmdIndex.build(corpus, cfg)
                     .scores(corpus.ids[:4], corpus.w[:4]))
    want = np.asarray(EmdIndex.build(
        corpus, dataclasses.replace(cfg, backend="reference"))
        .scores(corpus.ids[:4], corpus.w[:4]))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_distributed_pad_rows_masked_in_search(corpus_labels):
    """Zero-weight pad rows score 0; they must never appear in top-l."""
    corpus, _ = corpus_labels
    index = EmdIndex.build(corpus, EngineConfig(
        method="act", iters=1, backend="distributed", pad_multiple=64))
    assert index._padded_corpus.n == 64 > corpus.n
    _, idx = index.search(corpus.ids[:4], corpus.w[:4], top_l=8)
    assert int(np.asarray(idx).max()) < corpus.n


def test_with_config_rebuild(corpus_labels):
    corpus, _ = corpus_labels
    index = EmdIndex.build(corpus, EngineConfig(method="act", iters=1))
    moved = index.with_config(iters=3)
    assert moved.config.iters == 3 and moved.config.method == "act"
    ref = lc.lc_act_scores(corpus, corpus.ids[0], corpus.w[0], iters=3)
    np.testing.assert_array_equal(
        np.asarray(moved.scores(corpus.ids[0], corpus.w[0])),
        np.asarray(ref))


def test_scores_rejects_mismatched_shapes(corpus_labels):
    corpus, _ = corpus_labels
    index = EmdIndex.build(corpus, EngineConfig())
    with pytest.raises(ValueError, match="queries"):
        index.scores(corpus.ids[:2], corpus.w[:3])
    with pytest.raises(ValueError, match="queries"):
        index.scores(corpus.ids[None, :2], corpus.w[None, None, :2])
