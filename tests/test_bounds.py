"""Property tests of the paper's theorems (hypothesis).

Theorem 2: RWMD <= OMR <= ACT-1 <= ACT-k <= ICT <= EMD.
Theorem 1: ICT == optimum of the relaxation {(1),(2),(4)}.
Theorem 3: with an effective cost (C_ij = 0 iff i == j), OMR(p,q)=0 => p=q.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core import (act, emd_exact, ict, l1_normalize, omr,
                        pairwise_dist, rwmd, sinkhorn_cost)
from repro.core.relaxations import act_dir, ict_dir

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def _histo_pair(draw, overlap: bool):
    hp = draw(st.integers(2, 8))
    hq = draw(st.integers(2, 8))
    m = draw(st.integers(1, 4))
    seed = draw(st.integers(0, 2**31 - 1))
    r = np.random.default_rng(seed)
    P = r.normal(size=(hp, m))
    Q = r.normal(size=(hq, m))
    if overlap and hq >= 2:
        Q[0] = P[0]                      # force a zero-cost overlap
    p = l1_normalize(jnp.asarray(r.uniform(0.05, 1.0, hp), jnp.float32))
    q = l1_normalize(jnp.asarray(r.uniform(0.05, 1.0, hq), jnp.float32))
    C = pairwise_dist(jnp.asarray(P, jnp.float32), jnp.asarray(Q, jnp.float32))
    return p, q, C


@given(st.data(), st.booleans())
def test_theorem2_chain(data, overlap):
    p, q, C = _histo_pair(data.draw, overlap)
    vals = [
        float(rwmd(p, q, C)),
        float(omr(p, q, C)),
        float(act(p, q, C, iters=1)),
        float(act(p, q, C, iters=3)),
        float(ict(p, q, C)),
        emd_exact(p, q, C),
    ]
    for lo, hi in zip(vals, vals[1:], strict=False):
        assert lo <= hi + 1e-5, vals


@given(st.data())
def test_ict_optimal_for_relaxation(data):
    """Brute-force check of Theorem 1 on tiny instances: no feasible flow of
    the relaxed LP beats Algorithm 2 (sampled feasible flows)."""
    p, q, C = _histo_pair(data.draw, overlap=False)
    ict_val = float(ict_dir(p, q, C))
    r = np.random.default_rng(0)
    pn, qn, Cn = np.asarray(p), np.asarray(q), np.asarray(C)
    for _ in range(50):
        # random feasible flow: each row i pours p_i greedily in a random
        # destination order under capacity q_j (satisfies (2) and (4))
        total = 0.0
        for i in range(len(pn)):
            rem = pn[i]
            for j in r.permutation(len(qn)):
                move = min(rem, qn[j])
                total += move * Cn[i, j]
                rem -= move
                if rem <= 1e-12:
                    break
        assert ict_val <= total + 1e-5


@given(st.data())
def test_sinkhorn_upper_bounds_relaxations(data):
    p, q, C = _histo_pair(data.draw, overlap=False)
    sk = float(sinkhorn_cost(p, q, C, lam=50.0, n_iters=400))
    assert float(ict(p, q, C)) <= sk + 5e-3


@given(st.integers(2, 8), st.integers(0, 2**31 - 1))
def test_theorem3_omr_effective(h, seed):
    """Distinct coordinates (effective cost) and p != q  =>  OMR > 0,
    and OMR(p, p) == 0.

    Theorem 3's premise is an EFFECTIVE cost (C_ij = 0 iff i = j); with the
    float ZERO_SNAP (core/geometry.py) that means coordinates must be
    separated by more than the snap radius — enforced here, as it would be
    by any dedup preprocessing in production."""
    from hypothesis import assume
    from repro.core.geometry import ZERO_SNAP
    r = np.random.default_rng(seed)
    coords = r.normal(size=(h, 3))
    d2 = np.sum((coords[:, None] - coords[None, :]) ** 2, -1)
    np.fill_diagonal(d2, np.inf)
    scale = 2.0 * np.max(np.sum(coords ** 2, -1))
    assume(d2.min() > (2 * ZERO_SNAP) ** 2 * scale)
    C = pairwise_dist(jnp.asarray(coords, jnp.float32),
                      jnp.asarray(coords, jnp.float32))
    p = l1_normalize(jnp.asarray(r.uniform(0.05, 1.0, h), jnp.float32))
    q = l1_normalize(jnp.asarray(r.uniform(0.05, 1.0, h), jnp.float32))
    assert float(omr(p, p, C)) <= 1e-7
    if float(jnp.max(jnp.abs(p - q))) > 1e-4:
        assert float(omr(p, q, C)) > 0.0
    # RWMD does NOT share this property (full overlap -> always 0)
    assert float(rwmd(p, q, C)) <= 1e-7


@given(st.data())
def test_symmetry(data):
    p, q, C = _histo_pair(data.draw, overlap=True)
    for fn in (rwmd, omr, ict):
        assert abs(float(fn(p, q, C)) - float(fn(q, p, C.T))) < 1e-6
    assert abs(float(act(p, q, C, iters=2))
               - float(act(q, p, C.T, iters=2))) < 1e-6


@given(st.data(), st.integers(0, 4))
def test_act_monotone_in_iters(data, base):
    p, q, C = _histo_pair(data.draw, overlap=True)
    a = float(act_dir(p, q, C, iters=base))
    b = float(act_dir(p, q, C, iters=base + 1))
    assert a <= b + 1e-6
