"""Per-architecture smoke tests (reduced configs, CPU) + consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models import model as M
from repro.models.config import SHAPES, cells_for, LONG_CONTEXT_ARCHS

B, S = 2, 16


def _batch(cfg, rng):
    if cfg.frontend != "none":
        return {"embeddings": jax.random.normal(rng, (B, S, cfg.d_model)),
                "labels": jnp.zeros((B, S), jnp.int32)}
    return {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab),
            "labels": jnp.zeros((B, S), jnp.int32)}


@pytest.mark.parametrize("name", ARCH_IDS)
def test_smoke_forward_train_decode(name):
    cfg = smoke_config(name)
    rng = jax.random.PRNGKey(0)
    params = M.init(rng, cfg)
    batch = _batch(cfg, rng)
    loss = M.train_loss(params, batch, cfg)
    assert np.isfinite(float(loss)), name
    logits, aux, _ = M.forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # prefill + one decode step
    pl, cache = M.prefill(params, batch, cfg)
    assert pl.shape == (B, 1, cfg.vocab)
    dc = M.init_decode_cache(cfg, B, S, dtype=jnp.float32)
    db = {"cache_index": jnp.int32(S - 1)}
    if cfg.frontend != "none":
        db["embeddings"] = jax.random.normal(rng, (B, 1, cfg.d_model))
    else:
        db["tokens"] = jnp.zeros((B, 1), jnp.int32)
    dl, _ = M.decode_step(params, db, dc, cfg)
    assert dl.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(dl, np.float32)).all()


@pytest.mark.parametrize("name", ARCH_IDS)
def test_param_count_matches_init(name):
    cfg = get_config(name)
    shapes = jax.eval_shape(lambda: M.init(jax.random.PRNGKey(0), cfg))
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    assert n == cfg.param_count(), (name, n, cfg.param_count())


@pytest.mark.parametrize("name", ["olmo-1b", "mamba2-2.7b", "zamba2-2.7b",
                                  "gemma3-27b"])
def test_decode_matches_full_forward(name):
    cfg = smoke_config(name)
    rng = jax.random.PRNGKey(1)
    params = M.init(rng, cfg)
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    full, _, _ = M.forward(params, {"tokens": toks}, cfg)
    cache = M.init_decode_cache(cfg, B, S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        dl, cache = M.decode_step(
            params, {"tokens": toks[:, t:t + 1],
                     "cache_index": jnp.int32(t)}, cache, cfg)
        outs.append(dl)
    err = float(jnp.max(jnp.abs(jnp.concatenate(outs, 1) - full)))
    assert err < 2e-2, (name, err)


def test_sliding_window_schedule_gemma():
    cfg = get_config("gemma3-27b")
    ws = np.asarray(M.window_schedule(cfg))
    assert ws.shape == (62,)
    assert (ws[5::6] == 0).all()              # every 6th layer global
    assert (np.delete(ws, np.arange(5, 62, 6)) == 1024).all()


def test_window_changes_output():
    """A local window must actually mask long-range attention."""
    import dataclasses
    cfg = smoke_config("gemma3-27b")
    cfg_nw = dataclasses.replace(cfg, sliding_window=0, local_global_ratio=0)
    rng = jax.random.PRNGKey(2)
    params = M.init(rng, cfg)
    toks = jax.random.randint(rng, (1, 16), 0, cfg.vocab)
    a, _, _ = M.forward(params, {"tokens": toks}, cfg)
    b, _, _ = M.forward(params, {"tokens": toks}, cfg_nw)
    assert float(jnp.max(jnp.abs(a - b))) > 1e-6


def test_moe_router_load_balance_loss_positive():
    cfg = smoke_config("mixtral-8x22b")
    rng = jax.random.PRNGKey(3)
    params = M.init(rng, cfg)
    _, aux, _ = M.forward(params, _batch(cfg, rng), cfg)
    assert float(aux) > 0.0


def test_long_context_assignment():
    assert LONG_CONTEXT_ARCHS == {"mamba2-2.7b", "zamba2-2.7b", "gemma3-27b"}
    assert "long_500k" in cells_for("mamba2-2.7b")
    assert "long_500k" not in cells_for("nemotron-4-340b")
    total = sum(len(cells_for(a)) for a in ARCH_IDS)
    assert total == 33
    assert SHAPES["long_500k"].kind == "decode"
