"""Static-analysis suite tests: each checker pass must (a) run clean on
the repo as it stands and (b) reject a seeded violation of exactly the
invariant it guards. The collective-contract pass needs the 8-device
mesh and lives in tests/test_distributed.py; everything here runs
in-process on one device."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import bench_check, hazards, registry_lint, vmem
from repro.analysis.hlo_collectives import collective_bytes
from repro.analysis.jaxpr_cost import iter_eqns
from repro.cascade import spec as cspec
from repro.core.retrieval import METHODS
from repro.kernels import ops
from repro.launch import search as S


# ---------------------------------------------------------------- registry

def test_registry_lint_clean():
    violations, checked = registry_lint.run()
    assert violations == []
    assert checked > 0


def test_bound_table_rejects_missing_reflexivity():
    def rel(m, i, r, ri):
        if (m, i) == (r, ri) == ("ict", 0):
            return False
        return cspec.is_lower_bound(m, i, r, ri)
    out = registry_lint.check_bound_table(rel)
    assert any("reflexive" in v.message for v in out)


def test_bound_table_rejects_inconsistent_chain_edge():
    # Seed the inverted edge OMR <= RWMD: with RWMD <= OMR still present
    # the pair becomes mutually bounding (antisymmetry breaks), exactly
    # what an accidental tightness-table flip would produce.
    def rel(m, i, r, ri):
        if (m, r) == ("omr", "rwmd"):
            return True
        return cspec.is_lower_bound(m, i, r, ri)
    out = registry_lint.check_bound_table(rel)
    assert any("antisymmetric" in v.message for v in out)


def test_bound_table_rejects_emd_only_bound_in_chain():
    # wcd admitted under an act rescorer would wrongly mark the 'fast'
    # preset admissible.
    def rel(m, i, r, ri):
        if m == "wcd" and r == "act":
            return True
        return cspec.is_lower_bound(m, i, r, ri)
    out = registry_lint.check_bound_table(rel)
    assert any("EMD-only" in v.message for v in out)


def test_method_specs_reject_asymmetric_reverse_link():
    methods = dict(METHODS)
    methods["rwmd"] = dataclasses.replace(METHODS["rwmd"], reverse="omr")
    out = registry_lint.check_method_specs(methods)
    assert any("not symmetric" in v.message for v in out)


def test_method_specs_reject_dead_dist_fn():
    methods = dict(METHODS)
    methods["bow"] = dataclasses.replace(
        METHODS["bow"], dist_fn=METHODS["bow"].fn, batch_fn=None)
    out = registry_lint.check_method_specs(methods)
    assert any("dead code" in v.message for v in out)


def test_presets_reject_admissibility_drift():
    declared = dict(cspec.PRESET_ADMISSIBLE, fast=True)   # wcd stage lies
    out = registry_lint.check_cascade_presets(declared=declared)
    assert any("contradicts" in v.message for v in out)


def test_presets_reject_key_drift():
    declared = dict(cspec.PRESET_ADMISSIBLE)
    declared.pop("tight")
    out = registry_lint.check_cascade_presets(declared=declared)
    assert any("out of sync" in v.message for v in out)


# ----------------------------------------------------------------- hazards

def _specs():
    from repro.analysis.collectives_check import check_workload
    return S.search_input_specs(check_workload(), pad_multiple=8)


def test_hazards_clean_on_all_registry_steps():
    violations, checked = hazards.run()
    assert violations == []
    assert checked == len(S.step_cases())


def test_hazards_flag_host_callback():
    def bad(ids, w, coords, q_ids, q_w):
        s = jnp.sum(w) + jnp.sum(q_w)
        return jax.pure_callback(
            lambda x: np.asarray(x), jax.ShapeDtypeStruct((), jnp.float32),
            s)
    out = hazards.check_fn("seeded", bad, _specs())
    assert any("callback" in v.message for v in out)


def test_hazards_flag_float64_promotion():
    def bad(ids, w, coords, q_ids, q_w):
        return jnp.sum(w) * np.float64(2.0)   # f64 under x64 tracing
    out = hazards.check_fn("seeded", bad, _specs())
    assert any("promotion" in v.message for v in out)


def test_hazards_flag_oversized_constant():
    baked = jnp.zeros((512, 1024), jnp.float32)           # 2 MiB
    def bad(ids, w, coords, q_ids, q_w):
        return jnp.sum(w) + jnp.sum(baked)
    out = hazards.check_fn("seeded", bad, _specs())
    assert any("captured constant" in v.message for v in out)
    # A generous budget accepts the same constant.
    assert hazards.check_fn("seeded", bad, _specs(),
                            max_const_bytes=4 << 20) == []


def test_hazards_run_reports_injected_fn():
    def bad(ids, w, coords, q_ids, q_w):
        return jnp.sum(w) * np.float64(2.0)
    violations, checked = hazards.run(extra_fns={"injected": bad})
    assert checked == len(S.step_cases()) + 1
    assert [v for v in violations if v.subject == "injected"]


# -------------------------------------------------------------------- vmem

def test_vmem_clean_on_checked_profiles():
    violations, checked = vmem.run()
    assert violations == []
    assert checked == len(vmem.check_configs())


def test_vmem_rejects_over_budget_blocks():
    out = vmem.check_launch(
        "seeded", "cand_pour",
        dict(nq=8, b=4096, h=500, v=69_682, k=8, iters=7,
             block_n=256, block_v=256))
    assert any("exceeds" in v.message for v in out)


def test_vmem_rejects_invalid_config():
    out = vmem.check_launch("seeded", "dist_topk",
                            dict(nq=8, v=0, h=64, m=32, k=8))
    assert any("invalid launch config" in v.message for v in out)
    out = vmem.check_launch("seeded", "nope", dict())
    assert any("invalid launch config" in v.message for v in out)


def test_vmem_budget_is_configurable():
    label, family, dims = vmem.check_configs()[0]
    assert vmem.check_launch(label, family, dims) == []
    out = vmem.check_launch(label, family, dims, budget_bytes=1024)
    assert any("exceeds" in v.message for v in out)


def test_block_layout_mirrors_wrapper_clamps():
    # Blocks larger than the (padded) dims clamp exactly like the
    # wrappers: v=10 pads to 16, so block_v=256 -> 16 and one grid step.
    layout = ops.block_layout("dist_topk", nq=2, v=10, h=12, m=4, k=3)
    assert layout.grid == (2, 1, 1)
    assert layout.buffer("coords").shape == (16, 4)
    assert layout.buffer("z").shape == (1, 16, 3)


def test_block_layout_act_ladder_widths():
    layout = ops.block_layout("act_phase2", nq=2, n=64, h=32, iters=3)
    assert layout.buffer("zg").shape[-1] == 4          # iters + 1
    assert layout.buffer("wg").shape[-1] == 3          # iters
    cand = ops.block_layout("act_phase2_cand", nq=2, n=64, h=32, iters=3)
    assert cand.buffer("x").shape == (1, 64, 32)       # per-query gather


def test_vmem_counts_pipelined_buffers_twice():
    layout = ops.block_layout("dist_topk", nq=2, v=64, h=64, m=8, k=4)
    manual = sum(b.nbytes * (1 if b.role == "scratch" else 2)
                 for b in layout.buffers)
    assert layout.vmem_bytes() == manual
    assert layout.vmem_bytes(pipeline_depth=1) < manual


# ------------------------------------------------------- hlo_collectives

_RING_HLO = """\
HloModule ring

ENTRY %main (p0: f32[32]) -> f32[32] {
  %p0 = f32[32]{0} parameter(0)
  %ag = f32[32]{0} all-gather(f32[8]{0} %p0), replica_groups=[2,4], dimensions={0}
  %ar = f32[32]{0} all-reduce(f32[32]{0} %ag), replica_groups=[1,8], to_apply=%add
  %rs = f32[4]{0} reduce-scatter(f32[32]{0} %ar), replica_groups=[1,8], dimensions={0}
  ROOT %cp = f32[4]{0} collective-permute(f32[4]{0} %rs), source_target_pairs={{0,1}}
}
"""


def test_ring_wire_byte_model():
    got = collective_bytes(_RING_HLO, 8)
    # all-gather: result 32*4 bytes, g=4, 2 groups -> R*(g-1)*groups
    assert got["all-gather"] == 128 * 3 * 2
    # all-reduce: 2*R*(g-1)*groups with g=8, one group
    assert got["all-reduce"] == 2 * 128 * 7
    # reduce-scatter: operand = result*g -> R*g*(g-1)*groups
    assert got["reduce-scatter"] == 16 * 8 * 7
    # collective-permute: R * participants
    assert got["collective-permute"] == 16 * 8


_WHILE_HLO = """\
HloModule looped

%body (p: f32[16]) -> f32[16] {
  %p = f32[16]{0} parameter(0)
  ROOT %ag = f32[16]{0} all-gather(f32[4]{0} %p), replica_groups=[1,4], dimensions={0}
}

%cond (p: f32[16]) -> pred[] {
  %p = f32[16]{0} parameter(0)
  %limit = s32[] constant(5)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %limit), direction=LT
}

ENTRY %main (p0: f32[16]) -> f32[16] {
  %p0 = f32[16]{0} parameter(0)
  ROOT %w = f32[16]{0} while(f32[16]{0} %p0), condition=%cond, body=%body
}
"""


def test_while_trip_count_multiplies_body_collectives():
    got = collective_bytes(_WHILE_HLO, 4)
    # body all-gather wire = 64*(4-1) = 192, times the trip count 5.
    assert got["all-gather"] == 192 * 5


def test_while_without_recovered_trip_count_counts_once():
    hlo = _WHILE_HLO.replace("constant(5)", "parameter(1)")
    got = collective_bytes(hlo, 4)
    assert got["all-gather"] == 192


# ------------------------------------------------------------ jaxpr walk

def test_iter_eqns_descends_into_scan_and_while():
    def fn(x):
        def body(c, _):
            return c * 2.0, c
        c, _ = jax.lax.scan(body, x, None, length=3)
        return jax.lax.while_loop(lambda v: jnp.sum(v) < 10.0,
                                  lambda v: v + 1.0, c)
    closed = jax.make_jaxpr(fn)(jnp.ones((4,)))
    prims = {e.primitive.name for e in iter_eqns(closed.jaxpr)}
    assert "scan" in prims and "while" in prims
    assert "mul" in prims        # inside the scan body
    assert "add" in prims        # inside the while body


# ----------------------------------------------------------- step registry

def test_step_cases_unique_and_cover_registry():
    cases = S.step_cases()
    names = [c.name for c in cases]
    assert len(names) == len(set(names))
    methods = {c.method for c in cases if c.kind == "scores"}
    assert methods == set(METHODS)
    assert all(c.engine == "dist"
               for c in cases if c.kind == "cascade")
    guarded = {c.name for c in cases if c.scale_guarded}
    assert "cascade:pinned:dist" in guarded
    assert "search:act:dist" not in guarded      # top_k gathers by design


def test_pinned_cascade_case_is_admissible_with_absolute_budgets():
    case = {c.name: c for c in S.step_cases()}["cascade:pinned:dist"]
    assert case.cascade.admissible
    assert all(isinstance(s.budget, int) for s in case.cascade.stages)


def test_build_step_rejects_unknown_kind():
    case = S.StepCase("bad", "nope", "act", "dist")
    with pytest.raises(AssertionError):
        S.build_step(case, None)


# ------------------------------------------------------------------ bench

def _valid_precision_sweep():
    return {"entries": [
        {"policy": "f32", "recall_delta_vs_f32": 0.0,
         "handoff_bytes_per_row": 28, "queries_per_sec": 10.0},
        {"policy": "bf16", "recall_delta_vs_f32": 0.002,
         "handoff_bytes_per_row": 14, "queries_per_sec": 19.0},
        {"policy": "bf16_agg", "recall_delta_vs_f32": 0.02,
         "handoff_bytes_per_row": 14, "queries_per_sec": 19.0},
    ]}


def test_bench_check_clean_on_valid_artifacts(tmp_path):
    provenance = {"device_kind": "cpu",
                  "autotune": {"mode": "cached", "tune_cache": None,
                               "tuned_blocks": {"cand_dist": {"block_n": 2}}}}
    batch = tmp_path / "b.json"
    batch.write_text(json.dumps({"entries": [
        {"engine": "batched", "queries_per_sec": 10.0},
        {"engine": "distributed", "queries_per_sec": 5.0},
    ], "precision_sweep": _valid_precision_sweep(), **provenance}))
    cascade = tmp_path / "c.json"
    cascade.write_text(json.dumps({
        "precision_sweep": _valid_precision_sweep(),
        "entries": [
            {"recall_at_l": 1.0, "queries_per_sec": 9.0,
             "use_kernels": False},
            {"recall_at_l": 0.97, "queries_per_sec": 12.0,
             "use_kernels": True},
        ],
        "distributed_step": {"recall_at_l": 1.0, "queries_per_sec": 4.0},
        "smoke": False,
        "sweep": [
            {"n": 4096, "entries": [
                {"source": "full_scan", "recall_at_l": 1.0,
                 "queries_per_sec": 5.0},
                {"source": "centroid_lsh", "recall_at_l": 0.95,
                 "queries_per_sec": 20.0},
            ]},
        ],
        **provenance,
    }))
    serve = tmp_path / "s.json"
    serve.write_text(json.dumps(_valid_serve()))
    violations, checked = bench_check.run(batch_path=str(batch),
                                          cascade_path=str(cascade),
                                          serve_path=str(serve))
    assert violations == []
    assert checked == 3


def _valid_serve():
    return {
        "entries": [
            {"offered_qps": 50.0, "n_requests": 8, "completed": 8,
             "served": 8, "shed": 0, "p50_ms": 3.0, "p99_ms": 9.0,
             "tier_mix": {"primary": 8}},
        ],
        "chaos": {"n_requests": 8, "completed": 8, "shed": 1,
                  "tier_mix": {"primary": 5, "wcd": 2, "SHED": 1},
                  "deterministic": True},
    }


def test_bench_check_rejects_seeded_defects(tmp_path):
    batch = tmp_path / "b.json"
    batch.write_text(json.dumps({"entries": [
        {"engine": "batched", "queries_per_sec": 10.0}]}))
    cascade = tmp_path / "c.json"
    cascade.write_text(json.dumps({
        "entries": [{"recall_at_l": 1.4, "queries_per_sec": 9.0,
                     "use_kernels": False}],
        "device_kind": "cpu",
        "autotune": {"mode": "sometimes", "tuned_blocks": {}},
    }))
    serve = tmp_path / "s.json"
    serve.write_text(json.dumps({
        "entries": [
            {"offered_qps": 50.0, "n_requests": 8, "completed": 6,
             "served": 5, "shed": 1, "p50_ms": 12.0, "p99_ms": 9.0,
             "tier_mix": {"primary": 4}},
        ],
        "chaos": {"n_requests": 8, "completed": 8,
                  "deterministic": False},
    }))
    violations, _ = bench_check.run(batch_path=str(batch),
                                    cascade_path=str(cascade),
                                    serve_path=str(serve))
    msgs = "\n".join(v.message for v in violations)
    assert "no device_kind" in msgs             # batch artifact lacks it
    assert "no autotune record" in msgs
    assert "autotune mode 'sometimes'" in msgs  # cascade's bad mode
    assert "no distributed-engine entry" in msgs
    assert "outside [0, 1]" in msgs
    assert "use_kernels both ways" in msgs
    assert "no distributed_step record" in msgs
    assert "p50_ms=12.0 > p99_ms=9.0" in msgs
    assert "completed 6/8" in msgs
    assert "tier_mix totals 4 != served 5" in msgs
    assert "not deterministic" in msgs
    assert "no corpus-size sweep" in msgs       # cascade artifact lacks it
    assert "no precision_sweep" in msgs         # both artifacts lack it


def test_bench_check_precision_sweep_bars(tmp_path):
    """bf16 handoff bytes must be exactly half of f32's and the bf16
    recall delta must stay inside the acceptance band."""
    sweep = _valid_precision_sweep()
    sweep["entries"][1]["handoff_bytes_per_row"] = 28   # bf16 not halved
    sweep["entries"][1]["recall_delta_vs_f32"] = 0.05   # over the bar
    del sweep["entries"][2]["queries_per_sec"]          # missing field
    batch = tmp_path / "b.json"
    batch.write_text(json.dumps({"entries": [
        {"engine": "batched", "queries_per_sec": 10.0},
        {"engine": "distributed", "queries_per_sec": 5.0},
    ], "precision_sweep": sweep, "device_kind": "cpu",
        "autotune": {"mode": "off", "tuned_blocks": {}}}))
    violations, _ = bench_check.run(batch_path=str(batch),
                                    cascade_path=str(tmp_path / "nope"),
                                    serve_path=str(tmp_path / "nope"))
    msgs = "\n".join(v.message for v in violations
                     if v.subject == str(batch))
    assert "are not half of f32's" in msgs
    assert "bf16 recall delta 0.05" in msgs
    assert "missing 'queries_per_sec'" in msgs


def test_bench_check_sweep_acceptance_bar(tmp_path):
    """Full (non-smoke) sweeps must show a sublinear source beating the
    full scan's qps at recall >= 0.9 on the LARGEST rung; smoke sweeps
    are exempt; malformed rungs are flagged individually."""
    def artifact(sweep, smoke):
        return {"entries": [
            {"recall_at_l": 1.0, "queries_per_sec": 9.0,
             "use_kernels": False},
            {"recall_at_l": 1.0, "queries_per_sec": 9.0,
             "use_kernels": True}],
            "distributed_step": {"recall_at_l": 1.0,
                                 "queries_per_sec": 4.0},
            "device_kind": "cpu",
            "autotune": {"mode": "off", "tuned_blocks": {}},
            "smoke": smoke, "sweep": sweep,
            "precision_sweep": _valid_precision_sweep()}

    def check(sweep, smoke=False):
        p = tmp_path / "c.json"
        p.write_text(json.dumps(artifact(sweep, smoke)))
        return "\n".join(v.message
                         for v in bench_check.check_cascade(str(p)))

    good = [{"n": 256, "entries": [
        {"source": "full_scan", "recall_at_l": 1.0,
         "queries_per_sec": 50.0},
        {"source": "cluster_tree", "recall_at_l": 0.99,
         "queries_per_sec": 80.0}]}]
    assert check(good) == ""
    # sublinear slower than the scan at the largest rung: bar missed
    slow = [{"n": 1024, "entries": [
        {"source": "full_scan", "recall_at_l": 1.0,
         "queries_per_sec": 50.0},
        {"source": "centroid_lsh", "recall_at_l": 0.99,
         "queries_per_sec": 30.0}]}]
    assert "acceptance bar" in check(slow)
    # high qps but recall below 0.9: bar missed too
    lossy = [{"n": 1024, "entries": [
        {"source": "full_scan", "recall_at_l": 1.0,
         "queries_per_sec": 50.0},
        {"source": "centroid_lsh", "recall_at_l": 0.6,
         "queries_per_sec": 300.0}]}]
    assert "acceptance bar" in check(lossy)
    # ... but only the LARGEST rung carries the bar, and smoke is exempt
    good_big = [dict(good[0], n=4096)]
    assert "acceptance bar" not in check(slow + good_big)
    assert check(lossy, smoke=True) == ""
    # structural defects per rung
    bad = [{"n": 64, "entries": [
        {"source": "cluster_tree", "recall_at_l": 1.4,
         "queries_per_sec": -3.0}]}]
    msgs = check(bad + good)
    assert "no full_scan reference" in msgs
    assert "outside [0, 1]" in msgs
    assert "not a positive number" in msgs


def test_bench_check_serve_requires_chaos_record(tmp_path):
    serve = tmp_path / "s.json"
    art = _valid_serve()
    del art["chaos"]
    serve.write_text(json.dumps(art))
    out = bench_check.check_serve(str(serve))
    assert any("no chaos record" in v.message for v in out)
    # completion gate: a chaos run that hung a request is a violation
    art = _valid_serve()
    art["chaos"]["completed"] = 7
    serve.write_text(json.dumps(art))
    out = bench_check.check_serve(str(serve))
    assert any("7/8 requests under injected faults" in v.message
               for v in out)


def test_bench_check_reports_missing_artifacts(tmp_path):
    violations, _ = bench_check.run(batch_path=str(tmp_path / "no.json"),
                                    cascade_path=str(tmp_path / "no2.json"),
                                    serve_path=str(tmp_path / "no3.json"))
    assert len(violations) == 3
    assert all("artifact missing" in v.message for v in violations)


# -------------------------------------------------------------------- CLI

def test_cli_runs_fast_passes_clean(capsys):
    from repro.analysis import check
    rc = check.main(["--passes", "registry,vmem"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "PASS registry" in out and "PASS vmem" in out


def test_cli_rejects_unknown_pass():
    from repro.analysis import check
    with pytest.raises(SystemExit):
        check.main(["--passes", "nope"])


def test_cli_fails_on_violation(tmp_path, capsys, monkeypatch):
    from repro.analysis import check
    monkeypatch.chdir(tmp_path)                  # no BENCH_*.json here
    rc = check.main(["--passes", "bench"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "FAIL bench" in out
