"""Conformance of the ``kernels/partition`` shard_map shims — run in a
subprocess with 8 host devices (XLA_FLAGS must be set before jax
initializes, so these can't share the main single-device pytest
process).

The shims are what makes compiled ``pallas_call`` legal on a mesh
(a Pallas launch has no SPMD partitioning rule of its own); the
contract tested here is that routing a kernel launch through a shim is
INVISIBLE in the output: bitwise-identical scores to the single-host
kernel path, for every batched engine and every candidate kernel, plus
the divisibility fallback when the mesh axes don't divide the problem.
"""
import os
import subprocess
import sys

import pytest

_XLA_FLAGS = " ".join(
    f for f in os.environ.get("XLA_FLAGS", "").split()
    if not f.startswith("--xla_force_host_platform_device_count"))
_ENV = dict(os.environ,
            XLA_FLAGS=(_XLA_FLAGS
                       + " --xla_force_host_platform_device_count=8").strip(),
            PYTHONPATH="src")


def _run(script: str):
    res = subprocess.run([sys.executable, "-c", script], env=_ENV,
                         capture_output=True, text=True, cwd=".",
                         timeout=600)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    return res.stdout


@pytest.mark.slow
def test_shim_paths_bitwise_match_single_host_kernels():
    """Every batched kernel engine (act/rwmd/omr) and every candidate
    kernel (act/rwmd/rwmd_rev/omr/ict) scores bitwise identically with
    and without the mesh shims on a (2, 4) mesh — the shims repartition
    the same launches, they never change the arithmetic."""
    out = _run("""
import jax, numpy as np
import jax.numpy as jnp
from repro.core import retrieval
from repro.data.synth import make_text_like

mesh = jax.make_mesh((2, 4), ("data", "model"))
corpus, _ = make_text_like(n_docs=64, n_classes=4, vocab=96, m=8,
                           doc_len=12, hmax=16, seed=7)
nq = 16
q_ids, q_w = corpus.ids[:nq], corpus.w[:nq]

for method, iters in (("act", 3), ("rwmd", 0), ("omr", 0)):
    host = np.asarray(retrieval.batch_scores(
        corpus, q_ids, q_w, method=method, iters=iters, use_kernels=True))
    shim = np.asarray(retrieval.batch_scores(
        corpus, q_ids, q_w, method=method, iters=iters, use_kernels=True,
        mesh=mesh))
    np.testing.assert_array_equal(host, shim), method

rng = np.random.default_rng(0)
cand = jnp.asarray(rng.integers(0, corpus.n, size=(nq, 24)), jnp.int32)
for method, iters in (("act", 2), ("rwmd", 0), ("rwmd_rev", 0),
                      ("omr", 0), ("ict", 0)):
    host = np.asarray(retrieval.cand_scores(
        corpus, q_ids, q_w, cand, method=method, iters=iters,
        use_kernels=True))
    shim = np.asarray(retrieval.cand_scores(
        corpus, q_ids, q_w, cand, method=method, iters=iters,
        use_kernels=True, mesh=mesh))
    np.testing.assert_array_equal(host, shim), method
print("SHIM PARITY OK")
""")
    assert "SHIM PARITY OK" in out


@pytest.mark.slow
def test_shim_divisibility_fallback():
    """Shapes the mesh axes don't divide (odd query count; vocab not a
    multiple of the model axis) fall back to the non-shim kernel path
    instead of crashing — still bitwise equal to the single-host
    launch."""
    out = _run("""
import jax, numpy as np
import jax.numpy as jnp
from repro.core import retrieval
from repro.data.synth import make_text_like
from repro.kernels import partition

mesh = jax.make_mesh((2, 4), ("data", "model"))
corpus, _ = make_text_like(n_docs=63, n_classes=4, vocab=90, m=8,
                           doc_len=12, hmax=16, seed=7)
nq = 5                       # 5 % 2 != 0 -> queries not shardable
assert not partition.queries_shardable(mesh, nq)
assert not partition.phase1_shardable(mesh, nq, corpus.v)
assert not partition.rows_shardable(mesh, nq, corpus.n)
q_ids, q_w = corpus.ids[:nq], corpus.w[:nq]
host = np.asarray(retrieval.batch_scores(
    corpus, q_ids, q_w, method="act", iters=2, use_kernels=True))
shim = np.asarray(retrieval.batch_scores(
    corpus, q_ids, q_w, method="act", iters=2, use_kernels=True,
    mesh=mesh))
np.testing.assert_array_equal(host, shim)

# divisible queries but indivisible vocab/rows: Phase 1 and the pour
# fall back independently while the candidate shims still shard
nq = 4
assert partition.queries_shardable(mesh, nq)
assert not partition.phase1_shardable(mesh, nq, corpus.v)
q_ids, q_w = corpus.ids[:nq], corpus.w[:nq]
rng = np.random.default_rng(1)
cand = jnp.asarray(rng.integers(0, corpus.n, size=(nq, 12)), jnp.int32)
host = np.asarray(retrieval.cand_scores(
    corpus, q_ids, q_w, cand, method="ict", iters=0, use_kernels=True))
shim = np.asarray(retrieval.cand_scores(
    corpus, q_ids, q_w, cand, method="ict", iters=0, use_kernels=True,
    mesh=mesh))
np.testing.assert_array_equal(host, shim)
print("FALLBACK OK")
""")
    assert "FALLBACK OK" in out
