"""Kernel-conformance harness for the fused candidate-compaction kernels.

The contract (see ``kernels/cand_pour``'s module docstring):

* the in-kernel one-hot gather is BITWISE equal to an XLA gather;
* every fused candidate kernel matches its XLA-gather oracle and the
  reference ``lc_*_scores_cand`` engine to within ``ULP_TOL`` (4) float32
  ulps — the kernels reuse the reference reduction formulas on
  identically shaped tiles, so the residual ulps come from XLA re-fusing
  the REFERENCE path per program (FMA contraction of its reductions),
  not from the kernels;
* the LC-ICT remainder dump stays at the max FINITE cost under the
  kernel path (a PAD_DIST dump would explode float residue by ~1e30).

Sweeps pad rows, duplicate candidate ids, budgets not divisible by the
candidate block, and nq=1 vs batched grids — fixed cases plus a
hypothesis property (derandomized, so CI is deterministic).
"""
import zlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lc, retrieval
from repro.core.lc import PAD_DIST, Corpus
from repro.data.synth import make_text_like
from repro.kernels import ops as kops
from repro.kernels import ref as kref

#: Every registry method with a fused candidate kernel path.
CAND_METHODS = ("rwmd", "rwmd_rev", "omr", "act", "ict")

#: Max float32 ulp distance the conformance suite tolerates: the bound on
#: the reference path's per-program reduction reassociation (the kernels'
#: outputs are themselves deterministic across programs).
ULP_TOL = 4


def _ordered(f):
    """Map float32 bits to integers whose differences count ulps
    (negative floats mirror below zero; -0.0 and +0.0 both map to 0)."""
    i = np.ascontiguousarray(np.asarray(f, np.float32)).view(np.int32)
    i = i.astype(np.int64)
    return np.where(i >= 0, i, np.int64(-2**31) - i)


def assert_ulp_equal(got, want, max_ulp=ULP_TOL, err_msg=""):
    """Exact equality up to ``max_ulp`` float32 ulps (0 distance for
    bit-identical values; the default covers the reference path's
    per-program fusion wobble — see the module docstring)."""
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    assert got.shape == want.shape, (got.shape, want.shape)
    ulp = np.abs(_ordered(got) - _ordered(want))
    assert ulp.max(initial=0) <= max_ulp, (
        f"{err_msg}: {int((ulp > max_ulp).sum())}/{ulp.size} entries "
        f"beyond {max_ulp} ulp (max {int(ulp.max())}); "
        f"max abs diff {np.abs(got - want).max()}")


def _pad_corpus(c, pad_rows: int) -> Corpus:
    """Append zero-weight pad rows (id 0), as the distributed layouts do."""
    if not pad_rows:
        return c
    return Corpus(ids=jnp.pad(c.ids, ((0, pad_rows), (0, 0))),
                  w=jnp.pad(c.w, ((0, pad_rows), (0, 0))), coords=c.coords)


def _random_cand(rng, n, nq, b, duplicates=False, include=None):
    """(nq, b) candidate ids; ``duplicates`` samples with replacement,
    ``include`` forces specific row ids into every query's set."""
    cand = np.stack([rng.choice(n, b, replace=duplicates)
                     for _ in range(nq)])
    if include is not None:
        cand[:, :len(include)] = include
    return jnp.asarray(cand.astype(np.int32))


def _check_all_methods(c, qi, qw, cand, *, iters=2, block_q=8, block_n=128,
                       block_v=256, label=""):
    for method in CAND_METHODS:
        ref_s = retrieval.cand_scores(c, qi, qw, cand, method=method,
                                      iters=iters, block_q=block_q)
        ker_s = retrieval.cand_scores(c, qi, qw, cand, method=method,
                                      iters=iters, block_q=block_q,
                                      use_kernels=True, block_n=block_n,
                                      block_v=block_v)
        assert_ulp_equal(ker_s, ref_s, err_msg=f"{label}:{method}")


# ------------------------------------------------ engine-level conformance

@pytest.fixture(scope="module")
def corpus():
    return make_text_like(n_docs=40, n_classes=4, vocab=128, m=8,
                          doc_len=10, hmax=16, seed=3)[0]


_CASES = {
    # name: (nq, b, block_n, block_v, block_q, duplicates, pad_rows)
    "batched": (5, 13, 8, 32, 2, False, 0),
    "nq1": (1, 9, 16, 256, 8, False, 0),
    "duplicate_cands": (4, 12, 8, 64, 8, True, 0),
    "pad_rows_in_cand": (3, 10, 8, 128, 2, False, 8),
    "budget_not_block_multiple": (3, 21, 8, 16, 8, False, 0),
    "one_block": (2, 8, 128, 256, 8, False, 0),
}


@pytest.mark.parametrize("case", sorted(_CASES))
def test_cand_engines_match_reference(corpus, case):
    """Fused kernels vs the reference candidate engines, all five
    methods, across the pad/duplicate/blocking sweep."""
    nq, b, block_n, block_v, block_q, dup, pad_rows = _CASES[case]
    c = _pad_corpus(corpus, pad_rows)
    # crc32, not hash(): Python's string hash is salted per process, which
    # would make these "fixed" cases draw fresh candidates every run
    rng = np.random.default_rng(zlib.crc32(case.encode()))
    # pad rows (if any) are forced INTO the candidate sets: a candidate
    # kernel must score them exactly like the reference (zero weight
    # rows pour nothing), not merely never see them.
    include = [c.n - 1, c.n - 2] if pad_rows else None
    cand = _random_cand(rng, c.n, nq, b, duplicates=dup, include=include)
    qi, qw = corpus.ids[:nq], corpus.w[:nq]
    _check_all_methods(c, qi, qw, cand, block_q=block_q, block_n=block_n,
                       block_v=block_v, label=case)


def test_cand_engines_property():
    """Hypothesis sweep of the same conformance over random corpora,
    candidate sets, and block shapes (derandomized: CI-deterministic)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=5, deadline=None, derandomize=True)
    @given(seed=st.integers(0, 2**31 - 1), nq=st.integers(1, 5),
           b=st.integers(1, 24), block_n=st.sampled_from([8, 16, 128]),
           block_v=st.sampled_from([16, 64, 256]),
           duplicates=st.booleans(), pad=st.booleans())
    def run(seed, nq, b, block_n, block_v, duplicates, pad):
        c0, _ = make_text_like(n_docs=24, n_classes=3, vocab=64, m=6,
                               doc_len=8, hmax=8, seed=seed)
        c = _pad_corpus(c0, 8 if pad else 0)
        rng = np.random.default_rng(seed)
        b_ = min(b, c.n)
        cand = _random_cand(rng, c.n, nq, b_, duplicates=duplicates)
        _check_all_methods(c, c0.ids[:nq], c0.w[:nq], cand, block_q=2,
                           block_n=block_n, block_v=block_v,
                           label=f"seed{seed}")

    run()


# --------------------------------------------------- ops-level conformance

def _handoff(rng, nq, v, k, iters):
    Z = jnp.asarray(np.sort(rng.uniform(size=(nq, v, k)), -1), jnp.float32)
    W = jnp.asarray(rng.uniform(size=(nq, v, max(iters, 1))) * 0.3,
                    jnp.float32)
    return Z, W


def _cand_inputs(rng, nq, b, hmax, v):
    idsg = jnp.asarray(rng.integers(0, v, (nq, b, hmax)), jnp.int32)
    xg = jnp.asarray(rng.uniform(size=(nq, b, hmax)) *
                     (rng.uniform(size=(nq, b, hmax)) > 0.3), jnp.float32)
    return idsg, xg


@pytest.mark.parametrize("nq,b,hmax,v,iters", [
    (1, 9, 7, 37, 0), (3, 13, 7, 37, 3), (2, 8, 16, 128, 1),
    (4, 30, 5, 64, 7),
])
def test_cand_pour_op_matches_oracle(nq, b, hmax, v, iters, rng):
    idsg, xg = _cand_inputs(rng, nq, b, hmax, v)
    Z, W = _handoff(rng, nq, v, iters + 1, iters)
    got = kops.cand_pour(idsg, xg, Z, None if iters == 0 else W, iters,
                         block_n=8, block_v=16)
    want = kref.cand_pour_ref(idsg, xg, Z, None if iters == 0 else W, iters)
    assert_ulp_equal(got, want, err_msg=f"pour it={iters}")


@pytest.mark.parametrize("nq,b,hmax,v", [(1, 9, 7, 37), (3, 13, 9, 64)])
def test_cand_omr_op_matches_oracle(nq, b, hmax, v, rng):
    idsg, xg = _cand_inputs(rng, nq, b, hmax, v)
    Z, W = _handoff(rng, nq, v, 2, 1)
    # exact-zero nearest costs exercise the overlap branch
    Z = Z.at[:, ::3, 0].set(0.0)
    got = kops.cand_omr(idsg, xg, Z, W[..., 0], block_n=8, block_v=16)
    want = kref.cand_omr_ref(idsg, xg, Z, W[..., 0])
    assert_ulp_equal(got, want, err_msg="omr")


@pytest.mark.parametrize("mode", ["rev_min", "ict"])
@pytest.mark.parametrize("nq,b,hmax,v,h", [(1, 9, 7, 37, 6),
                                           (3, 13, 5, 64, 10)])
def test_cand_dist_ops_match_oracle(mode, nq, b, hmax, v, h, rng):
    idsg, xg = _cand_inputs(rng, nq, b, hmax, v)
    Dq = jnp.asarray(rng.uniform(size=(nq, v, h)), jnp.float32)
    qw = jnp.asarray(rng.uniform(size=(nq, h)), jnp.float32)
    # a padded query bin per query: PAD_DIST cost column, zero weight
    Dq = Dq.at[:, :, -1].set(PAD_DIST)
    qw = qw.at[:, -1].set(0.0)
    op = kops.cand_rev_min if mode == "rev_min" else kops.cand_ict
    oracle = (kref.cand_rev_min_ref if mode == "rev_min"
              else kref.cand_ict_ref)
    got = op(idsg, xg, Dq, qw, block_n=8, block_v=16)
    assert_ulp_equal(got, oracle(idsg, xg, Dq, qw), err_msg=mode)


def test_cand_gather_is_bitwise_exact(rng):
    """The in-kernel one-hot gather reproduces an XLA gather bit-for-bit
    (table values ride through 1.0 * value + exact-zero products) — the
    structural half of the conformance contract."""
    import functools

    import jax
    from jax.experimental import pallas as pl

    from repro.kernels.cand_pour import _gather_rows

    v, width, r, block_v = 48, 5, 64, 16
    table = jnp.asarray(rng.uniform(size=(v, width)) *
                        np.where(rng.uniform(size=(v, width)) > 0.9,
                                 PAD_DIST, 1.0), jnp.float32)
    ids = jnp.asarray(rng.integers(0, v, (r,)), jnp.int32)

    def kernel(ids_ref, tab_ref, out_ref):
        out_ref[...] = _gather_rows(ids_ref[...], tab_ref[...], block_v)

    got = pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec((r,), lambda: (0,)),
                  pl.BlockSpec((v, width), lambda: (0, 0))],
        out_specs=pl.BlockSpec((r, width), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((r, width), jnp.float32),
        interpret=True,
    )(ids, table)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(table[ids]))


@pytest.mark.parametrize("nq,b,hmax,iters", [(1, 10, 7, 1), (4, 33, 17, 3)])
def test_act_phase2_cand_matches_ref(nq, b, hmax, iters, rng):
    """The candidate-grid (per-query x) extension of act_phase2 against
    its sequential-rounds oracle."""
    xg = jnp.asarray(rng.uniform(size=(nq, b, hmax)) *
                     (rng.uniform(size=(nq, b, hmax)) > 0.3), jnp.float32)
    zg = jnp.asarray(np.sort(rng.uniform(size=(nq, b, hmax, iters + 1)), -1),
                     jnp.float32)
    wg = jnp.asarray(rng.uniform(size=(nq, b, hmax, iters)) * 0.3,
                     jnp.float32)
    t = kops.act_phase2_cand(xg, zg, wg, block_n=16, block_h=8)
    tr = kref.act_phase2_cand_ref(xg, zg, wg)
    assert t.shape == (nq, b)
    np.testing.assert_allclose(np.asarray(t), np.asarray(tr), rtol=1e-5,
                               atol=1e-6)


# --------------------------------------------- ict remainder-dump contract

def test_cand_ict_remainder_dump_stays_max_finite():
    """Regression (cascade satellite): an all-remainder query — total
    capacity far below the row's mass — must dump the residue at the max
    FINITE gathered cost under the kernel path too. A PAD_DIST dump
    would score ~1e30 * remainder instead of ~1."""
    idsg = jnp.zeros((1, 1, 1), jnp.int32)
    xg = jnp.ones((1, 1, 1), jnp.float32)
    # one real query bin at cost 1.0 with capacity 0.25; one padded bin
    Dq = jnp.asarray([[[1.0, PAD_DIST]]], jnp.float32)
    qw = jnp.asarray([[0.25, 0.0]], jnp.float32)
    got = np.asarray(kops.cand_ict(idsg, xg, Dq, qw))
    # 0.25 poured at cost 1.0 + 0.75 remainder dumped at max finite (1.0)
    np.testing.assert_allclose(got, [[1.0]], rtol=1e-6)
    assert got[0, 0] < 1e6, "remainder was dumped at PAD_DIST"
    np.testing.assert_array_equal(got,
                                  np.asarray(kref.cand_ict_ref(idsg, xg,
                                                               Dq, qw)))


# ------------------------------------------ precision-policy conformance

#: Measured max absolute error of each reduced-precision policy against
#: the float32 engines on the conformance fixture (kernel and reference
#: paths; 3x headroom over the observed worst case — bf16 storage error
#: peaked at 2.2e-3 and bf16_agg's bf16 matmul at 1.3e-1, on omr).
POLICY_ABS_TOL = {"bf16": 8e-3, "bf16_agg": 0.4}


@pytest.mark.parametrize("policy", sorted(POLICY_ABS_TOL))
def test_cand_engines_policy_conformance(corpus, policy):
    """The ULP conformance contract holds PER POLICY: under a reduced-
    precision policy the fused kernels still match the reference engine
    at float32 ulp distance (both paths consume the identical reduced
    handoffs — the policy moves both, not their difference), while the
    policy itself drifts from float32 only within its measured band."""
    nq, b = 4, 12
    qi, qw = corpus.ids[:nq], corpus.w[:nq]
    rng = np.random.default_rng(zlib.crc32(policy.encode()))
    cand = _random_cand(rng, corpus.n, nq, b)
    tol = POLICY_ABS_TOL[policy]
    for method in CAND_METHODS:
        f32_s = retrieval.cand_scores(corpus, qi, qw, cand, method=method,
                                      iters=2)
        ref_s = retrieval.cand_scores(corpus, qi, qw, cand, method=method,
                                      iters=2, precision=policy)
        ker_s = retrieval.cand_scores(corpus, qi, qw, cand, method=method,
                                      iters=2, precision=policy,
                                      use_kernels=True, block_n=8,
                                      block_v=64)
        assert_ulp_equal(ker_s, ref_s, err_msg=f"{policy}:{method}")
        np.testing.assert_allclose(np.asarray(ref_s), np.asarray(f32_s),
                                   atol=tol, rtol=0,
                                   err_msg=f"{policy}:{method} vs f32")
        # the drift must be real: a bitwise-f32 "bf16" run means the
        # policy kwarg fell off the stack (see analysis.precision_lint)
        assert float(np.abs(np.asarray(ref_s, np.float64)
                            - np.asarray(f32_s, np.float64)).max()) > 0.0, \
            f"{policy}:{method} scored bitwise f32 — policy ignored"


def test_ict_engine_all_remainder_query_finite(corpus):
    """Same contract through the full engine: an unnormalized query whose
    capacities absorb only a quarter of each row's mass stays finite and
    ulp-identical across the kernel and reference paths."""
    nq, b = 2, 6
    qi = corpus.ids[:nq]
    qw = corpus.w[:nq] * 0.25                 # total capacity 0.25 per query
    cand = _random_cand(np.random.default_rng(0), corpus.n, nq, b)
    ref_s = np.asarray(lc.lc_ict_scores_cand(corpus, qi, qw, cand))
    ker_s = np.asarray(lc.lc_ict_scores_cand(corpus, qi, qw, cand,
                                             use_kernels=True, block_n=8,
                                             block_v=32))
    assert_ulp_equal(ker_s, ref_s, err_msg="ict all-remainder")
    assert float(np.abs(ker_s).max()) < 1e6, \
        "all-remainder ICT scores exploded: PAD_DIST dump regression"
