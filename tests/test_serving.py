"""Online serving runtime: micro-batching, degradation, chaos, lifecycle.

The acceptance contract these tests pin down:

* requests served at the PRIMARY tier are bit-identical to calling
  ``EmdIndex.search`` directly — micro-batching and padding change the
  launch shape, never the answer;
* under injected launch failures every request still completes, and a
  degraded response is (a) labeled with the tier actually served and
  (b) bit-identical to an index built directly with that tier's config —
  zero wrong results, only labeled quality changes;
* kill-and-restore from a snapshot resumes with parity-checked scores,
  and a corrupt newest snapshot falls back to the previous generation;
* everything is deterministic under fixed chaos seeds.
"""
import asyncio
import dataclasses

import numpy as np
import pytest

from repro.api import EmdIndex, EngineConfig
from repro.cascade.spec import CASCADES
from repro.checkpoint.store import CheckpointCorrupt
from repro.data.synth import make_text_like
from repro.serving import (ChaosInjector, ChaosSchedule, EmdServer,
                           ServerOverloaded, ServingPolicy, ServingTier,
                           corrupt_checkpoint, resolve_tier, restore_latest,
                           restore_server, snapshot, validate_ladder)
from repro.serving.server import _tier_config

pytestmark = pytest.mark.chaos


@pytest.fixture(scope="module")
def corpus():
    c, _ = make_text_like(n_docs=24, vocab=48, m=8, doc_len=12, hmax=12)
    return c


@pytest.fixture(scope="module")
def config():
    return EngineConfig(method="act", iters=2, top_l=4)


@pytest.fixture(scope="module")
def index(corpus, config):
    return EmdIndex.build(corpus, config)


def policy(**kw):
    kw.setdefault("ladder", ("primary", "wcd"))
    kw.setdefault("max_batch", 4)
    kw.setdefault("flush_ms", 20.0)
    kw.setdefault("backoff_ms", 0.0)
    kw.setdefault("max_retries", 1)
    kw.setdefault("deadline_ms", 10_000.0)
    return ServingPolicy(**kw)


def run(coro):
    return asyncio.run(coro)


# --------------------------------------------------------------- parity
def test_single_query_bit_identical_to_direct_search(index, corpus):
    async def go():
        async with EmdServer(index, policy()) as server:
            return await server.search(corpus.ids[0], corpus.w[0])
    res = run(go())
    s, i = index.search(corpus.ids[0], corpus.w[0])
    np.testing.assert_array_equal(res.scores, np.asarray(s))
    np.testing.assert_array_equal(res.indices, np.asarray(i))
    assert res.tier == "primary" and not res.degraded
    assert res.expected_recall == 1.0 and res.generation == 0


def test_microbatch_coalesces_and_pads_to_bucket(index, corpus):
    async def go():
        async with EmdServer(index, policy()) as server:
            outs = await asyncio.gather(*[
                server.search(corpus.ids[k], corpus.w[k]) for k in range(3)])
            return outs, server.stats
    outs, stats = run(go())
    # 3 concurrent callers -> ONE launch, padded up to the pow-2 bucket 4.
    assert stats.launches == 1 and stats.flushes == 1
    assert stats.bucket_launches == {4: 1}
    assert stats.tier_served == {"primary": 3}
    for k, o in enumerate(outs):
        s, i = index.search(corpus.ids[k], corpus.w[k])
        np.testing.assert_array_equal(o.scores, np.asarray(s))
        np.testing.assert_array_equal(o.indices, np.asarray(i))


def test_bucket_is_next_pow2_capped_at_max_batch(index):
    async def go():
        async with EmdServer(index, policy(max_batch=8)) as server:
            return [server._bucket(n) for n in (1, 2, 3, 5, 8, 9)]
    assert run(go()) == [1, 2, 4, 8, 8, 8]


def test_requires_running_server_and_single_query(index, corpus):
    server = EmdServer(index, policy())

    async def not_running():
        with pytest.raises(RuntimeError, match="not running"):
            await server.search(corpus.ids[0], corpus.w[0])

    async def batched_query():
        async with EmdServer(index, policy()) as srv:
            with pytest.raises(ValueError, match=r"one \(h,\) query"):
                await srv.search(corpus.ids[:2], corpus.w[:2])
    run(not_running())
    run(batched_query())


# --------------------------------------------------- chaos: degradation
def test_injected_failures_degrade_with_correct_labeled_results(
        index, corpus, config):
    # Attempts: 0 ok (req A), then req B: 1 fail, 2 fail (primary
    # exhausted, max_retries=1) -> 3 ok on the wcd rung.
    chaos = ChaosInjector(ChaosSchedule(fail_launches=frozenset({1, 2})))

    async def go():
        async with EmdServer(index, policy(),
                             launch_hook=chaos) as server:
            a = await server.search(corpus.ids[0], corpus.w[0])
            b = await server.search(corpus.ids[1], corpus.w[1])
            return a, b, server.stats
    a, b, stats = run(go())
    assert a.tier == "primary" and not a.degraded
    assert b.tier == "wcd" and b.degraded and b.retries == 2
    assert [e[2] for e in chaos.log] == ["ok", "fail", "fail", "ok"]
    assert stats.launch_failures == 2
    # Zero wrong results: the degraded answer is bit-identical to an
    # index built directly with the degraded tier's config.
    wcd = EmdIndex.build(corpus,
                         _tier_config(config, resolve_tier("wcd")))
    s, i = wcd.search(corpus.ids[1], corpus.w[1])
    np.testing.assert_array_equal(b.scores, np.asarray(s))
    np.testing.assert_array_equal(b.indices, np.asarray(i))


def test_retry_with_backoff_recovers_without_degrading(index, corpus):
    chaos = ChaosInjector(ChaosSchedule(fail_launches=frozenset({0})))

    async def go():
        async with EmdServer(index, policy(max_retries=2),
                             launch_hook=chaos) as server:
            return await server.search(corpus.ids[0], corpus.w[0])
    res = run(go())
    assert res.tier == "primary" and not res.degraded and res.retries == 1
    s, _ = index.search(corpus.ids[0], corpus.w[0])
    np.testing.assert_array_equal(res.scores, np.asarray(s))


def test_ladder_exhaustion_sheds_with_fast_fail(index, corpus):
    chaos = ChaosInjector(ChaosSchedule(
        fail_launches=frozenset(range(16))))

    async def go():
        async with EmdServer(index, policy(),
                             launch_hook=chaos) as server:
            with pytest.raises(ServerOverloaded, match="ladder"):
                await server.search(corpus.ids[0], corpus.w[0])
            return server.stats
    stats = run(go())
    assert stats.shed == 1
    assert stats.launch_failures == 4      # 2 tiers x (1 + max_retries)


def test_all_requests_complete_under_random_faults(index, corpus, config):
    """100% completion, zero wrong results: every request either carries
    a tier-labeled answer bit-identical to that tier's direct index, or
    (ladder exhausted) fails FAST with ServerOverloaded."""
    sched = ChaosSchedule.from_seed(7, horizon=64, p_fail=0.3)
    chaos = ChaosInjector(sched)
    n_req = 12

    async def go():
        async with EmdServer(index, policy(max_batch=2),
                             launch_hook=chaos) as server:
            return await asyncio.gather(
                *[server.search(corpus.ids[k % corpus.n],
                                corpus.w[k % corpus.n])
                  for k in range(n_req)], return_exceptions=True)
    outs = run(go())
    assert len(outs) == n_req
    direct = {"primary": index}
    for k, o in enumerate(outs):
        if isinstance(o, ServerOverloaded):
            continue                        # shed = completed, fast-failed
        assert not isinstance(o, BaseException), o
        if o.tier not in direct:
            direct[o.tier] = EmdIndex.build(
                corpus, _tier_config(config, resolve_tier(o.tier)))
        s, i = direct[o.tier].search(corpus.ids[k % corpus.n],
                                     corpus.w[k % corpus.n])
        np.testing.assert_array_equal(o.scores, np.asarray(s))
        np.testing.assert_array_equal(o.indices, np.asarray(i))
        assert o.degraded == (o.tier != "primary")


def test_chaos_schedule_deterministic_under_seed(index, corpus):
    def mix(seed):
        sched = ChaosSchedule.from_seed(seed, horizon=32, p_fail=0.4)
        chaos = ChaosInjector(sched)

        async def go():
            async with EmdServer(index, policy(),
                                 launch_hook=chaos) as server:
                outs = []
                for k in range(6):
                    try:
                        r = await server.search(corpus.ids[k], corpus.w[k])
                        outs.append(r.tier)
                    except ServerOverloaded:
                        outs.append("SHED")
                return outs, chaos.log
        return run(go())

    tiers_a, log_a = mix(3)
    tiers_b, log_b = mix(3)
    assert tiers_a == tiers_b and log_a == log_b
    assert ChaosSchedule.from_seed(3, 32, p_fail=0.4) == \
        ChaosSchedule.from_seed(3, 32, p_fail=0.4)


def test_deadline_pressure_starts_batch_down_ladder(index, corpus):
    async def go():
        async with EmdServer(index, policy(headroom=1.0)) as server:
            # Warm estimate says primary takes 1s; the request only has
            # ~50ms of budget left -> the batch starts at the wcd rung.
            server.stats.tier_latency_ms["primary"] = 1000.0
            return await server.search(corpus.ids[0], corpus.w[0],
                                       deadline_ms=50.0)
    res = run(go())
    assert res.tier == "wcd" and res.degraded


# ----------------------------------------------------- ladder validation
def test_ladder_validated_before_traffic(index, corpus, config):
    with pytest.raises(ValueError, match="unknown ladder rung"):
        EmdServer(index, policy(ladder=("primary", "nope")))
    with pytest.raises(ValueError, match="duplicate"):
        EmdServer(index, policy(ladder=("primary", "wcd", "wcd")))
    # A cascade rung whose budgets cannot resolve fails at construction.
    with pytest.raises(ValueError, match="cannot serve"):
        validate_ladder(policy(ladder=("primary", "fast")), config,
                        n=2, top_l=4)


def test_resolve_tier_covers_presets_methods_and_specs():
    assert resolve_tier("primary").name == "primary"
    fast = resolve_tier("fast")
    assert fast.cascade is CASCADES["fast"]
    assert fast.expected_recall == 0.95
    wcd = resolve_tier("wcd")
    assert wcd.method == "wcd" and wcd.cascade is None
    spec_tier = resolve_tier(CASCADES["chain"])
    assert spec_tier.cascade is CASCADES["chain"]
    assert spec_tier.expected_recall == 1.0    # admissible spec
    with pytest.raises(ValueError, match="both cascade and method"):
        ServingTier(name="bad", cascade=CASCADES["fast"], method="wcd")


def test_cascade_preset_rung_serves_through_cascade(index, corpus, config):
    chaos = ChaosInjector(ChaosSchedule(fail_launches=frozenset({0, 1})))

    async def go():
        async with EmdServer(index, policy(ladder=("primary", "chain")),
                             launch_hook=chaos) as server:
            return await server.search(corpus.ids[2], corpus.w[2])
    res = run(go())
    assert res.tier == "chain" and res.degraded
    assert res.expected_recall == 1.0          # admissible preset
    chain = EmdIndex.build(
        corpus, dataclasses.replace(config, cascade=CASCADES["chain"]))
    s, i = chain.search(corpus.ids[2], corpus.w[2])
    np.testing.assert_array_equal(res.scores, np.asarray(s))
    np.testing.assert_array_equal(res.indices, np.asarray(i))


# ----------------------------------------------------- corpus mutation
def test_append_and_delete_keep_external_ids_stable(index, corpus):
    async def go():
        async with EmdServer(index, policy()) as server:
            new_ids = server.append(np.asarray(corpus.ids[:3]),
                                    np.asarray(corpus.w[:3]))
            assert new_ids.tolist() == [24, 25, 26]
            assert server.generation == 1 and server.corpus.n == 27
            # Row 0's duplicate now exists at external id 24: searching
            # for doc 0 must surface BOTH external ids.
            r = await server.search(corpus.ids[0], corpus.w[0])
            assert {0, 24} <= set(np.asarray(r.indices).tolist())
            assert r.generation == 1
            removed = server.delete([24, 26])
            assert removed == 2 and server.generation == 2
            assert server.corpus.n == 25
            # Survivors keep their ids: 25 still maps to corpus row 1.
            assert 25 in server.doc_ids.tolist()
            r2 = await server.search(corpus.ids[1], corpus.w[1])
            assert {1, 25} <= set(np.asarray(r2.indices).tolist())
            with pytest.raises(KeyError, match="unknown doc ids"):
                server.delete([24])             # already gone
            with pytest.raises(ValueError, match="top_l"):
                server.delete(server.doc_ids[:-2].tolist())
            with pytest.raises(ValueError, match="rows"):
                server.append(np.zeros((2, 5), np.int32),
                              np.zeros((2, 5), np.float32))
    run(go())


def test_inflight_batch_finishes_on_old_generation(index, corpus):
    """A mutation between enqueue and flush must not tear the batch: the
    launch snapshots one generation and answers from it."""
    async def go():
        async with EmdServer(index, policy(flush_ms=50.0)) as server:
            fut = asyncio.ensure_future(
                server.search(corpus.ids[0], corpus.w[0]))
            await asyncio.sleep(0)             # enqueued, not yet flushed
            server.append(np.asarray(corpus.ids[:1]),
                          np.asarray(corpus.w[:1]))
            res = await fut
            # Served on whichever generation the flush snapshotted —
            # either is correct; the label must match the answer.
            assert res.generation in (0, 1)
            if res.generation == 0:
                s, i = index.search(corpus.ids[0], corpus.w[0])
                np.testing.assert_array_equal(res.scores, np.asarray(s))
                np.testing.assert_array_equal(res.indices, np.asarray(i))
    run(go())


# ------------------------------------------------- snapshot / restore
def test_snapshot_kill_restore_parity(index, corpus, tmp_path):
    d = str(tmp_path / "snap")

    async def serve_and_snapshot():
        async with EmdServer(index, policy()) as server:
            server.append(np.asarray(corpus.ids[:2]),
                          np.asarray(corpus.w[:2]))
            server.delete([24])
            res = await server.search(corpus.ids[0], corpus.w[0])
            snapshot(server, d)
            return res

    async def restore_and_serve():
        server = restore_server(d, policy())
        async with server:
            assert server.generation == 2
            assert server.corpus.n == 25
            assert 25 in server.doc_ids.tolist()
            res = await server.search(corpus.ids[0], corpus.w[0])
            # Restored server keeps assigning fresh ids after the max.
            assert server.append(np.asarray(corpus.ids[:1]),
                                 np.asarray(corpus.w[:1])).tolist() == [26]
            return res

    before = run(serve_and_snapshot())
    after = run(restore_and_serve())
    np.testing.assert_array_equal(before.scores, after.scores)
    np.testing.assert_array_equal(before.indices, after.indices)


def test_corrupt_newest_snapshot_falls_back_to_previous(
        index, corpus, tmp_path):
    d = str(tmp_path / "snap")

    async def go():
        async with EmdServer(index, policy()) as server:
            p0 = snapshot(server, d)                   # generation 0
            server.append(np.asarray(corpus.ids[:1]),
                          np.asarray(corpus.w[:1]))
            p1 = snapshot(server, d)                   # generation 1
            return p0, p1
    _, p1 = run(go())
    corrupt_checkpoint(p1, leaves=("ids",), seed=1)
    # Direct load of the corrupt generation surfaces the typed error ...
    with pytest.raises(CheckpointCorrupt):
        restore_server(d, policy(), generation=1)
    # ... and the fallback path restores the intact generation 0.
    snap = restore_latest(d)
    assert snap.generation == 0 and snap.corpus.n == 24

    async def verify():
        server = restore_server(d, policy())
        async with server:
            assert server.generation == 0
            res = await server.search(corpus.ids[0], corpus.w[0])
            s, i = index.search(corpus.ids[0], corpus.w[0])
            np.testing.assert_array_equal(res.scores, np.asarray(s))
            np.testing.assert_array_equal(res.indices, np.asarray(i))
    run(verify())


def test_every_snapshot_corrupt_is_a_typed_failure(index, tmp_path):
    d = str(tmp_path / "snap")

    async def go():
        async with EmdServer(index, policy()) as server:
            return snapshot(server, d)
    p = run(go())
    corrupt_checkpoint(p, seed=2)                      # every leaf
    with pytest.raises(CheckpointCorrupt, match="no intact"):
        restore_latest(d)


def test_stop_drains_queued_requests(index, corpus):
    async def go():
        server = EmdServer(index, policy(flush_ms=1000.0, max_batch=8))
        await server.start()
        futs = [asyncio.ensure_future(
            server.search(corpus.ids[k], corpus.w[k])) for k in range(2)]
        await asyncio.sleep(0)
        await server.stop()                   # must serve, not abandon
        return await asyncio.gather(*futs)
    outs = run(go())
    assert all(o.tier == "primary" for o in outs)
