"""Precision-policy suite: sentinel representability, reduced-precision
selection and masking regressions, policy threading end to end, VMEM
layout halving, snapshot round-trips, and the static precision lint.

The sentinel bugfixes under test (see ``repro.core.precision``):

* ``lc.PAD_DIST`` (1e30) OVERFLOWS float16 to inf and ROUNDS in
  bfloat16, so every reduced-precision path writes
  ``pad_dist_for(dtype)`` — finite, exactly representable, and (where
  the dtype's range allows) at least the float32 sentinel on upcast;
* ``retrieval._mask_self`` masks in the float32 ACCUMULATOR dtype:
  ``finfo(bfloat16).max`` is also bf16's overflow-saturation value, so
  an in-dtype mask would tie the diagonal with saturated entries and
  let top_k's index order retrieve self;
* checkpoint restore preserves leaf dtypes — a stored-vs-target
  mismatch is a typed error, never a silent cast.
"""
import asyncio
import dataclasses
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import EmdIndex, EngineConfig
from repro.checkpoint import store
from repro.checkpoint.store import CheckpointCorrupt
from repro.core import lc, retrieval
from repro.core.lc import PAD_DIST
from repro.core.precision import (POLICIES, PrecisionPolicy, pad_dist_for,
                                  resolve)
from repro.data.synth import make_text_like
from repro.kernels import ops as kops

_PAD_F32 = float(np.float32(1e30))


@pytest.fixture(scope="module")
def corpus():
    return make_text_like(n_docs=24, vocab=48, m=8, doc_len=12, hmax=12,
                          seed=5)[0]


# ----------------------------------------------------- sentinel contract

def test_pad_dist_f32_is_bitwise_historical():
    assert pad_dist_for(jnp.float32) == _PAD_F32
    assert np.float32(pad_dist_for("float32")) == np.float32(PAD_DIST)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "float16"])
def test_pad_dist_properties(dtype):
    """Finite, below the dtype max, exactly representable (downcast/
    upcast round-trips bit-exact), and above any real transport cost."""
    pad = pad_dist_for(dtype)
    fi = jnp.finfo(jnp.dtype(dtype))
    assert np.isfinite(pad)
    assert pad < float(fi.max)
    roundtrip = float(jnp.asarray(pad, jnp.dtype(dtype)))
    assert roundtrip == pad, f"{dtype} sentinel not exactly representable"
    assert pad > 1e3        # any unit-scale transport cost stays below


def test_pad_dist_upcast_clears_f32_sentinel_where_range_allows():
    """Strict ``< pad`` comparisons stay correct across a mixed handoff:
    a bf16-stored sentinel upcast to float32 must not drop below the
    float32 sentinel (float16 cannot reach 1e30 — its sentinel only
    needs to exceed real costs, which the property test covers)."""
    assert float(jnp.asarray(pad_dist_for(jnp.bfloat16),
                             jnp.float32)) >= _PAD_F32


def test_f32_sentinel_breaks_reduced_dtypes():
    """The bug this PR fixes: the historical 1e30 sentinel is not usable
    in reduced storage dtypes directly."""
    with np.errstate(over="ignore"):
        assert np.isinf(np.float16(_PAD_F32))          # overflow
    assert float(jnp.asarray(_PAD_F32, jnp.bfloat16)) != _PAD_F32  # rounds


# ----------------------------------- reduced-precision top-k / self-mask

def _assert_selection(D, k, chunk):
    Z, S = lc.streaming_smallest_k(D, k, chunk=chunk)
    Zr, Sr = lc.smallest_k(D, k)
    np.testing.assert_array_equal(np.asarray(S), np.asarray(Sr))
    np.testing.assert_array_equal(np.asarray(Z, np.float32),
                                  np.asarray(Zr, np.float32))
    s = np.asarray(S)
    for row in s.reshape(-1, k):
        assert len(set(row.tolist())) == k, f"duplicate winners: {row}"
    z = np.asarray(Z, np.float32)
    assert np.isfinite(z).all()
    assert (np.diff(z, axis=-1) >= 0).all(), "selection not ascending"


def test_streaming_smallest_k_bf16_no_duplicate_winners(rng):
    """Winner-masking regression: extracted entries are masked with the
    bf16-representable sentinel, so a masked winner can never tie its
    way back into the registers — indices stay unique per row even with
    exact bf16 value ties straddling chunk boundaries."""
    vals = rng.uniform(0.0, 4.0, size=(4, 40)).astype(np.float32)
    D = jnp.asarray(vals, jnp.bfloat16)             # rounding mints ties
    assert int((np.asarray(D, np.float32)[:, :, None]
                == np.asarray(D, np.float32)[:, None, :]).sum()) > 160
    _assert_selection(D, k=6, chunk=8)


def test_streaming_smallest_k_bf16_huge_costs_below_sentinel(rng):
    """Real costs just below the bf16 sentinel still lose to it: the pad
    columns of a non-multiple chunk never enter the winner set."""
    pad = pad_dist_for(jnp.bfloat16)
    vals = rng.uniform(0.5, 0.99, size=(2, 20)).astype(np.float32) * pad
    D = jnp.asarray(vals, jnp.bfloat16)
    Z, S = lc.streaming_smallest_k(D, 4, chunk=8)   # pads 20 -> 24
    assert int(np.asarray(S).max()) < 20, "pad column selected as winner"
    assert float(np.asarray(Z, np.float32).max()) < pad
    _assert_selection(D, k=4, chunk=8)


def test_streaming_smallest_k_f16_stays_finite(rng):
    """float16: the historical 1e30 mask is inf here; the dtype-derived
    sentinel keeps every register finite and the selection exact."""
    D = jnp.asarray(rng.uniform(0.0, 100.0, size=(3, 30)), jnp.float16)
    _assert_selection(D, k=5, chunk=8)


def test_mask_self_bf16_saturation_tiebreak():
    """A row whose scores saturated to finfo(bfloat16).max must still
    never retrieve itself: the mask is written in float32, strictly
    above every finite bf16 value."""
    sat = float(jnp.finfo(jnp.bfloat16).max)
    scores = jnp.full((4, 4), sat, jnp.bfloat16)
    scores = scores.at[jnp.arange(4), (jnp.arange(4) + 1) % 4].set(0.5)
    masked = retrieval._mask_self(scores)
    assert masked.dtype == jnp.float32
    diag = np.diag(np.asarray(masked))
    off = np.asarray(masked)[~np.eye(4, dtype=bool)]
    assert (diag > off.max()).all(), "self tied with saturated entries"
    _, idx = jax.lax.top_k(-masked, 1)
    assert not (np.asarray(idx)[:, 0] == np.arange(4)).any(), \
        "top-1 retrieved self on a saturated bf16 row"


def test_mask_self_f32_passthrough_bit_unchanged(rng):
    scores = jnp.asarray(rng.uniform(size=(5, 5)), jnp.float32)
    masked = np.asarray(retrieval._mask_self(scores))
    np.testing.assert_array_equal(masked[~np.eye(5, dtype=bool)],
                                  np.asarray(scores)[~np.eye(5, dtype=bool)])


# ------------------------------------------------------ policy threading

def test_policy_presets():
    assert POLICIES["f32"] == PrecisionPolicy("f32", "float32", "float32",
                                              "float32")
    assert POLICIES["bf16"].storage == "bfloat16"
    assert POLICIES["bf16"].compute == "float32"
    assert POLICIES["bf16_agg"].compute == "bfloat16"
    for p in POLICIES.values():
        assert p.accum == "float32", "accumulators are always float32"
    assert resolve("bf16") is POLICIES["bf16"]
    assert resolve(POLICIES["bf16"]) is POLICIES["bf16"]
    with pytest.raises(ValueError, match="unknown precision policy"):
        resolve("f8")
    with pytest.raises(ValueError, match="precision"):
        EngineConfig(method="act", precision="f64")


def test_default_policy_is_bitwise_f32(corpus):
    """precision="f32" must be the identity: bitwise-equal scores to a
    build that never heard of policies (the tier-1 safety property)."""
    qi, qw = corpus.ids[:3], corpus.w[:3]
    base = retrieval.batch_scores(corpus, qi, qw, method="act", iters=2)
    f32 = retrieval.batch_scores(corpus, qi, qw, method="act", iters=2,
                                 precision="f32")
    np.testing.assert_array_equal(np.asarray(base), np.asarray(f32))


@pytest.mark.parametrize("method", ["act", "rwmd", "rwmd_rev", "omr", "ict"])
def test_batch_scores_bf16_within_measured_band(corpus, method):
    qi, qw = corpus.ids[:4], corpus.w[:4]
    f32 = np.asarray(retrieval.batch_scores(corpus, qi, qw, method=method,
                                            iters=2), np.float64)
    bf = np.asarray(retrieval.batch_scores(corpus, qi, qw, method=method,
                                           iters=2, precision="bf16"),
                    np.float64)
    err = np.abs(bf - f32).max()
    assert err < 8e-3, f"{method}: bf16 drift {err} beyond measured band"
    assert err > 0.0, f"{method}: bitwise f32 — precision kwarg dropped"


def test_bf16_policy_preserves_topk_agreement(corpus):
    """recall@k of the bf16 policy vs the f32 ranking on the fixture —
    the micro version of the benched precision-vs-recall frontier."""
    qi, qw = corpus.ids[:8], corpus.w[:8]
    k = 8
    f32 = retrieval.batch_scores(corpus, qi, qw, method="act", iters=2)
    bf = retrieval.batch_scores(corpus, qi, qw, method="act", iters=2,
                                precision="bf16")
    _, ref_idx = jax.lax.top_k(-f32, k)
    _, got_idx = jax.lax.top_k(-bf, k)
    assert retrieval.topl_overlap(got_idx, ref_idx) >= 0.95


# -------------------------------------------------- VMEM layout halving

def test_block_layouts_halve_storage_slabs_under_bf16():
    """The static VMEM model reflects the policy: storage-role buffers
    (Z ladder, gathered ladders, candidate distance table) are exactly
    half as large under bf16, while index/accumulator buffers hold."""
    dims = dict(nq=8, v=2048, h=64, m=32, k=8)
    f32 = kops.block_layout("dist_topk", **dims)
    bf = kops.block_layout("dist_topk", **dims, dtype="bfloat16")
    assert bf.buffer("z").nbytes * 2 == f32.buffer("z").nbytes
    assert bf.buffer("s").nbytes == f32.buffer("s").nbytes
    assert bf.vmem_bytes() < f32.vmem_bytes()

    cdims = dict(nq=8, b=256, h=64, v=2048, k=8, iters=3, block_n=64)
    f32 = kops.block_layout("cand_pour", **cdims)
    bf = kops.block_layout("cand_pour", **cdims, dtype="bfloat16")
    assert bf.buffer("table").nbytes * 2 == f32.buffer("table").nbytes
    assert bf.vmem_bytes() < f32.vmem_bytes()


def test_vmem_pass_covers_bf16_profiles():
    from repro.analysis import vmem
    labels = [label for label, _, _ in vmem.check_configs()]
    assert any(label.endswith(":bf16") for label in labels), \
        "vmem pass lost its bf16-policy profiles"
    violations, checked = vmem.run()
    assert violations == [] and checked == len(labels)


# ------------------------------------------- checkpoint dtype round-trip

def test_restore_dtype_mismatch_is_typed_error(tmp_path):
    d = str(tmp_path)
    store.save(d, 0, {"x": jnp.ones((3, 2), jnp.bfloat16)})
    with pytest.raises(CheckpointCorrupt, match="dtype mismatch"):
        store.restore(d, 0, {"x": np.zeros((3, 2), np.float32)})
    out = store.restore(d, 0, {"x": jnp.zeros((3, 2), jnp.bfloat16)})
    assert jnp.asarray(out["x"]).dtype == jnp.bfloat16


def test_bf16_policy_index_snapshot_kill_restore(corpus, tmp_path):
    """A bf16-policy index survives snapshot/kill/restore with its
    policy intact and parity-equal scores — no silent upcast on the way
    back in."""
    from repro.serving import EmdServer, ServingPolicy, restore_server

    cfg = EngineConfig(method="act", iters=2, top_l=4, precision="bf16")
    index = EmdIndex.build(corpus, cfg)
    pol = ServingPolicy(ladder=("primary",), max_batch=2, flush_ms=5.0,
                        backoff_ms=0.0, max_retries=1, deadline_ms=10_000.0)
    d = str(tmp_path / "snap")

    async def serve_and_snapshot():
        from repro.serving import snapshot
        async with EmdServer(index, pol) as server:
            res = await server.search(corpus.ids[0], corpus.w[0])
            snapshot(server, d)
            return res

    async def restore_and_serve():
        server = restore_server(d, pol)
        assert server.config.precision == "bf16", \
            "restore dropped the precision policy"
        async with server:
            return await server.search(corpus.ids[0], corpus.w[0])

    before = asyncio.run(serve_and_snapshot())
    after = asyncio.run(restore_and_serve())
    np.testing.assert_array_equal(np.asarray(before.scores),
                                  np.asarray(after.scores))
    np.testing.assert_array_equal(np.asarray(before.indices),
                                  np.asarray(after.indices))


# ------------------------------------------------------- precision lint

def test_precision_lint_clean_on_policy_trace(corpus):
    from repro.analysis import precision_lint
    qi, qw = corpus.ids[:4], corpus.w[:4]

    def step(q_ids, q_w):
        return retrieval.batch_scores(corpus, q_ids, q_w, method="act",
                                      iters=2, precision="bf16")

    out = precision_lint.check_fn("clean:bf16", step, (qi, qw), nq=4,
                                  v=corpus.v, h=corpus.hmax)
    assert out == []


def test_precision_lint_flags_dropped_policy(corpus):
    """An allegedly-bf16 step that traces pure f32 (the kwarg fell off)
    is a loud violation, not a silent width doubling."""
    from repro.analysis import precision_lint
    qi, qw = corpus.ids[:4], corpus.w[:4]

    def step(q_ids, q_w):            # "bf16" case that ignores the policy
        return retrieval.batch_scores(corpus, q_ids, q_w, method="act",
                                      iters=2)

    out = precision_lint.check_fn("seeded:ignored", step, (qi, qw), nq=4,
                                  v=corpus.v, h=corpus.hmax)
    assert len(out) == 1 and "no bfloat16 avals" in out[0].message


def test_precision_lint_flags_f32_handoff(corpus):
    """A trace that downcasts SOMETHING to bf16 but leaves a Phase-1
    handoff f32 is the subtler regression the shape probe catches."""
    from repro.analysis import precision_lint
    qi, qw = corpus.ids[:4], corpus.w[:4]

    def step(q_ids, q_w):
        s = retrieval.batch_scores(corpus, q_ids, q_w, method="act",
                                   iters=2)               # handoffs f32
        # a traced bf16 op of NON-handoff shape: the policy "exists" in
        # the jaxpr, but the handoff arrays themselves stayed f32
        bonus = q_w.astype(jnp.bfloat16).astype(jnp.float32)
        return s + bonus.sum() * 0.0

    out = precision_lint.check_fn("seeded:handoff", step, (qi, qw), nq=4,
                                  v=corpus.v, h=corpus.hmax)
    assert out and any("float32" in v.message for v in out)


def test_step_cases_include_bf16_collective_subjects():
    """The guarded mesh step list carries the bf16 cases whose halved
    all-gather bytes the collectives manifest pins."""
    from repro.launch import search as S
    names = {c.name: c for c in S.step_cases()}
    for name in ("scores:act:dist:bf16", "scores:act:dist:kernels:bf16"):
        assert name in names, name
        assert names[name].precision == "bf16"
        assert names[name].scale_guarded


# ------------------------------------- cross-backend parity (slow, mesh)

@pytest.mark.slow
@pytest.mark.parametrize("policy,atol", [("bf16", 8e-3), ("bf16_agg", 0.4)])
def test_distributed_backend_policy_parity(policy, atol):
    """EngineConfig(precision=...) on backend="distributed" over the
    8-device host mesh matches the single-host engine under the same
    policy at the measured tolerance (subprocess: XLA_FLAGS must be set
    before jax initializes)."""
    import os
    import subprocess
    import sys

    xla = " ".join(
        f for f in os.environ.get("XLA_FLAGS", "").split()
        if not f.startswith("--xla_force_host_platform_device_count"))
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS=(xla
                          + " --xla_force_host_platform_device_count=8")
               .strip())
    script = f"""
import dataclasses, jax, numpy as np
from repro.api import EmdIndex, EngineConfig
from repro.data.synth import make_text_like

mesh = jax.make_mesh((4, 2), ("data", "model"))
corpus, _ = make_text_like(n_docs=24, vocab=64, m=8, doc_len=10, hmax=16)
q_ids, q_w = corpus.ids[:5], corpus.w[:5]
cfg = EngineConfig(method="act", iters=2, backend="distributed",
                   pad_multiple=16, precision={policy!r})
dst = EmdIndex.build(corpus, cfg, mesh=mesh)
ref = EmdIndex.build(corpus, dataclasses.replace(cfg, backend="reference"))
np.testing.assert_allclose(np.asarray(dst.scores(q_ids, q_w)),
                           np.asarray(ref.scores(q_ids, q_w)),
                           rtol=0, atol={atol})
pal = EmdIndex.build(corpus, dataclasses.replace(cfg, backend="pallas"))
np.testing.assert_allclose(np.asarray(pal.scores(q_ids, q_w)),
                           np.asarray(ref.scores(q_ids, q_w)),
                           rtol=0, atol={atol})
print("POLICY PARITY OK")
"""
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, cwd=".",
                         timeout=600)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert "POLICY PARITY OK" in res.stdout
