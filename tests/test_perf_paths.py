"""Equivalence tests for the performance-path variants vs the plain paths:
flash attention, partitionable top-k, packed/shard_map MoE, remat policies.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.layers as L
from repro.configs import smoke_config
from repro.core.lc import smallest_k
from repro.models import model as M


def test_flash_attention_matches_dense(rng):
    B, S, KV, G, hd = 2, 2048, 2, 3, 32
    q = jnp.asarray(rng.normal(size=(B, S, KV, G, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    for window in (0, 100, 513):
        out_f = L._flash_attention(q, k, v, jnp.int32(window), hd ** -0.5)
        qpos = jnp.arange(S)[:, None]
        kpos = jnp.arange(S)[None, :]
        ok = kpos <= qpos
        ok &= jnp.where(window > 0, (qpos - kpos) < window, True)
        mask = jnp.where(ok, 0.0, L.NEG_INF)
        s = (jnp.einsum("bqngh,btnh->bqngt", q * hd ** -0.5, k)
             + mask[None, :, None, None, :])
        out_d = jnp.einsum("bqngt,btnh->bqngh", jax.nn.softmax(s, -1), v)
        err = float(jnp.max(jnp.abs(out_f - out_d)))
        assert err < 1e-4, (window, err)


@pytest.mark.parametrize("shape", [(40, 17), (3, 64, 9)])
@pytest.mark.parametrize("k", [1, 4, 8])
def test_smallest_k_matches_lax_top_k(shape, k, rng):
    d = jnp.asarray(rng.normal(size=shape), jnp.float32)
    z, s = smallest_k(d, k)
    neg, sr = jax.lax.top_k(-d, k)
    np.testing.assert_allclose(np.asarray(z), np.asarray(-neg), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


def _moe_batch(cfg, rng):
    B, S = 2, 16
    return {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab),
            "labels": jnp.zeros((B, S), jnp.int32)}


def test_packed_moe_equivalent():
    cfg1 = smoke_config("mixtral-8x22b")
    rng = jax.random.PRNGKey(0)
    params = M.init(rng, cfg1)
    batch = _moe_batch(cfg1, rng)
    y1, _, _ = M.forward(params, batch, cfg1)
    cfg2 = dataclasses.replace(cfg1, moe_ff_shards=2)
    blocks = dict(params["blocks"])
    moe = dict(blocks["moe"])
    moe["w_up"] = jax.vmap(lambda w: L.pack_moe_weights(w, 2))(moe["w_up"])
    moe["w_gate"] = jax.vmap(lambda w: L.pack_moe_weights(w, 2))(moe["w_gate"])
    moe["w_down"] = jax.vmap(lambda w: L.pack_moe_down(w, 2))(moe["w_down"])
    blocks["moe"] = moe
    p2 = dict(params)
    p2["blocks"] = blocks
    y2, _, _ = M.forward(p2, batch, cfg2)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-3


def test_remat_policy_dots_same_loss_and_grads():
    cfg = dataclasses.replace(smoke_config("olmo-1b"), remat=True)
    cfg_d = dataclasses.replace(cfg, remat_policy="dots")
    rng = jax.random.PRNGKey(1)
    params = M.init(rng, cfg)
    batch = {"tokens": jax.random.randint(rng, (2, 16), 0, cfg.vocab),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    l1, g1 = jax.value_and_grad(lambda p: M.train_loss(p, batch, cfg))(params)
    l2, g2 = jax.value_and_grad(lambda p: M.train_loss(p, batch, cfg_d))(params)
    assert abs(float(l1) - float(l2)) < 1e-6
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2), strict=True):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


@pytest.mark.slow
def test_moe_shard_map_matches_constraint_path():
    """shard_map EP == plain path, on a real 8-device mesh (subprocess)."""
    import os
    import subprocess
    import sys
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    script = """
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import smoke_config
from repro.models import model as M
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = smoke_config("mixtral-8x22b")          # E=4 experts over model=4
cfg_sm = dataclasses.replace(cfg, moe_shard_map=True)
params = M.init(jax.random.PRNGKey(0), cfg)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                      cfg.vocab)}
y_ref, _, _ = M.forward(params, batch, cfg)
import contextlib
ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else contextlib.nullcontext()
with ctx:
    y_sm = jax.jit(lambda p, b: M.forward(p, b, cfg_sm)[0])(params, batch)
err = float(jnp.max(jnp.abs(y_ref - y_sm)))
assert err < 1e-3, err
print("SHMAP OK", err)
"""
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "SHMAP OK" in res.stdout
