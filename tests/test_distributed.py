"""Distributed integration tests — run in a subprocess with 8 host devices
(XLA_FLAGS must be set before jax initializes, so these can't share the
main pytest process, which runs single-device)."""
import os
import subprocess
import sys

import pytest

# Every subprocess gets exactly the 8-device host mesh these tests are
# written for (matching the CI job step's XLA_FLAGS): any inherited
# device-count flag is replaced, other exported XLA_FLAGS content (dump
# dirs etc.) is preserved, so local runs are self-sufficient regardless
# of the environment.
_XLA_FLAGS = " ".join(
    f for f in os.environ.get("XLA_FLAGS", "").split()
    if not f.startswith("--xla_force_host_platform_device_count"))
_ENV = dict(os.environ,
            XLA_FLAGS=(_XLA_FLAGS
                       + " --xla_force_host_platform_device_count=8").strip(),
            PYTHONPATH="src")


def _run(script: str):
    res = subprocess.run([sys.executable, "-c", script], env=_ENV,
                         capture_output=True, text=True, cwd=".",
                         timeout=600)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    return res.stdout


@pytest.mark.slow
def test_sharded_train_step_runs_and_improves():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import smoke_config
from repro.launch import mesh as Mx, steps as St
from repro.models import model as M
from repro.models.config import InputShape
from repro.optim import adamw
from repro.data.tokens import DataConfig, global_batch

mesh = Mx.make_test_mesh(2, 2, multi_pod=True)
cfg = smoke_config("olmo-1b")
shape = InputShape("t", 32, 8, "train")
step, _ = St.jit_train_step(cfg, shape, mesh,
                            opt_cfg=adamw.AdamWConfig(peak_lr=3e-3,
                                                      warmup_steps=2,
                                                      total_steps=40))
params = M.init(jax.random.PRNGKey(0), cfg)
opt = adamw.init(params, cfg.opt_state_dtype)
dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=0)
losses = []
import contextlib
ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else contextlib.nullcontext()
with ctx:
    for s in range(30):
        b = {k: jnp.asarray(v) for k, v in global_batch(dc, s).items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
assert all(np.isfinite(losses)), losses
assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses
print("TRAIN OK", losses[0], "->", losses[-1])
""")
    assert "TRAIN OK" in out


@pytest.mark.slow
def test_elastic_reshard_8_to_4():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import smoke_config
from repro.checkpoint import store
from repro.runtime import elastic
from repro.models import model as M
import tempfile

cfg = smoke_config("olmo-1b")
params = M.init(jax.random.PRNGKey(0), cfg)
d = tempfile.mkdtemp()
store.save(d, 5, params)

mesh8 = jax.make_mesh((4, 2), ("data", "model"))
mesh4 = jax.make_mesh((2, 2), ("data", "model"))
p8 = elastic.restore_on_mesh(d, 5, params, mesh8)
p4 = elastic.restore_on_mesh(d, 5, params, mesh4)
for a, b, c in zip(jax.tree.leaves(params), jax.tree.leaves(p8),
                   jax.tree.leaves(p4), strict=True):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
# live reshard between meshes
p4b = elastic.reshard_live(p8, mesh4)
for a, b in zip(jax.tree.leaves(p8), jax.tree.leaves(p4b), strict=True):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("ELASTIC OK")
""")
    assert "ELASTIC OK" in out


@pytest.mark.slow
def test_compressed_cross_pod_psum():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.optim.grad_utils import compressed_psum_tree

mesh = jax.make_mesh((8,), ("pod",))
if hasattr(jax, "shard_map"):
    shard_map = partial(jax.shard_map, check_vma=False)
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _sm
    shard_map = partial(_sm, check_rep=False)

@partial(shard_map, mesh=mesh, in_specs=(P("pod"), P()),
         out_specs=P("pod"))
def reduce_grads(g, key):
    return compressed_psum_tree({"g": g}, key, "pod")["g"]

g = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
key = jax.random.PRNGKey(1)
import contextlib
ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else contextlib.nullcontext()
with ctx:
    out = reduce_grads(g, key)
exact = jnp.broadcast_to(jnp.sum(g, 0, keepdims=True), g.shape)
rel = float(jnp.max(jnp.abs(out - exact)) / jnp.max(jnp.abs(exact)))
assert rel < 0.05, rel
print("COMPRESSED PSUM OK", rel)
""")
    assert "COMPRESSED PSUM OK" in out


@pytest.mark.slow
def test_distributed_search_matches_reference():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np, contextlib
from repro.data.synth import make_text_like
from repro.launch.search import make_search_step, search_shardings, jit_search_step
from repro.core import lc
from repro.configs.emd_20news import EMDWorkload

mesh = jax.make_mesh((4, 2), ("data", "model"))
corpus, _ = make_text_like(n_docs=16, vocab=64, m=8, doc_len=24, hmax=16)
w = EMDWorkload(name="t", n_db=16, vocab=64, dim=8, hmax=16, iters=2,
                queries=8)
step = jit_search_step(w, mesh, top_l=4)
q_ids, q_w = corpus.ids[:8], corpus.w[:8]
ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else contextlib.nullcontext()
with ctx:
    scores, idx = step(corpus.ids, corpus.w, corpus.coords, q_ids, q_w)
# reference: single-device engine
for u in range(8):
    ref = lc.lc_act_scores(corpus, q_ids[u], q_w[u], iters=2)
    neg, ridx = jax.lax.top_k(-ref, 4)
    np.testing.assert_allclose(np.asarray(scores[u]), np.asarray(-neg),
                               rtol=1e-5, atol=1e-6)
print("SEARCH OK")
""")
    assert "SEARCH OK" in out


@pytest.mark.slow
def test_distributed_every_method_matches_batched():
    """Acceptance: EVERY method in retrieval.METHODS scores identically
    (within tolerance) on backend="distributed" over an 8-device (4, 2)
    mesh vs the single-host batched engine — including pad rows
    (pad_multiple pads 24 -> 32) and a block_q that divides neither the
    query count nor the per-shard count. Also covers the symmetric
    measure on the mesh."""
    out = _run("""
import dataclasses, jax, numpy as np
from repro.api import EmdIndex, EngineConfig
from repro.core.retrieval import METHODS
from repro.data.synth import make_text_like

mesh = jax.make_mesh((4, 2), ("data", "model"))
corpus, _ = make_text_like(n_docs=24, vocab=64, m=8, doc_len=10, hmax=16)
q_ids, q_w = corpus.ids[:5], corpus.w[:5]       # odd nq: padded to the mesh
assert bool((np.asarray(q_w) == 0.0).any())     # query-side padding in play
for method in sorted(METHODS):
    cfg = EngineConfig(method=method, iters=2, backend="distributed",
                       pad_multiple=16, block_q=3)
    dst = EmdIndex.build(corpus, cfg, mesh=mesh)
    assert dst._padded_corpus.n == 32 > corpus.n
    ref = EmdIndex.build(corpus, dataclasses.replace(cfg,
                                                     backend="reference"))
    np.testing.assert_allclose(np.asarray(dst.scores(q_ids, q_w)),
                               np.asarray(ref.scores(q_ids, q_w)),
                               rtol=1e-5, atol=1e-6, err_msg=method)
    _, idx = dst.search(q_ids, q_w, top_l=8)
    assert int(np.asarray(idx).max()) < corpus.n, method   # pads masked
    print("METHOD OK", method)
sym = EngineConfig(method="rwmd", symmetric=True, backend="distributed",
                   pad_multiple=16, block_q=3)
d = EmdIndex.build(corpus, sym, mesh=mesh)
r = EmdIndex.build(corpus, dataclasses.replace(sym, backend="reference"))
np.testing.assert_allclose(np.asarray(d.scores(q_ids, q_w)),
                           np.asarray(r.scores(q_ids, q_w)),
                           rtol=1e-5, atol=1e-6)
print("ALL METHODS OK")
""")
    assert "ALL METHODS OK" in out
    for method in ("act", "bow", "omr", "rwmd", "rwmd_rev", "wcd"):
        assert f"METHOD OK {method}" in out


@pytest.mark.slow
def test_distributed_all_pairs_dedup_matches_reference():
    """Corpus-as-queries all-pairs on a small vocabulary crosses the
    unique-bin dedup gate INSIDE the SPMD step (jnp.unique + inverse
    gather over DP-sharded query ids) — parity vs the single-host
    engine, which crosses the same gate."""
    out = _run("""
import dataclasses, jax, numpy as np
from repro.api import EmdIndex, EngineConfig
from repro.core import lc
from repro.data.synth import make_text_like

mesh = jax.make_mesh((4, 2), ("data", "model"))
corpus, _ = make_text_like(n_docs=24, n_classes=4, vocab=40, m=6,
                           doc_len=30, hmax=16)
assert corpus.n * corpus.hmax >= lc.DEDUP_STACK_RATIO * corpus.v
cfg = EngineConfig(method="rwmd", iters=0, backend="distributed",
                   pad_multiple=8, block_q=5)
dst = EmdIndex.build(corpus, cfg, mesh=mesh)
ref = EmdIndex.build(corpus, dataclasses.replace(cfg, backend="reference"))
np.testing.assert_allclose(np.asarray(dst.all_pairs()),
                           np.asarray(ref.all_pairs()),
                           rtol=1e-5, atol=1e-6)
print("DEDUP SPMD OK")
""")
    assert "DEDUP SPMD OK" in out


@pytest.mark.slow
def test_distributed_scan_engine_matches_batched_step():
    """batch_engine="scan" on the mesh replays the per-query graphs — the
    verification escape hatch exists on the distributed backend too."""
    out = _run("""
import dataclasses, jax, numpy as np
from repro.api import EmdIndex, EngineConfig
from repro.data.synth import make_text_like

mesh = jax.make_mesh((2, 4), ("data", "model"))
corpus, _ = make_text_like(n_docs=16, vocab=64, m=8, doc_len=24, hmax=16)
cfg = EngineConfig(method="act", iters=2, backend="distributed",
                   pad_multiple=8)
fast = EmdIndex.build(corpus, cfg, mesh=mesh)
slow = EmdIndex.build(corpus, dataclasses.replace(cfg, batch_engine="scan"),
                      mesh=mesh)
q_ids, q_w = corpus.ids[:4], corpus.w[:4]
np.testing.assert_allclose(np.asarray(fast.scores(q_ids, q_w)),
                           np.asarray(slow.scores(q_ids, q_w)),
                           rtol=1e-5, atol=1e-6)
print("SCAN STEP OK")
""")
    assert "SCAN STEP OK" in out


@pytest.mark.slow
def test_distributed_cascade_exact_and_matches_reference():
    """Cascaded prune-and-rescore on the 8-device (4, 2) mesh: the
    shard-blocked stage-wise top-budget (topk_blocks = model axis size,
    ladder-merged winners) produces (a) the identical top-l as the
    single-host cascade for the same spec, and (b) the admissible-cascade
    exactness property — budgets covering every true top-l neighbor's
    stage rank => identical top-l index set as full-corpus rescoring —
    for both the act and ict rescorers. Pad rows (24 -> 32) in play."""
    out = _run("""
import dataclasses, jax, numpy as np
import jax.numpy as jnp
from repro import cascade
from repro.api import EmdIndex, EngineConfig
from repro.cascade import CascadeSpec, CascadeStage, rescore
from repro.core import retrieval
from repro.data.synth import make_text_like

mesh = jax.make_mesh((4, 2), ("data", "model"))
corpus, _ = make_text_like(n_docs=24, n_classes=4, vocab=64, m=8,
                           doc_len=10, hmax=16, seed=5)
nq, top_l = 5, 3
q_ids, q_w = corpus.ids[:nq], corpus.w[:nq]

for rescorer, stages in (("act", (("rwmd", 0), ("omr", 0))),
                         ("ict", (("rwmd", 0), ("act", 1)))):
    iters = 2 if rescorer == "act" else 1
    all_rows = jnp.broadcast_to(jnp.arange(corpus.n, dtype=jnp.int32),
                                (nq, corpus.n))
    full = np.asarray(rescore.resolve(rescorer).fn(
        corpus, q_ids, q_w, all_rows, iters=iters))
    ref_idx = np.argsort(full, axis=1, kind="stable")[:, :top_l]
    budgets = []
    for m, it in stages:
        s = np.asarray(retrieval.batch_scores(corpus, q_ids, q_w,
                                              method=m, iters=it))
        order = np.argsort(s, axis=1, kind="stable")
        rank = np.empty_like(order)
        np.put_along_axis(rank, order,
                          np.arange(s.shape[1])[None, :], axis=1)
        budgets.append(max(top_l,
                           int(np.take_along_axis(rank, ref_idx,
                                                  axis=1).max()) + 1))
    for i in range(len(budgets) - 2, -1, -1):
        budgets[i] = max(budgets[i], budgets[i + 1])
    spec = CascadeSpec(stages=tuple(
        CascadeStage(m, b, iters=it)
        for (m, it), b in zip(stages, budgets, strict=True)),
        rescorer=rescorer, rescorer_iters=iters)
    assert spec.admissible

    cfg = EngineConfig(method="act", iters=iters, top_l=top_l,
                       cascade=spec, backend="distributed",
                       pad_multiple=16, block_q=3)
    dst = EmdIndex.build(corpus, cfg, mesh=mesh)
    assert dst._padded_corpus.n == 32 > corpus.n
    s_d, i_d = dst.search(q_ids, q_w)
    # (a) parity with the single-host cascade
    ref = EmdIndex.build(corpus,
                         dataclasses.replace(cfg, backend="reference"))
    s_r, i_r = ref.search(q_ids, q_w)
    np.testing.assert_array_equal(np.sort(np.asarray(i_d), 1),
                                  np.sort(np.asarray(i_r), 1))
    np.testing.assert_allclose(np.sort(np.asarray(s_d), 1),
                               np.sort(np.asarray(s_r), 1),
                               rtol=1e-5, atol=1e-6)
    # (b) admissible-cascade exactness vs full-corpus rescoring
    np.testing.assert_array_equal(np.sort(np.asarray(i_d), 1),
                                  np.sort(ref_idx, 1))
    assert int(np.asarray(i_d).max()) < corpus.n      # pads masked
    print("CASCADE MESH OK", rescorer, budgets)
print("ALL CASCADE OK")
""")
    assert "ALL CASCADE OK" in out
    assert "CASCADE MESH OK act" in out
    assert "CASCADE MESH OK ict" in out


@pytest.mark.slow
def test_distributed_cascade_kernel_conformance():
    """The fused candidate kernels inside the mesh cascade step on the
    8-device (4, 2) mesh: ``use_kernels=True`` (interpret-mode Pallas
    lowers to plain HLO, so SPMD shards it like any other op) returns
    the identical top-l set as (a) the non-kernel mesh cascade and
    (b) full-corpus rescoring — the acceptance criterion's mesh half.
    Budgets cover the true neighbors' stage ranks under both paths."""
    out = _run("""
import contextlib, jax, numpy as np
import jax.numpy as jnp
from repro.cascade import CascadeSpec, CascadeStage, rescore
from repro.configs.emd_20news import EMDWorkload
from repro.core import retrieval
from repro.core.lc import Corpus
from repro.data.synth import make_text_like
from repro.launch import search as Sx

mesh = jax.make_mesh((4, 2), ("data", "model"))
corpus, _ = make_text_like(n_docs=24, n_classes=4, vocab=64, m=8,
                           doc_len=10, hmax=16, seed=5)
nq, top_l, iters = 5, 3, 2
q_ids, q_w = corpus.ids[:nq], corpus.w[:nq]
stages = (("rwmd", 0), ("omr", 0))

# budgets covering the true act top-l ranks under BOTH paths
budget_req = []
for uk in (False, True):
    all_rows = jnp.broadcast_to(jnp.arange(corpus.n, dtype=jnp.int32),
                                (nq, corpus.n))
    full = np.asarray(rescore.resolve("act").fn(
        corpus, q_ids, q_w, all_rows, iters=iters, use_kernels=uk))
    ref_idx = np.argsort(full, axis=1, kind="stable")[:, :top_l]
    req = []
    for m, it in stages:
        s = np.asarray(retrieval.batch_scores(corpus, q_ids, q_w,
                                              method=m, iters=it,
                                              use_kernels=uk))
        order = np.argsort(s, axis=1, kind="stable")
        rank = np.empty_like(order)
        np.put_along_axis(rank, order,
                          np.arange(s.shape[1])[None, :], axis=1)
        req.append(max(top_l,
                       int(np.take_along_axis(rank, ref_idx,
                                              axis=1).max()) + 1))
    budget_req.append(req)
budgets = [max(a, b) for a, b in zip(*budget_req, strict=True)]
for i in range(len(budgets) - 2, -1, -1):
    budgets[i] = max(budgets[i], budgets[i + 1])
spec = CascadeSpec(stages=tuple(CascadeStage(m, b, iters=it)
                                for (m, it), b in zip(stages, budgets, strict=True)),
                   rescorer="act", rescorer_iters=iters)
assert spec.admissible

workload = EMDWorkload(name="t", n_db=corpus.n, vocab=corpus.v,
                       dim=corpus.m, hmax=corpus.hmax, iters=iters,
                       queries=nq, method="act")
n_pad = 32
padded = Corpus(ids=jnp.pad(corpus.ids, ((0, n_pad - corpus.n), (0, 0))),
                w=jnp.pad(corpus.w, ((0, n_pad - corpus.n), (0, 0))),
                coords=corpus.coords)
in_sh, _ = Sx.search_shardings(mesh, workload)
p_ids = jax.device_put(padded.ids, in_sh[0])
p_w = jax.device_put(padded.w, in_sh[1])
coords = jax.device_put(padded.coords, in_sh[2])
qi = jnp.pad(q_ids, ((0, 8 - nq), (0, 0)))      # data axis = 4: pad to 8
qw = jnp.pad(q_w, ((0, 8 - nq), (0, 0)))

set_mesh = getattr(jax, "set_mesh", None)
ctx = set_mesh(mesh) if set_mesh else contextlib.nullcontext()
results = {}
with ctx:
    for uk in (False, True):
        step = Sx.jit_cascade_search_step(workload, mesh, spec,
                                          top_l=top_l, pad_multiple=16,
                                          block_q=3, use_kernels=uk)
        s, i = step(p_ids, p_w, coords, qi, qw)
        results[uk] = (np.asarray(s)[:nq], np.asarray(i)[:nq])

i_ref, i_ker = results[False][1], results[True][1]
np.testing.assert_array_equal(np.sort(i_ker, 1), np.sort(i_ref, 1))
np.testing.assert_allclose(np.sort(results[True][0], 1),
                           np.sort(results[False][0], 1),
                           rtol=1e-6, atol=1e-7)
assert int(i_ker.max()) < corpus.n                # pads masked
full = np.asarray(rescore.resolve("act").fn(
    corpus, q_ids, q_w,
    jnp.broadcast_to(jnp.arange(corpus.n, dtype=jnp.int32),
                     (nq, corpus.n)), iters=iters))
ref_idx = np.argsort(full, axis=1, kind="stable")[:, :top_l]
np.testing.assert_array_equal(np.sort(i_ker, 1), np.sort(ref_idx, 1))
print("CASCADE KERNEL MESH OK", budgets)
""")
    assert "CASCADE KERNEL MESH OK" in out


@pytest.mark.slow
def test_distributed_compiled_cascade_matches_interpret_oracle():
    """The acceptance parity check: the distributed kernel cascade —
    every Pallas launch routed through the ``kernels/partition``
    shard_map shims, the structure that compiles on real device meshes —
    returns the exact top-l of the single-host ``backend="pallas"``
    cascade (the interpret-mode conformance oracle) on the 8-device
    (2, 4) mesh, end to end through ``EmdIndex``."""
    out = _run("""
import jax, numpy as np
from repro.api import EmdIndex, EngineConfig
from repro.data.synth import make_text_like

mesh = jax.make_mesh((2, 4), ("data", "model"))
corpus, _ = make_text_like(n_docs=64, n_classes=4, vocab=96, m=8,
                           doc_len=12, hmax=16, seed=7)
nq, top_l = 16, 4
q_ids, q_w = corpus.ids[:nq], corpus.w[:nq]
cfg = EngineConfig(method="act", iters=2, top_l=top_l, cascade="fast",
                   backend="pallas")
assert cfg.score_kwargs()["use_kernels"]
oracle = EmdIndex.build(corpus, cfg)
s_o, i_o = oracle.search(q_ids, q_w)

import dataclasses
dcfg = dataclasses.replace(cfg, backend="distributed", pad_multiple=8)
assert dcfg.score_kwargs()["use_kernels"]   # kernels stay ON on the mesh
dist = EmdIndex.build(corpus, dcfg, mesh=mesh)
s_d, i_d = dist.search(q_ids, q_w)
np.testing.assert_array_equal(np.sort(np.asarray(i_d), 1),
                              np.sort(np.asarray(i_o), 1))
np.testing.assert_allclose(np.sort(np.asarray(s_d), 1),
                           np.sort(np.asarray(s_o), 1),
                           rtol=1e-6, atol=1e-7)
print("COMPILED CASCADE PARITY OK")
""")
    assert "COMPILED CASCADE PARITY OK" in out


@pytest.mark.slow
def test_emd_index_distributed_backend_multi_device():
    """EmdIndex(backend='distributed') on an 8-device (4, 2) mesh matches
    the reference backend — identical code path as single-host callers."""
    out = _run("""
import jax, numpy as np
from repro.api import EmdIndex, EngineConfig
from repro.data.synth import make_text_like

mesh = jax.make_mesh((4, 2), ("data", "model"))
corpus, _ = make_text_like(n_docs=24, vocab=64, m=8, doc_len=24, hmax=16)
ref = EmdIndex.build(corpus, EngineConfig(method="act", iters=2, top_l=4))
dst = EmdIndex.build(corpus, EngineConfig(method="act", iters=2, top_l=4,
                                          backend="distributed",
                                          pad_multiple=8), mesh=mesh)
# odd batch size: not divisible by the data axis -> padded internally
q_ids, q_w = corpus.ids[:5], corpus.w[:5]
s_ref = np.asarray(ref.scores(q_ids, q_w))
s_dst = np.asarray(dst.scores(q_ids, q_w))
np.testing.assert_allclose(s_ref, s_dst, rtol=1e-5, atol=1e-6)
t_ref, i_ref = ref.search(q_ids, q_w)
t_dst, i_dst = dst.search(q_ids, q_w)
np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_dst))
print("INDEX DIST OK")
""")
    assert "INDEX DIST OK" in out


@pytest.mark.slow
def test_static_check_cli_clean_on_main():
    """The full static-check CLI (registry + hazards + vmem +
    collectives vs the committed golden manifest) exits 0 on the repo as
    it stands — the same invocation CI's static-checks job runs."""
    res = subprocess.run(
        [sys.executable, "-m", "repro.analysis.check"], env=_ENV,
        capture_output=True, text=True, cwd=".", timeout=600)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    for passname in ("registry", "hazards", "vmem", "collectives"):
        assert f"PASS {passname}" in res.stdout, res.stdout


@pytest.mark.slow
def test_collective_scaling_guard_catches_seeded_gather():
    """Seed the violation the scaling guard exists for: a step whose
    (nq, n) score matrix is forced replicated (one all-gather of the
    whole matrix over 'model'), compiled at the guard's two corpus
    sizes. The guard must flag it, and must stay quiet on the real
    registry-built steps at the same sizes."""
    out = _run("""
import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.analysis import collectives_check as C
from repro.launch import search as S

mesh = C.make_mesh()
cases = {c.name: c for c in S.step_cases()}
case = cases["scores:rwmd:dist"]

def bad_step_fn(workload):
    step = S.make_scores_step(workload.iters, method="rwmd", engine="dist")
    def bad(ids, w, coords, q_ids, q_w):
        s = step(ids, w, coords, q_ids, q_w)
        # Replicate the (nq, n) score matrix: the corpus-scaled
        # all-gather the shard-local contract forbids.
        return jax.lax.with_sharding_constraint(
            s, NamedSharding(mesh, P(None, None)))
    in_sh, _ = S.search_shardings(mesh, workload)
    return jax.jit(bad, in_shardings=in_sh,
                   out_shardings=NamedSharding(mesh, P(None, None)))

n0, n1 = C.SCALE_N_DBS
violations = C.check_scaling(
    case, mesh,
    small_fn=bad_step_fn(C.check_workload(n0)),
    big_fn=bad_step_fn(C.check_workload(n1)))
assert violations, "seeded corpus-scaled all-gather not flagged"
assert "scale with the corpus" in violations[0].message, violations
assert C.check_scaling(case, mesh) == []          # real step stays clean
assert C.check_scaling(cases["cascade:pinned:dist"], mesh) == []
print("SCALING GUARD OK", violations[0].message[:60])
""")
    assert "SCALING GUARD OK" in out


@pytest.mark.slow
def test_reshard_live_index_tables_8_to_4_to_8():
    """Satellite of the serving PR: survivor-only recovery of a BUILT
    index. ``elastic.reshard_live`` moves the Phase-1 tables of an
    8-device index onto the surviving 4-device mesh in memory (no
    checkpoint round-trip), parity-checked against a full rebuild, and
    the resharded tables actually serve — spliced under the small mesh's
    jitted step they return the identical top-l. Then back up 4 -> 8
    (the node returns)."""
    out = _run("""
import dataclasses, jax, numpy as np
from repro.api import EmdIndex, EngineConfig
from repro.configs.emd_20news import EMDWorkload
from repro.core.lc import Corpus
from repro.data.synth import make_text_like
from repro.launch import search as dsearch
from repro.runtime import elastic

corpus, _ = make_text_like(n_docs=24, vocab=64, m=8, doc_len=10, hmax=16)
cfg = EngineConfig(method="act", iters=2, top_l=4, backend="distributed",
                   pad_multiple=8)
mesh8 = jax.make_mesh((4, 2), ("data", "model"))
mesh4 = jax.make_mesh((2, 2), ("data", "model"))
idx8 = EmdIndex.build(corpus, cfg, mesh=mesh8)
q_ids, q_w = corpus.ids[:5], corpus.w[:5]
s8, i8 = idx8.search(q_ids, q_w)

def table_shardings(mesh):
    w = EMDWorkload(name="emd-index", n_db=corpus.n, vocab=corpus.v,
                    dim=corpus.m, hmax=corpus.hmax,
                    iters=cfg.effective_iters, queries=0, method=cfg.method)
    in_sh, _ = dsearch.scores_shardings(mesh, w, method=cfg.method)
    return {"ids": in_sh[0], "w": in_sh[1], "coords": in_sh[2]}

tables8 = {"ids": idx8._padded_corpus.ids, "w": idx8._padded_corpus.w,
           "coords": idx8._padded_corpus.coords}
t4 = elastic.reshard_live(tables8, mesh4, shardings=table_shardings(mesh4))
dev4 = set(mesh4.devices.ravel().tolist())
for leaf in jax.tree.leaves(t4):
    assert set(leaf.devices()) <= dev4, (leaf.devices(), dev4)
# parity vs a full rebuild on the surviving mesh
idx4 = EmdIndex.build(corpus, cfg, mesh=mesh4)
for k in tables8:
    np.testing.assert_array_equal(np.asarray(t4[k]),
                                  np.asarray(getattr(idx4._padded_corpus, k)))
# the resharded tables SERVE under the small mesh's step
idx4b = dataclasses.replace(idx4, _padded_corpus=Corpus(**t4))
s4, i4 = idx4b.search(q_ids, q_w)
np.testing.assert_array_equal(np.asarray(i8), np.asarray(i4))
np.testing.assert_allclose(np.asarray(s8), np.asarray(s4),
                           rtol=1e-5, atol=1e-6)
# scale back up: 4 -> 8
t8 = elastic.reshard_live(t4, mesh8, shardings=table_shardings(mesh8))
for k in tables8:
    np.testing.assert_array_equal(np.asarray(t8[k]), np.asarray(tables8[k]))
idx8b = dataclasses.replace(idx8, _padded_corpus=Corpus(**t8))
s8b, i8b = idx8b.search(q_ids, q_w)
np.testing.assert_array_equal(np.asarray(i8), np.asarray(i8b))
print("RESHARD LIVE OK")
""")
    assert "RESHARD LIVE OK" in out


@pytest.mark.slow
def test_distributed_sourced_cascade_matches_single_host():
    """Sourced cascades (both sublinear sources) on the 8-device (4, 2)
    mesh: the source state rides into the SPMD step as replicated
    trailing operands, and the distributed top-l matches the single-host
    reference backend fed the SAME built source — for the IVF/LSH source
    with the exact-centroid refine path on and for the cluster tree."""
    out = _run("""
import dataclasses, jax, numpy as np
from repro.api import EmdIndex, EngineConfig
from repro.candidates import CentroidLSHSpec, ClusterTreeSpec
from repro.cascade import CascadeSpec, CascadeStage
from repro.data.synth import make_clustered_text

mesh = jax.make_mesh((4, 2), ("data", "model"))
corpus, _ = make_clustered_text(90, n_topics=4, vocab=128, m=8, hmax=16,
                                min_len=8, seed=3)
q_ids, q_w = corpus.ids[:5], corpus.w[:5]       # odd nq: padded to the mesh
for src_spec in (CentroidLSHSpec(n_buckets=8, probes=4, bucket_cap=24,
                                 refine=48),
                 ClusterTreeSpec(branching=4, depth=2, beam=4, probes=3,
                                 leaf_cap=16)):
    spec = CascadeSpec(stages=(CascadeStage("rwmd", 16),),
                       rescorer="act", rescorer_iters=2, source=src_spec)
    cfg = EngineConfig(method="act", iters=2, top_l=4, cascade=spec,
                       backend="distributed", pad_multiple=16, block_q=3)
    dst = EmdIndex.build(corpus, cfg, mesh=mesh)
    assert dst._padded_corpus.n > corpus.n          # pad rows in play
    ref = EmdIndex.build(corpus,
                         dataclasses.replace(cfg, backend="reference"),
                         source=dst.source)         # same built source
    s_d, i_d = dst.search(q_ids, q_w)
    s_r, i_r = ref.search(q_ids, q_w)
    np.testing.assert_array_equal(np.sort(np.asarray(i_d), 1),
                                  np.sort(np.asarray(i_r), 1))
    np.testing.assert_allclose(np.sort(np.asarray(s_d), 1),
                               np.sort(np.asarray(s_r), 1),
                               rtol=1e-5, atol=1e-6)
    assert int(np.asarray(i_d).max()) < corpus.n    # pads masked
    print("SOURCED MESH OK", src_spec.kind)
print("ALL SOURCED OK")
""")
    assert "ALL SOURCED OK" in out
    assert "SOURCED MESH OK centroid_lsh" in out
    assert "SOURCED MESH OK cluster_tree" in out


@pytest.mark.slow
def test_sourced_cascade_traffic_stays_flat():
    """The subsystem's core promise under the scaling guard: compiling
    the sourced cascade steps at the guard's two corpus sizes, cross-mesh
    traffic and FLOPs must NOT grow with the corpus (only the replicated
    source state and probed gathers may appear) — for the LSH source
    (refine on), its kernel variant, and the cluster tree."""
    out = _run("""
from repro.analysis import collectives_check as C
from repro.launch import search as S

mesh = C.make_mesh()
cases = {c.name: c for c in S.step_cases()}
for name in ("cascade:sourced:lsh:dist", "cascade:sourced:lsh:dist:kernels",
             "cascade:sourced:tree:dist"):
    case = cases[name]
    assert case.scale_guarded
    assert C.check_scaling(case, mesh) == [], name
    print("FLAT OK", name)
print("ALL FLAT OK")
""")
    assert "ALL FLAT OK" in out
    assert "FLAT OK cascade:sourced:tree:dist" in out


@pytest.mark.slow
def test_emd_server_recovers_on_mesh_change():
    """Serving-level recovery on mesh change: a live EmdServer over a
    distributed-backend index rebuilds every tier on the surviving mesh
    as a new generation (in-flight semantics preserved) and keeps
    serving identical results."""
    out = _run("""
import asyncio, jax, numpy as np
from repro.api import EmdIndex, EngineConfig
from repro.data.synth import make_text_like
from repro.serving import EmdServer, ServingPolicy

corpus, _ = make_text_like(n_docs=24, vocab=64, m=8, doc_len=10, hmax=16)
cfg = EngineConfig(method="act", iters=2, top_l=4, backend="distributed",
                   pad_multiple=8)
mesh8 = jax.make_mesh((4, 2), ("data", "model"))
mesh4 = jax.make_mesh((2, 2), ("data", "model"))
index = EmdIndex.build(corpus, cfg, mesh=mesh8)
policy = ServingPolicy(ladder=("primary", "wcd"), max_batch=4,
                       flush_ms=5.0, backoff_ms=0.0, deadline_ms=60_000)

async def main():
    async with EmdServer(index, policy) as server:
        before = await server.search(corpus.ids[0], corpus.w[0])
        server.reshard(mesh4)            # half the machine went away
        after = await server.search(corpus.ids[0], corpus.w[0])
        assert after.generation == before.generation + 1
        np.testing.assert_array_equal(before.scores, after.scores)
        np.testing.assert_array_equal(before.indices, after.indices)
        server.reshard(mesh8)            # and came back
        again = await server.search(corpus.ids[0], corpus.w[0])
        np.testing.assert_array_equal(before.scores, again.scores)

asyncio.run(main())
print("SERVER MESH RECOVERY OK")
""")
    assert "SERVER MESH RECOVERY OK" in out
