"""Quickstart: every distance measure on one histogram pair + a top-5 search.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.api import EmdIndex, EngineConfig
from repro.core import (act, emd_exact, ict, l1_normalize, omr,
                        pairwise_dist, rwmd, sinkhorn_cost)
from repro.data.synth import make_text_like


def main() -> None:
    rng = np.random.default_rng(0)
    # Two histograms over 3-D embedded coordinates, one shared coordinate.
    P = rng.normal(size=(5, 3))
    Q = rng.normal(size=(6, 3))
    Q[0] = P[0]                                   # overlap
    p = l1_normalize(jnp.asarray(rng.uniform(0.1, 1.0, 5), jnp.float32))
    q = l1_normalize(jnp.asarray(rng.uniform(0.1, 1.0, 6), jnp.float32))
    C = pairwise_dist(jnp.asarray(P, jnp.float32), jnp.asarray(Q, jnp.float32))

    print("Theorem 2 chain (each a tighter lower bound of EMD):")
    print(f"  RWMD  = {float(rwmd(p, q, C)):.4f}")
    print(f"  OMR   = {float(omr(p, q, C)):.4f}")
    print(f"  ACT-1 = {float(act(p, q, C, iters=1)):.4f}")
    print(f"  ACT-3 = {float(act(p, q, C, iters=3)):.4f}")
    print(f"  ICT   = {float(ict(p, q, C)):.4f}")
    print(f"  EMD   = {emd_exact(p, q, C):.4f}   (exact LP)")
    print(f"  Sinkhorn(lam=20) = {float(sinkhorn_cost(p, q, C)):.4f} "
          "(regularized, above EMD)")

    corpus, labels = make_text_like(n_docs=64, vocab=256, m=16, doc_len=40,
                                    hmax=24, seed=1)
    index = EmdIndex.build(corpus, EngineConfig(method="act", iters=2,
                                                top_l=5))
    scores, idx = index.search(corpus.ids[7], corpus.w[7])
    print("\nLC-ACT top-5 neighbors of doc 7 "
          f"(label {labels[7]}): ids={np.asarray(idx).tolist()} "
          f"labels={labels[np.asarray(idx)].tolist()}")
    print(f"scores={np.round(np.asarray(scores), 4).tolist()}")


if __name__ == "__main__":
    main()
