"""Serving driver: batched prefill + decode, then EMD neighbor retrieval.

Prefills a batch of prompts through the reduced model, greedily decodes
continuations token by token (the serve-side path the prefill_32k /
decode_32k dry-run cells lower at production scale), then routes each
generated sequence through the unified ``EmdIndex`` serving API to
retrieve its nearest documents — the retrieval-augmented serving loop the
ROADMAP's production system runs per request.

Run: PYTHONPATH=src python examples/serve_decode.py [--arch gemma3-27b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import EmdIndex, EngineConfig
from repro.configs import smoke_config
from repro.core.histogram import docs_to_corpus
from repro.data.synth import make_text_like
from repro.data.tokens import DataConfig, global_batch
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    params = M.init(jax.random.PRNGKey(0), cfg)
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.prompt_len,
                    global_batch=args.batch, seed=7)
    prompts = jnp.asarray(global_batch(dc, 0)["tokens"])
    print(f"{cfg.name} (reduced): prefill {prompts.shape} then decode "
          f"{args.gen_len} tokens")

    total = args.prompt_len + args.gen_len
    decode = jax.jit(lambda p, b, c: M.decode_step(p, b, c, cfg))

    t0 = time.perf_counter()
    cache = M.init_decode_cache(cfg, args.batch, total, dtype=jnp.float32)
    # prefill via the decode path token-by-token for cache layout parity
    # with M.prefill (which returns a compact cache); timing reported for
    # the decode loop only.
    for t in range(args.prompt_len):
        logits, cache = decode(params, {"tokens": prompts[:, t:t + 1],
                                        "cache_index": jnp.int32(t)}, cache)
    t_prefill = time.perf_counter() - t0

    out = []
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
    t0 = time.perf_counter()
    for t in range(args.prompt_len, total):
        out.append(tok)
        logits, cache = decode(params, {"tokens": tok,
                                        "cache_index": jnp.int32(t)}, cache)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"prefill(sequential) {t_prefill:.2f}s; decode "
          f"{args.gen_len} x {args.batch} tokens in {dt:.2f}s "
          f"({1e3 * dt / args.gen_len:.1f} ms/token/batch)")
    print("continuations:", gen[:, :8].tolist())
    assert bool(jnp.isfinite(logits).all())

    # Retrieval stage: the decoded sequences become EMD queries against a
    # document store served by EmdIndex (one build, batched queries).
    store, _ = make_text_like(n_docs=128, vocab=512, m=16, doc_len=40,
                              hmax=24, seed=11)
    index = EmdIndex.build(store, EngineConfig(method="act", iters=2,
                                               top_l=3))
    seqs = np.asarray(jnp.concatenate([prompts, gen], axis=1)) % store.v
    queries = docs_to_corpus(list(seqs), np.asarray(store.coords),
                             store.hmax)
    t0 = time.perf_counter()
    scores, idx = index.search(queries.ids, queries.w)
    jax.block_until_ready(scores)
    dt_r = time.perf_counter() - t0
    print(f"EMD retrieval over {store.n} docs: "
          f"{1e3 * dt_r / args.batch:.2f} ms/request, "
          f"neighbors={np.asarray(idx).tolist()}")
    print("OK")


if __name__ == "__main__":
    main()
