"""Serving driver: batched prefill + autoregressive decode with a KV cache.

Prefills a batch of prompts through the reduced model, then greedily
decodes continuations token by token — the serve-side path the
prefill_32k / decode_32k dry-run cells lower at production scale.

Run: PYTHONPATH=src python examples/serve_decode.py [--arch gemma3-27b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.data.tokens import DataConfig, global_batch
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    params = M.init(jax.random.PRNGKey(0), cfg)
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.prompt_len,
                    global_batch=args.batch, seed=7)
    prompts = jnp.asarray(global_batch(dc, 0)["tokens"])
    print(f"{cfg.name} (reduced): prefill {prompts.shape} then decode "
          f"{args.gen_len} tokens")

    total = args.prompt_len + args.gen_len
    decode = jax.jit(lambda p, b, c: M.decode_step(p, b, c, cfg))

    t0 = time.perf_counter()
    cache = M.init_decode_cache(cfg, args.batch, total, dtype=jnp.float32)
    # prefill via the decode path token-by-token for cache layout parity
    # with M.prefill (which returns a compact cache); timing reported for
    # the decode loop only.
    for t in range(args.prompt_len):
        logits, cache = decode(params, {"tokens": prompts[:, t:t + 1],
                                        "cache_index": jnp.int32(t)}, cache)
    t_prefill = time.perf_counter() - t0

    out = []
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
    t0 = time.perf_counter()
    for t in range(args.prompt_len, total):
        out.append(tok)
        logits, cache = decode(params, {"tokens": tok,
                                        "cache_index": jnp.int32(t)}, cache)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"prefill(sequential) {t_prefill:.2f}s; decode "
          f"{args.gen_len} x {args.batch} tokens in {dt:.2f}s "
          f"({1e3 * dt / args.gen_len:.1f} ms/token/batch)")
    print("continuations:", gen[:, :8].tolist())
    assert bool(jnp.isfinite(logits).all())
    print("OK")


if __name__ == "__main__":
    main()
