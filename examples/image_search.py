"""Dense-histogram image search: the RWMD failure mode and its fix.

MNIST-like blobs WITH background (all supports overlap). RWMD collapses to
0 for every pair (paper Table 6: 10% precision = chance); OMR/ACT restore
the ranking at the same linear complexity. All scoring goes through the
unified ``EmdIndex`` API.

Run: PYTHONPATH=src python examples/image_search.py
"""
from repro.api import EmdIndex, EngineConfig
from repro.data.synth import make_image_like


def main() -> None:
    for background in (False, True):
        corpus, labels = make_image_like(n_images=96, n_classes=6, side=12,
                                         include_background=background,
                                         seed=4)
        tag = "dense (with background)" if background else "sparse"
        print(f"\n=== {tag}: n={corpus.n} bins/histogram={corpus.hmax} ===")
        rw = EmdIndex.build(corpus, EngineConfig(method="rwmd")).scores(
            corpus.ids[0], corpus.w[0])
        print(f"RWMD scores vs doc 0: min={float(rw.min()):.5f} "
              f"max={float(rw.max()):.5f}"
              + ("   <- ALL ZERO: full support overlap" if background else ""))
        for name, cfg in [("RWMD", EngineConfig(method="rwmd")),
                          ("OMR", EngineConfig(method="omr")),
                          ("ACT-7", EngineConfig(method="act", iters=7))]:
            index = EmdIndex.build(corpus, cfg)
            p = index.precision_at_l(labels, 8)
            chance = 1.0 / (int(labels.max()) + 1)
            note = "  (~chance!)" if abs(p - chance) < 0.08 else ""
            print(f"  {name:6s} precision@8 = {p:.3f}{note}")


if __name__ == "__main__":
    main()
