"""Dense-histogram image search: the RWMD failure mode and its fix.

MNIST-like blobs WITH background (all supports overlap). RWMD collapses to
0 for every pair (paper Table 6: 10% precision = chance); OMR/ACT restore
the ranking at the same linear complexity. All scoring goes through the
unified ``EmdIndex`` API, and serving queries run the CASCADED
prune-and-rescore path — with a stage ladder matched to the domain
(pruning dense histograms with the collapsed RWMD would be garbage, so
the dense cascade prunes with OMR), and recall printed vs exact EMD.

Run: PYTHONPATH=src python examples/image_search.py
"""
from repro import cascade
from repro.api import CascadeSpec, CascadeStage, EmdIndex, EngineConfig
from repro.data.synth import make_image_like


def main() -> None:
    for background in (False, True):
        corpus, labels = make_image_like(n_images=96, n_classes=6, side=12,
                                         include_background=background,
                                         seed=4)
        tag = "dense (with background)" if background else "sparse"
        print(f"\n=== {tag}: n={corpus.n} bins/histogram={corpus.hmax} ===")
        rw = EmdIndex.build(corpus, EngineConfig(method="rwmd")).scores(
            corpus.ids[0], corpus.w[0])
        print(f"RWMD scores vs doc 0: min={float(rw.min()):.5f} "
              f"max={float(rw.max()):.5f}"
              + ("   <- ALL ZERO: full support overlap" if background else ""))
        for name, cfg in [("RWMD", EngineConfig(method="rwmd")),
                          ("OMR", EngineConfig(method="omr")),
                          ("ACT-7", EngineConfig(method="act", iters=7))]:
            index = EmdIndex.build(corpus, cfg)
            p = index.precision_at_l(labels, 8)
            chance = 1.0 / (int(labels.max()) + 1)
            note = "  (~chance!)" if abs(p - chance) < 0.08 else ""
            print(f"  {name:6s} precision@8 = {p:.3f}{note}")

        # Cascaded serving + recall vs exact EMD. Sparse supports keep
        # the per-pair LP cheap enough for FULL exact scoring; on dense
        # histograms (144-bin LPs) the exact reference itself runs as an
        # ADMISSIBLE cascade — OMR/ACT prune (provable EMD lower bounds,
        # immune to the RWMD collapse), host-side LP rescore.
        top_l, nq = 6, 3
        q_ids, q_w = corpus.ids[:nq], corpus.w[:nq]
        if background:
            spec = CascadeSpec(stages=(CascadeStage("omr", 0.33),),
                               rescorer="act", rescorer_iters=7)
            exact_spec = CascadeSpec(
                stages=(CascadeStage("omr", 0.25),
                        CascadeStage("act", 8, iters=7)),
                rescorer="emd")
        else:
            # budgets sized for n=96 (the "fast" preset's 5% would clamp
            # to the top_l floor); residual recall loss here is the
            # ACT-vs-EMD ranking gap at the boundary, not pruning loss
            spec = CascadeSpec(stages=(CascadeStage("wcd", 0.5),
                                       CascadeStage("rwmd", 0.25)),
                               rescorer="act", rescorer_iters=7)
            exact_spec = CascadeSpec(stages=(CascadeStage("rwmd", corpus.n),),
                                     rescorer="emd")   # full exact EMD
        assert exact_spec.admissible
        _, idx = EmdIndex.build(corpus, EngineConfig(
            cascade=spec, top_l=top_l)).search(q_ids, q_w)
        _, idx_exact = EmdIndex.build(corpus, EngineConfig(
            cascade=exact_spec, top_l=top_l)).search(q_ids, q_w)
        print(f"  cascade {spec.describe()}: recall@{top_l} vs exact EMD "
              f"({exact_spec.describe()}) = "
              f"{cascade.topk_recall(idx, idx_exact):.3f}")


if __name__ == "__main__":
    main()
