"""End-to-end training driver: sharded train loop with checkpoint/restart,
fault injection, and straggler tracking — the full production path on a
host-device mesh.

Trains a reduced olmo-family model for a few hundred steps on the
deterministic synthetic pipeline; loss must drop. A node failure is
injected mid-run and recovered from the last checkpoint; the final state is
bit-identical to a failure-free run (deterministic data -> exact replay).

Run: PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
       python examples/train_lm.py [--steps 300]
(plain single-device works too; the mesh shrinks automatically)
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.data.tokens import DataConfig, global_batch
from repro.launch import mesh as Mx, steps as St
from repro.models import model as M
from repro.models.config import InputShape
from repro.optim import adamw
from repro.runtime.fault import FaultTolerantRunner


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--fail-at", type=int, default=77)
    args = ap.parse_args()

    n_dev = len(jax.devices())
    nd = max(n_dev // 2, 1)
    nm = max(n_dev // nd, 1)
    mesh = Mx.make_test_mesh(nd, nm)
    print(f"devices={n_dev} mesh=({nd} data, {nm} model)")

    cfg = smoke_config(args.arch)
    shape = InputShape("train", 64, 8, "train")
    opt_cfg = adamw.AdamWConfig(peak_lr=3e-3, warmup_steps=20,
                                total_steps=args.steps)
    step_fn, _ = St.jit_train_step(cfg, shape, mesh, opt_cfg=opt_cfg)
    params = M.init(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params, cfg.opt_state_dtype)
    dc = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=0)

    losses = []
    failed = {"done": False}

    def wrapped(state, batch):
        if (not failed["done"]
                and int(state["opt"]["step"]) == args.fail_at):
            failed["done"] = True
            raise RuntimeError("injected node failure")
        with jax.set_mesh(mesh):
            p, o, metrics = step_fn(state["params"], state["opt"], batch)
        losses.append(float(metrics["loss"]))
        return {"params": p, "opt": o}

    def batch_for(step: int):
        return {k: jnp.asarray(v) for k, v in global_batch(dc, step).items()}

    ckpt = tempfile.mkdtemp(prefix="train_lm_ckpt_")
    runner = FaultTolerantRunner(wrapped, batch_for, ckpt, ckpt_every=25)
    state = runner.run({"params": params, "opt": opt}, args.steps)

    print(f"restarts={runner.restarts} "
          f"straggler-flagged={len(runner.straggler.flagged_steps)}")
    k = max(len(losses) // 10, 1)
    first, last = float(np.mean(losses[:k])), float(np.mean(losses[-k:]))
    print(f"loss {first:.3f} -> {last:.3f} over {int(state['opt']['step'])} "
          f"steps (ckpts in {ckpt})")
    assert last < first - 0.2, "training did not improve loss"
    print("OK")


if __name__ == "__main__":
    main()
