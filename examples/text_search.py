"""End-to-end text similarity search (the paper's 20 Newsgroups workflow).

Builds a word2vec-like embedded corpus, scores every document against the
database with each method, and reports precision@top-l + per-query runtime —
a miniature of the paper's Fig. 8(a).

Run: PYTHONPATH=src python examples/text_search.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lc, retrieval
from repro.data.synth import make_text_like


def main() -> None:
    corpus, labels = make_text_like(n_docs=256, n_classes=8, vocab=1024,
                                    m=48, doc_len=60, hmax=48, seed=2)
    labels = jnp.asarray(labels)
    print(f"corpus: n={corpus.n} hmax={corpus.hmax} v={corpus.v} m={corpus.m}")

    for name, method, kw in [("BoW-cosine", "bow", {}),
                             ("WCD", "wcd", {}),
                             ("LC-RWMD", "rwmd", {}),
                             ("LC-OMR", "omr", {}),
                             ("LC-ACT-1", "act", dict(iters=1)),
                             ("LC-ACT-7", "act", dict(iters=7))]:
        t0 = time.perf_counter()
        S = retrieval.all_pairs_scores(corpus, method=method, **kw)
        jax.block_until_ready(S)
        dt = time.perf_counter() - t0
        precs = [retrieval.precision_at_l(S, labels, L) for L in (1, 4, 16)]
        print(f"{name:10s} prec@1/4/16 = "
              + "/".join(f"{p:.3f}" for p in precs)
              + f"   ({1e3 * dt / corpus.n:.2f} ms/query)")

    # single query with the Pallas-kernel-backed engine
    s_k = lc.lc_act_scores(corpus, corpus.ids[0], corpus.w[0], iters=3,
                           use_kernels=True)
    s_j = lc.lc_act_scores(corpus, corpus.ids[0], corpus.w[0], iters=3)
    print("\nkernel engine max |diff| vs jnp engine:",
          float(jnp.max(jnp.abs(s_k - s_j))))


if __name__ == "__main__":
    main()
