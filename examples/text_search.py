"""End-to-end text similarity search (the paper's 20 Newsgroups workflow).

Builds a word2vec-like embedded corpus, serves it through one
``EmdIndex`` per method, and reports precision@top-l + per-query runtime —
a miniature of the paper's Fig. 8(a). Serving queries then go through the
CASCADED search path (cheap bounds prune, ACT rescores — see the
"Cascaded search" README section), with recall measured against exact
EMD. The same call sites work unchanged with ``backend="pallas"`` (fused
kernels) or ``backend="distributed"`` (mesh-sharded), demonstrated at the
end.

Run: PYTHONPATH=src python examples/text_search.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import cascade
from repro.api import CascadeSpec, CascadeStage, EmdIndex, EngineConfig
from repro.core import retrieval
from repro.data.synth import make_text_like


def main() -> None:
    corpus, labels = make_text_like(n_docs=256, n_classes=8, vocab=1024,
                                    m=48, doc_len=60, hmax=48, seed=2)
    labels = jnp.asarray(labels)
    print(f"corpus: n={corpus.n} hmax={corpus.hmax} v={corpus.v} m={corpus.m}")

    for name, cfg in [("BoW-cosine", EngineConfig(method="bow")),
                      ("WCD", EngineConfig(method="wcd")),
                      ("LC-RWMD", EngineConfig(method="rwmd")),
                      ("LC-OMR", EngineConfig(method="omr")),
                      ("LC-ACT-1", EngineConfig(method="act", iters=1)),
                      ("LC-ACT-7", EngineConfig(method="act", iters=7))]:
        index = EmdIndex.build(corpus, cfg)
        t0 = time.perf_counter()
        S = index.all_pairs()
        jax.block_until_ready(S)
        dt = time.perf_counter() - t0
        precs = [retrieval.precision_at_l(S, labels, L) for L in (1, 4, 16)]
        print(f"{name:10s} prec@1/4/16 = "
              + "/".join(f"{p:.3f}" for p in precs)
              + f"   ({1e3 * dt / corpus.n:.2f} ms/query)")

    # Cascaded serving: wcd prefetch -> rwmd prune -> ACT rescore. Only
    # the pruned candidate ladder is ever rescored. Recall is measured
    # against EXACT EMD, itself served by the cascade subsystem: an
    # ADMISSIBLE ladder (every stage a provable EMD lower bound) with
    # generous budgets feeding the host-side LP rescorer — full-corpus
    # exact EMD at these sizes would be ~300 ms/pair x n x nq.
    top_l, nq = 8, 4
    q_ids, q_w = corpus.ids[:nq], corpus.w[:nq]
    fast = EmdIndex.build(corpus, EngineConfig(cascade="fast",
                                               top_l=top_l))
    t0 = time.perf_counter()
    _, idx_fast = fast.search(q_ids, q_w)
    jax.block_until_ready(idx_fast)
    dt = time.perf_counter() - t0
    exact_spec = CascadeSpec(stages=(CascadeStage("rwmd", 0.5),
                                     CascadeStage("act", 0.1, iters=3)),
                             rescorer="emd")
    assert exact_spec.admissible
    _, idx_exact = EmdIndex.build(corpus, EngineConfig(
        cascade=exact_spec, top_l=top_l)).search(q_ids, q_w)
    rows = cascade.stage_rows(cascade.CASCADES["fast"], corpus.n, top_l)
    print(f"\ncascade {cascade.CASCADES['fast'].describe()}  "
          f"(rows/query: {rows})")
    print(f"  recall@{top_l} vs exact EMD "
          f"({exact_spec.describe()}, admissible) = "
          f"{cascade.topk_recall(idx_fast, idx_exact):.3f}   "
          f"({1e3 * dt / nq:.2f} ms/query incl. compile)")

    # identical call, Pallas-kernel backend (interpret mode off-TPU)
    idx_ref = EmdIndex.build(corpus, EngineConfig(method="act", iters=3))
    s_j = idx_ref.scores(corpus.ids[0], corpus.w[0])
    idx_k = EmdIndex.build(corpus, EngineConfig(method="act", iters=3,
                                                backend="pallas"))
    s_k = idx_k.scores(corpus.ids[0], corpus.w[0])
    print("\npallas backend max |diff| vs reference backend:",
          float(jnp.max(jnp.abs(s_k - s_j))))

    # identical call, distributed backend (single-device mesh here; a
    # multi-host launcher passes its production mesh to build())
    idx_d = EmdIndex.build(corpus, EngineConfig(method="act", iters=3,
                                                backend="distributed"))
    s_d = idx_d.scores(corpus.ids[:8], corpus.w[:8])
    loop = np.stack([np.asarray(idx_ref.scores(corpus.ids[u], corpus.w[u]))
                     for u in range(8)])
    print("distributed backend max |diff| vs reference backend:",
          float(np.max(np.abs(np.asarray(s_d) - loop))))


if __name__ == "__main__":
    main()
